"""Quickstart: PTQTP on a single weight matrix in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Decomposes W into two trit-planes + group scales (paper Alg. 1/2), packs them
2-bit, and runs the multiplication-free matmul — comparing reconstruction
error against binary and 2/3-bit RTN baselines.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.baselines.billm import billm_quantize
from repro.core.baselines.rtn import rtn_quantize
from repro.core.packing import pack_trits, ptqtp_weight_bytes
from repro.core.ptqtp import (PTQTPConfig, ptqtp_dequantize, ptqtp_error,
                              ptqtp_quantize)
from repro.kernels.ternary_matmul.ops import ternary_matmul


def main():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((512, 1024), dtype=np.float32) * 0.02)

    # --- quantize: W ≈ diag(α¹)T¹ + diag(α²)T² ---------------------------
    q = ptqtp_quantize(w, PTQTPConfig(group_size=128, t_max=50, eps=1e-4))
    print(f"converged in {int(q.iters)} iterations")
    print(f"relative error   PTQTP-1.58b : {float(ptqtp_error(w, q)):.4f}")
    for bits in (3, 2):
        w_hat, _ = rtn_quantize(w, bits=bits, group_size=128)
        rel = float(jnp.linalg.norm(w - w_hat) / jnp.linalg.norm(w))
        print(f"relative error   RTN-{bits}b g128 : {rel:.4f}")
    w_bin, _ = billm_quantize(w)
    rel = float(jnp.linalg.norm(w - w_bin) / jnp.linalg.norm(w))
    print(f"relative error   binary-resid: {rel:.4f}")

    # --- pack: 4 trits/byte → 0.53 B/weight -------------------------------
    t1p, t2p = pack_trits(q.t1), pack_trits(q.t2)
    nbytes = t1p.nbytes + t2p.nbytes + q.alpha.nbytes
    print(f"storage: {w.nbytes} B fp32 -> {nbytes} B packed "
          f"({w.nbytes / nbytes:.2f}x; fp16 baseline "
          f"{2 * w.size / ptqtp_weight_bytes(w.shape, 128):.2f}x)")

    # --- multiplication-free matmul ---------------------------------------
    x = jnp.asarray(rng.standard_normal((4, 1024), dtype=np.float32))
    y = ternary_matmul(x, t1p, t2p, q.alpha, group_size=128)
    y_ref = x @ ptqtp_dequantize(q).T
    print(f"ternary matmul max|Δ| vs dequantized dense: "
          f"{float(jnp.max(jnp.abs(y - y_ref))):.2e}")


if __name__ == "__main__":
    main()
