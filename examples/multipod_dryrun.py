"""Production-mesh walkthrough: lower the PTQTP-quantized serving step of any
assigned architecture onto the 2-pod × 16×16 mesh and read the roofline.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch gemma3-27b

Thin veneer over repro.launch.dryrun (which owns the 512-placeholder-device
XLA flag) run in a subprocess so this process's JAX stays single-device.
"""

import argparse
import json
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="multi", choices=("single", "multi"))
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as td:
        for quantized in (False, True):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", args.arch, "--shape", args.shape,
                   "--mesh", args.mesh, "--out", td]
            if quantized:
                cmd.append("--quantized")
            subprocess.run(cmd, cwd=str(REPO), check=True,
                           env={"PYTHONPATH": str(REPO / "src"),
                                "PATH": "/usr/bin:/bin", "HOME": "/root"},
                           capture_output=True, text=True)
            tag = (f"{args.arch}__{args.shape}__{args.mesh}"
                   + ("__q" if quantized else ""))
            res = json.loads((Path(td) / f"{tag}.json").read_text())
            r = res["roofline"]
            label = "PTQTP-1.58b" if quantized else "fp16/bf16  "
            print(f"{label} chips={res['n_chips']:4d} "
                  f"compute={r['compute_s']:.2e}s "
                  f"memory={r['memory_s']:.2e}s "
                  f"collective={r['collective_s']:.2e}s "
                  f"dominant={r['dominant']}")


if __name__ == "__main__":
    main()
