"""Fault-tolerance walkthrough: checkpoint → simulated preemption → resume →
elastic restore, with heartbeat/straggler monitoring.

    PYTHONPATH=src python examples/fault_tolerant_training.py

Demonstrates the runtime substrate a 1000-node fleet relies on (DESIGN.md §5):
every step heartbeats; a SIGTERM-style preemption checkpoints and exits; the
restarted trainer resumes exactly (same step, same data); the elastic restore
path reloads the same checkpoint for a different host/mesh layout.
"""

import tempfile
from pathlib import Path

import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamW
from repro.runtime.checkpoint import latest_step, load_checkpoint
from repro.runtime.monitor import StragglerDetector
from repro.runtime.preempt import PreemptionGuard
from repro.training.trainer import Trainer, TrainerConfig


def make_trainer(workdir, steps, log=print):
    cfg = configs.get_smoke_config("qwen2-1.5b")
    return Trainer(
        cfg, AdamW(lr=3e-3),
        DataConfig(seq_len=64, global_batch=8),
        TrainerConfig(total_steps=steps, ckpt_dir=str(workdir / "ckpt"),
                      ckpt_interval=10, log_interval=10,
                      run_dir=str(workdir / "run")),
        log_fn=log)


def main():
    workdir = Path(tempfile.mkdtemp(prefix="ptqtp_ft_"))
    print(f"workdir: {workdir}")

    # --- phase 1: train, get preempted at step ~15 -------------------------
    guard = PreemptionGuard(signals=())
    t1 = make_trainer(workdir, steps=100)
    seen = []

    def log_and_preempt(msg):
        print(msg)
        seen.append(msg)
        if "step 15" in msg or (t1.history and t1.history[-1]["step"] >= 15):
            guard.request()   # what SIGTERM would do on a real fleet

    t1.log = log_and_preempt
    t1.fit(guard=guard)
    step1 = latest_step(workdir / "ckpt")
    print(f"[1] preempted; last committed checkpoint @ step {step1}")
    assert step1 is not None and step1 < 100

    # --- phase 2: restart resumes from the checkpoint ----------------------
    t2 = make_trainer(workdir, steps=40)
    t2.fit()
    print(f"[2] resumed run reached step {t2.history[-1]['step']} "
          f"(started at {t2.history[0]['step']})")
    assert t2.history[0]["step"] == step1 + 1

    # --- phase 3: elastic restore (different host count reads same files) --
    step, tree, _ = load_checkpoint(workdir / "ckpt")
    n_params = sum(int(np.prod(np.shape(x)))
                   for x in _leaves(tree["params"]))
    print(f"[3] elastic restore of step {step}: {n_params:,} params as host "
          f"arrays — caller re-device_puts with its own mesh shardings")

    # --- phase 4: fleet health from heartbeats ----------------------------
    rep = StragglerDetector(str(workdir / "run")).assess()
    print(f"[4] fleet health: healthy={rep['healthy']} dead={rep['dead']} "
          f"stragglers={rep['stragglers']} "
          f"median_step={rep['median_step_s']:.3f}s")


def _leaves(tree):
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _leaves(v)
    else:
        yield tree


if __name__ == "__main__":
    main()
