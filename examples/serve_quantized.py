"""End-to-end driver: train a byte LM → stream-quantize into a trit-plane
artifact → boot the server from the artifact, comparing FP and 1.58-bit
generations.

    PYTHONPATH=src python examples/serve_quantized.py [--steps 300]

This is the paper's deployment story in one script: post-training, zero
calibration data, model-agnostic tree walk, multiplication-free serving —
with the quantized model persisted as a versioned on-disk artifact
(quantize once) that server processes memory-map at boot (serve many,
without ever touching the FP weights again). Serving goes through the v1
request API: ``submit(prompt, SamplingParams(...)) -> RequestHandle``,
with the first request consumed as a token stream — and then once more
over HTTP (v1.4): the same engine behind an ``EngineDriver`` thread and
the asyncio SSE endpoint, consumed with nothing but ``urllib`` — here
under crash-restart supervision (v1.5): an ``EngineSupervisor`` owns a
factory that re-maps the artifact, so engine death would rebuild a new
generation and replay in-flight requests bit-identically.
"""

import argparse
import json
import sys
import tempfile
import time
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from benchmarks.common import perplexity, trained_eval_model
from repro.artifacts import load_artifact, write_artifact
from repro.core.ptqtp import PTQTPConfig
from repro.data.tokenizer import ByteTokenizer
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.frontend import EngineSupervisor, ThreadedHttpServer


def sse_completion(base_url, prompt_ids, max_new=24, tenant="", seed=0):
    """Consume ``POST /v1/completions`` as an SSE stream with the stdlib:
    one ``data:`` JSON event per token, a terminal result event, then
    ``data: [DONE]``. Returns (token ids, result dict)."""
    body = json.dumps({"prompt": prompt_ids, "stream": True,
                       "max_new_tokens": max_new, "tenant": tenant,
                       "seed": seed}).encode()
    req = urllib.request.Request(
        base_url + "/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    tokens, result = [], None
    with urllib.request.urlopen(req) as resp:
        for raw in resp:          # SSE events arrive one line at a time
            line = raw.decode("utf-8").strip()
            if not line.startswith("data: ") or line == "data: [DONE]":
                continue
            event = json.loads(line[len("data: "):])
            if "token" in event:
                tokens.append(event["token"])
            else:                 # the terminal RequestResult summary
                result = event
    return tokens, result

PROMPTS = [
    "12 plus 30 equals",
    "count 7 8 9",
    "slot 3 holds 77 ; recall slot 3 gives",
    "the model computes",
    "5 plus 5 equals",
    "count 20 21 22",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--artifact", default=None,
                    help="artifact directory (default: a temp dir)")
    args = ap.parse_args()

    # --- 1. a trained model (cached under benchmarks/results) -------------
    cfg, params, _ = trained_eval_model(steps=args.steps)
    print(f"[1] trained LM: {cfg.n_layers}L d={cfg.d_model} "
          f"ppl={perplexity(params, cfg, n_batches=4):.3f}")

    # --- 2. PTQTP → on-disk artifact (single pass, no data, streamed) -----
    out = args.artifact or tempfile.mkdtemp(prefix="ptqtp_artifact_") + "/lm"
    t0 = time.time()
    write_artifact(out, arch=cfg.name, model_cfg=cfg,
                   ptqtp_cfg=PTQTPConfig(group_size=128, t_max=50),
                   params=params, overwrite=True)
    t_quant = time.time() - t0
    t0 = time.time()
    qparams, manifest = load_artifact(out)
    t_load = time.time() - t0
    stats = manifest["stats"]
    print(f"[2] PTQTP: {stats['n_quantized']} kernels "
          f"({stats['source_fp16_bytes'] / stats['quantized_bytes']:.2f}x vs "
          f"fp16, {stats['bytes_per_weight']:.4f} B/weight) quantized+saved "
          f"in {t_quant:.1f}s, memory-mapped back in {t_load * 1e3:.0f}ms; "
          f"ppl={perplexity(qparams, cfg, n_batches=4):.3f}")

    # --- 3. serve batched requests from both models (Serving API v1) ------
    # FP32 serves from host memory; PTQTP boots straight off the artifact —
    # the bucketed scheduler's bounded compile set is fully precompiled by
    # warmup() in both cases. Requests go through submit(prompt,
    # SamplingParams) -> RequestHandle; the first request is consumed as a
    # token stream, the rest through blocking result()s. Per-request seeds
    # make any sampled request reproducible regardless of its batch-mates.
    tok = ByteTokenizer()
    for tag, p in (("fp32", params), ("ptqtp-1.58b artifact", qparams)):
        eng = ServingEngine(p, cfg, EngineConfig(max_slots=4, capacity=128,
                                                 prefill_chunk=32))
        eng.warmup()
        handles = [eng.submit(tok.encode(prompt, eos=False),
                              SamplingParams(max_new_tokens=args.max_new,
                                             seed=i))
                   for i, prompt in enumerate(PROMPTS)]
        t0 = time.time()
        streamed = "".join(tok.decode([t]) for t in handles[0].tokens())
        results = [h.result() for h in handles]
        n_tok = sum(len(r.tokens) for r in results)
        ttft = 1e3 * max(r.ttft for r in results)
        print(f"[3] {tag}: {len(results)} reqs, {n_tok} tokens, "
              f"{n_tok / (time.time() - t0):.1f} tok/s, "
              f"worst ttft {ttft:.0f}ms, "
              f"{eng.compile_stats()['n_prefill_compiles']} prefill programs")
        print(f"      {PROMPTS[0]!r} ~> {streamed.split('.')[0]!r} (streamed)")
        for r in sorted(results, key=lambda r: r.uid)[1:3]:
            text = tok.decode(list(r.tokens)).split(".")[0]
            print(f"      {PROMPTS[r.uid]!r} -> {text!r}")

    # --- 4. the same artifact over HTTP, supervised (v1.4/v1.5) -----------
    # one EngineDriver thread owns the engine; the asyncio frontend streams
    # SSE. Tokens over the wire are bit-identical to in-process submit()
    # at temperature 0 — asserted here against the last in-process run.
    # The driver lifecycle is wrapped in an EngineSupervisor whose factory
    # re-maps the artifact: should the engine ever die (or hang a step),
    # it is rebuilt under a new generation id and every in-flight request
    # replays from token 0, deduped against what its client already saw —
    # this is what `serve.py --supervise --artifact <dir>` runs in
    # production, and because replay rides the determinism contract the
    # streams are bit-identical either way.
    def engine_factory():
        p, _ = load_artifact(out, verify="off")
        return ServingEngine(p, cfg, EngineConfig(max_slots=4, capacity=128,
                                                  prefill_chunk=32))

    driver = EngineSupervisor(engine_factory).start()
    srv = ThreadedHttpServer(driver).start()
    base = f"http://{srv.host}:{srv.port}"
    streamed_ids, result = sse_completion(
        base, tok.encode(PROMPTS[0], eos=False), max_new=args.max_new,
        tenant="example", seed=0)
    assert tuple(streamed_ids) == results[0].tokens  # wire == in-process
    with urllib.request.urlopen(base + "/healthz") as resp:
        health = json.loads(resp.read())
    sup = health["supervisor"]
    print(f"[4] http: {base} streamed {len(streamed_ids)} tokens "
          f"(finish_reason={result['finish_reason']}, bit-identical to "
          f"in-process); healthz ok={health['ok']}, supervised "
          f"(generation {sup['generation']}, {sup['restarts']} restarts)")
    print(f"      {PROMPTS[0]!r} ~> "
          f"{tok.decode(streamed_ids).split('.')[0]!r} (SSE)")
    srv.stop()
    driver.drain(timeout=60)
    driver.close()


if __name__ == "__main__":
    main()
