"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), v5e-class constants:

  compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory     = HLO_bytes_per_chip / HBM_BW
  collective = collective_bytes_per_chip / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (the SPMD-partitioned
per-device module). Collective bytes are NOT in cost_analysis — we parse the
post-partitioning HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# -- hardware constants (TPU v5e-class target; see system contract) ---------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "token": 0,
    "f4e2m1fn": 1, "u1": 1, "s1": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuples: '(bf16[2,3]{...}, u8[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_op: Dict[str, int]
    counts: Dict[str, int]

    def to_dict(self):
        return {"total_bytes": self.total_bytes, "by_op": self.by_op,
                "counts": self.counts}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in the (post-SPMD) HLO text."""
    defs: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)

    by_op: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opname = m.group(3)
        base = None
        for op in COLLECTIVE_OPS:
            if opname == op or opname.startswith(op + "-start") or \
               opname.startswith(op + "."):
                base = op
                break
        if base is None:
            continue
        # operand list between the first '(' after the op name and its ')'
        try:
            args = line.split(opname, 1)[1]
            args = args[args.index("(") + 1:]
            depth = 1
            out = []
            for ch in args:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            arg_str = "".join(out)
        except (ValueError, IndexError):
            continue
        nbytes = 0
        # operands may appear as %name refs or inline-typed values
        names = re.findall(r"%([\w\.\-]+)", arg_str)
        if names:
            for nm in names:
                if nm in defs:
                    nbytes += shape_bytes(defs[nm])
        if nbytes == 0:
            nbytes = shape_bytes(arg_str)
        if nbytes == 0:
            # last resort: the result type (= operand size for all-reduce)
            nbytes = shape_bytes(m.group(2))
        by_op[base] += nbytes
        counts[base] += 1
    total = sum(by_op.values())
    return CollectiveStats(total, by_op, counts)


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   collective_bytes_per_chip: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops_per_chip / PEAK_FLOPS,
        "memory_s": bytes_per_chip / HBM_BW,
        "collective_s": collective_bytes_per_chip / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    terms["dominant"] = dominant.replace("_s", "")
    terms["step_lower_bound_s"] = bound_s
    # fraction of the bound spent doing useful math (roofline fraction)
    terms["roofline_fraction"] = (
        terms["compute_s"] / bound_s if bound_s > 0 else float("nan"))
    return terms


def model_flops(cfg, shape_info, *, train: bool) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE); decode D=B."""
    total, active = cfg.param_counts()
    n = active
    if shape_info["kind"] == "train":
        d = shape_info["global_batch"] * shape_info["seq_len"]
        return 6.0 * n * d
    if shape_info["kind"] == "prefill":
        d = shape_info["global_batch"] * shape_info["seq_len"]
        return 2.0 * n * d  # forward only
    # decode: one token per sequence
    return 2.0 * n * shape_info["global_batch"]
