"""Zero-copy artifact reader: memory-mapped boot of a quantized model.

``load_artifact`` reconstructs the params pytree straight off the shard
files: every tensor leaf — packed trit-planes, group scales, and the FP
leaves — is an ``np.memmap`` view at its manifest byte-offset, so booting a
server materializes *no* second host copy of the model. Pages fault in as
the first dispatches touch them (and the OS page cache shares them across
server processes on one host — quantize once, serve many).

Integrity: the manifest must be ``complete`` (the writer only publishes
complete artifacts, so an incomplete one means a torn copy), the format
version must match, and ``verify=True`` (or :func:`verify_artifact`)
re-checksums every buffer against its recorded crc32.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import numpy as np

from repro.artifacts import format as afmt
from repro.artifacts.format import MANIFEST_NAME, ArtifactError


def read_manifest(artifact_dir: str | Path) -> Dict[str, Any]:
    """Load and sanity-check the manifest (no tensor data is touched)."""
    artifact_dir = Path(artifact_dir)
    p = artifact_dir / MANIFEST_NAME
    if not p.exists():
        raise ArtifactError(f"not an artifact directory (no {MANIFEST_NAME}): "
                            f"{artifact_dir}")
    with open(p) as f:
        manifest = json.load(f)
    if manifest.get("format") != afmt.FORMAT_NAME:
        raise ArtifactError(f"{p}: format {manifest.get('format')!r} is not "
                            f"{afmt.FORMAT_NAME!r}")
    if manifest.get("format_version") != afmt.FORMAT_VERSION:
        raise ArtifactError(
            f"{p}: format_version {manifest.get('format_version')} != "
            f"supported {afmt.FORMAT_VERSION}")
    if not manifest.get("complete"):
        raise ArtifactError(
            f"{artifact_dir} is incomplete (interrupted write or torn copy); "
            "re-run the quantize CLI to finish it")
    return manifest


def _buffer_view(mm: np.memmap, rec: Dict[str, Any], where: str) -> np.ndarray:
    end = rec["offset"] + rec["nbytes"]
    if end > mm.shape[0]:
        raise ArtifactError(f"{where}: buffer [{rec['offset']}, {end}) "
                            f"exceeds shard size {mm.shape[0]}")
    view = mm[rec["offset"]:end].view(np.dtype(rec["dtype"]))
    return view.reshape(rec["shape"])


def load_artifact(artifact_dir: str | Path, *, verify: bool = False
                  ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """-> (params_tree, manifest) with memmap-backed leaves.

    ``verify=True`` eagerly re-checksums every buffer (reads the whole
    artifact once); the default leaves pages untouched until first use.
    """
    artifact_dir = Path(artifact_dir)
    manifest = read_manifest(artifact_dir)
    mmaps: Dict[str, np.memmap] = {}
    for shard in manifest["shards"]:
        p = artifact_dir / shard["file"]
        if not p.exists() or p.stat().st_size < shard["nbytes"]:
            raise ArtifactError(f"shard {p} missing or truncated "
                                f"(need {shard['nbytes']} bytes)")
        mmaps[shard["file"]] = np.memmap(p, dtype=np.uint8, mode="r")

    flat: Dict[str, Any] = {}
    for path, rec in manifest["tensors"].items():
        views = {}
        for name, buf in rec["buffers"].items():
            view = _buffer_view(mmaps[buf["shard"]], buf, f"{path}:{name}")
            if verify and afmt.checksum(view) != buf["crc32"]:
                raise ArtifactError(
                    f"checksum mismatch for tensor {path!r} buffer {name!r} "
                    f"in {artifact_dir / buf['shard']} — artifact is corrupt; "
                    "re-run the quantize CLI with --overwrite")
            views[name] = view
        if rec["kind"] == "ptqtp":
            m = rec["meta"]
            fields = {f"{afmt.QK_KEY_PREFIX}{k}": v for k, v in views.items()}
            fields[afmt.QK_META_KEY] = np.asarray(
                [m["d_in"], m["d_out"], m["group_size"]], np.int64)
            flat[path] = afmt.decode_quantized_kernel(fields)
        else:
            flat[path] = views["data"]
    return afmt.unflatten_paths(flat), manifest


def load_model_config(manifest: Dict[str, Any]):
    """ModelConfig the artifact's params were built for."""
    return afmt.model_config_from_json(manifest["model_config"])


def verify_artifact(artifact_dir: str | Path) -> Dict[str, Any]:
    """Full integrity pass; returns the manifest stats on success."""
    _, manifest = load_artifact(artifact_dir, verify=True)
    return manifest.get("stats", {})
