"""Zero-copy artifact reader: memory-mapped boot of a quantized model.

``load_artifact`` reconstructs the params pytree straight off the shard
files: every tensor leaf — packed trit-planes, group scales, and the FP
leaves — is an ``np.memmap`` view at its manifest byte-offset, so booting a
server materializes *no* second host copy of the model. Pages fault in as
the first dispatches touch them (and the OS page cache shares them across
server processes on one host — quantize once, serve many).

Integrity: the manifest must be ``complete`` (the writer only publishes
complete artifacts, so an incomplete one means a torn copy) and the format
version must match. On top of that, ``verify`` selects how much of the data
itself is checked before boot:

  * ``"off"`` / ``False`` — trust the bytes; pages fault in lazily.
  * ``"sizes"`` — stat every shard and require its size to equal the
    manifest's byte count exactly (the writer truncates each shard to its
    committed length, so any mismatch is a torn copy or trailing garbage).
    Catches truncation in O(#shards) without reading a single tensor byte.
  * ``"full"`` / ``True`` — the sizes check plus an eager crc32 pass over
    every buffer. A mismatch raises :class:`~.format.ArtifactError` naming
    the tensor, buffer, shard file, byte range, and the expected vs actual
    crc32, so the damaged region can be located without a bisection hunt.
"""

from __future__ import annotations

import contextlib
import json
from pathlib import Path
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.artifacts import format as afmt
from repro.artifacts.format import MANIFEST_NAME, ArtifactError


def _boot_span(obs, name: str, **args):
    """A boot-phase span on the observability bundle's "boot" track, or a
    no-op when the caller didn't pass one (the reader stays importable and
    usable without the serving stack)."""
    if obs is None:
        return contextlib.nullcontext()
    from repro.serving.observability import TRACK_BOOT

    return obs.span(name, track=TRACK_BOOT, cat="boot", args=args or None)


def read_manifest(artifact_dir: str | Path) -> Dict[str, Any]:
    """Load and sanity-check the manifest (no tensor data is touched)."""
    artifact_dir = Path(artifact_dir)
    p = artifact_dir / MANIFEST_NAME
    if not p.exists():
        raise ArtifactError(f"not an artifact directory (no {MANIFEST_NAME}): "
                            f"{artifact_dir}")
    with open(p) as f:
        manifest = json.load(f)
    if manifest.get("format") != afmt.FORMAT_NAME:
        raise ArtifactError(f"{p}: format {manifest.get('format')!r} is not "
                            f"{afmt.FORMAT_NAME!r}")
    if manifest.get("format_version") != afmt.FORMAT_VERSION:
        raise ArtifactError(
            f"{p}: format_version {manifest.get('format_version')} != "
            f"supported {afmt.FORMAT_VERSION}")
    if not manifest.get("complete"):
        raise ArtifactError(
            f"{artifact_dir} is incomplete (interrupted write or torn copy); "
            "re-run the quantize CLI to finish it")
    return manifest


def _buffer_view(mm: np.memmap, rec: Dict[str, Any], where: str) -> np.ndarray:
    end = rec["offset"] + rec["nbytes"]
    if end > mm.shape[0]:
        raise ArtifactError(f"{where}: buffer [{rec['offset']}, {end}) "
                            f"exceeds shard size {mm.shape[0]}")
    view = mm[rec["offset"]:end].view(np.dtype(rec["dtype"]))
    return view.reshape(rec["shape"])


VERIFY_MODES = ("off", "sizes", "full")


def _verify_mode(verify: Union[bool, str, None]) -> str:
    if verify is True:
        return "full"
    if verify is False or verify is None:
        return "off"
    if verify in VERIFY_MODES:
        return verify
    raise ValueError(f"verify must be a bool or one of {VERIFY_MODES}, "
                     f"got {verify!r}")


def check_shard_sizes(artifact_dir: str | Path,
                      manifest: Dict[str, Any]) -> None:
    """The ``verify="sizes"`` fast pass: every shard file must exist with
    *exactly* its committed byte count (the writer truncates shards to
    their manifest length, so smaller means a torn copy and larger means
    trailing garbage). Reads no tensor bytes."""
    artifact_dir = Path(artifact_dir)
    for shard in manifest["shards"]:
        p = artifact_dir / shard["file"]
        if not p.exists():
            raise ArtifactError(f"shard {p} is missing "
                                f"(manifest commits {shard['nbytes']} bytes)")
        size = p.stat().st_size
        if size != shard["nbytes"]:
            what = "truncated" if size < shard["nbytes"] else "oversized"
            raise ArtifactError(
                f"shard {p} is {what}: {size} bytes on disk vs "
                f"{shard['nbytes']} committed in the manifest — torn copy "
                "or partial download; re-fetch or re-quantize the artifact")


def load_artifact(artifact_dir: str | Path, *,
                  verify: Union[bool, str] = False,
                  obs: Optional[Any] = None
                  ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """-> (params_tree, manifest) with memmap-backed leaves.

    ``verify`` is ``"off"``/``False`` (default; lazy pages, no checks beyond
    the manifest), ``"sizes"`` (stat-only shard-length check, no tensor
    reads), or ``"full"``/``True`` (sizes plus an eager crc32 re-checksum of
    every buffer — reads the whole artifact once). See module docstring.

    ``obs`` (optional, a ``repro.serving.observability.Observability``)
    records each boot phase — manifest read, shard size check, mmap,
    tensor assembly — as spans on the trace's "boot" track, so a served
    boot timeline shows where artifact-load time went.
    """
    artifact_dir = Path(artifact_dir)
    mode = _verify_mode(verify)
    with _boot_span(obs, "manifest_read", verify=mode):
        manifest = read_manifest(artifact_dir)
    if mode in ("sizes", "full"):
        with _boot_span(obs, "shard_size_check",
                        shards=len(manifest["shards"])):
            check_shard_sizes(artifact_dir, manifest)
    mmaps: Dict[str, np.memmap] = {}
    with _boot_span(obs, "mmap", shards=len(manifest["shards"])):
        for shard in manifest["shards"]:
            p = artifact_dir / shard["file"]
            if not p.exists() or p.stat().st_size < shard["nbytes"]:
                raise ArtifactError(f"shard {p} missing or truncated "
                                    f"(need {shard['nbytes']} bytes)")
            mmaps[shard["file"]] = np.memmap(p, dtype=np.uint8, mode="r")

    flat: Dict[str, Any] = {}
    with _boot_span(obs, "tensor_assemble",
                    tensors=len(manifest["tensors"]), checksum=mode == "full"):
        for path, rec in manifest["tensors"].items():
            views = {}
            for name, buf in rec["buffers"].items():
                view = _buffer_view(mmaps[buf["shard"]], buf, f"{path}:{name}")
                if mode == "full":
                    actual = afmt.checksum(view)
                    if actual != buf["crc32"]:
                        end = buf["offset"] + buf["nbytes"]
                        raise ArtifactError(
                            f"checksum mismatch for tensor {path!r} buffer "
                            f"{name!r}: shard {artifact_dir / buf['shard']} "
                            f"bytes [{buf['offset']}, {end}) expected "
                            f"crc32 {buf['crc32']:#010x}, got {actual:#010x} "
                            "— artifact is corrupt; re-run the quantize CLI "
                            "with --overwrite")
                views[name] = view
            if rec["kind"] == "ptqtp":
                m = rec["meta"]
                fields = {f"{afmt.QK_KEY_PREFIX}{k}": v
                          for k, v in views.items()}
                fields[afmt.QK_META_KEY] = np.asarray(
                    [m["d_in"], m["d_out"], m["group_size"]], np.int64)
                flat[path] = afmt.decode_quantized_kernel(fields)
            else:
                flat[path] = views["data"]
    return afmt.unflatten_paths(flat), manifest


def load_model_config(manifest: Dict[str, Any]):
    """ModelConfig the artifact's params were built for."""
    return afmt.model_config_from_json(manifest["model_config"])


def verify_artifact(artifact_dir: str | Path,
                    mode: str = "full") -> Dict[str, Any]:
    """Standalone integrity pass (``"full"`` or the stat-only ``"sizes"``);
    returns the manifest stats on success."""
    if _verify_mode(mode) == "off":
        raise ValueError('verify_artifact mode must be "sizes" or "full"')
    _, manifest = load_artifact(artifact_dir, verify=mode)
    return manifest.get("stats", {})
