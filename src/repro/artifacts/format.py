"""Artifact format: manifest schema, leaf codec, config (de)serialization.

This module owns every byte-level and JSON-level convention of the artifact
directory (see the package docstring in ``__init__`` for the layout). It is
deliberately free of any quantization or serving logic so that the writer,
the reader, *and* ``runtime/checkpoint.py`` (whose npz flatten routes its
``QuantizedKernel`` handling through :func:`encode_quantized_kernel` /
:func:`decode_quantized_kernel`) all share one codec — the two on-disk
formats cannot drift.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Iterator, Tuple

import numpy as np

from repro.core.quantize_model import QuantizedKernel

FORMAT_NAME = "ptqtp-artifact"
FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"
SHARD_ALIGN = 64  # byte alignment of every tensor buffer inside a shard

# QuantizedKernel buffer names, in canonical storage order.
QK_BUFFERS = ("t1p", "t2p", "alpha")
# Flat-key suffixes used by the npz checkpoint flatten (kept identical to the
# pre-unification checkpoint format so old checkpoints still load).
QK_KEY_PREFIX = "__qk_"
QK_META_KEY = "__qk_meta"


class ArtifactError(RuntimeError):
    """Malformed, incomplete, or corrupt artifact."""


# ---------------------------------------------------------------------------
# QuantizedKernel leaf codec (shared with runtime/checkpoint.py)
# ---------------------------------------------------------------------------

def encode_quantized_kernel(qk: QuantizedKernel) -> Dict[str, np.ndarray]:
    """QuantizedKernel -> flat field dict of host arrays.

    Field names are the checkpoint npz suffixes (``__qk_t1p`` ...); the
    static metadata rides along as one int64 vector so the whole kernel is
    representable in any array container.
    """
    fields = {f"{QK_KEY_PREFIX}{name}": np.asarray(getattr(qk, name))
              for name in QK_BUFFERS}
    fields[QK_META_KEY] = np.asarray(
        [qk.d_in, qk.d_out, qk.group_size], np.int64)
    return fields


def decode_quantized_kernel(fields: Dict[str, Any]) -> QuantizedKernel:
    """Inverse of :func:`encode_quantized_kernel` (accepts np or jax arrays)."""
    meta = np.asarray(fields[QK_META_KEY])
    return QuantizedKernel(
        fields[f"{QK_KEY_PREFIX}t1p"], fields[f"{QK_KEY_PREFIX}t2p"],
        fields[f"{QK_KEY_PREFIX}alpha"],
        int(meta[0]), int(meta[1]), int(meta[2]))


# ---------------------------------------------------------------------------
# params-tree walking (writer-side) / rebuilding (reader-side)
# ---------------------------------------------------------------------------

def iter_tree_leaves(tree: Any, path: str = "") -> Iterator[Tuple[str, Any]]:
    """Yield (path, leaf) pairs in the same order and with the same ``/a/b``
    path naming as ``quantize_tree``'s walk, one leaf at a time — the
    streaming writer's traversal never holds more than the current leaf."""
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from iter_tree_leaves(v, f"{path}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_tree_leaves(v, f"{path}/{i}")
    else:
        yield path, tree


def unflatten_paths(flat: Dict[str, Any]) -> Dict[str, Any]:
    """{"/a/b": leaf} -> nested dict tree (model params are dict-only)."""
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


# ---------------------------------------------------------------------------
# config (de)serialization
# ---------------------------------------------------------------------------

def ptqtp_config_to_json(cfg) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def ptqtp_config_from_json(d: Dict[str, Any]):
    from repro.core.ptqtp import PTQTPConfig

    return PTQTPConfig(**d)


# Runtime dispatch knobs that say nothing about the quantized weights: kept
# out of the manifest so artifact identity (and the writer's resume
# mismatch check) depends only on the model itself, and a served artifact
# never pins the kernel backend it happened to be quantized under.
RUNTIME_ONLY_CONFIG_KEYS = ("attn_backend",)


def model_config_to_json(cfg) -> Dict[str, Any]:
    d = dataclasses.asdict(cfg)
    for k in RUNTIME_ONLY_CONFIG_KEYS:
        d.pop(k, None)
    return d


def model_config_from_json(d: Dict[str, Any]):
    from repro.configs.base import ModelConfig
    from repro.models.moe import MoEConfig

    d = dict(d)
    for k in RUNTIME_ONLY_CONFIG_KEYS:
        d.pop(k, None)
    if d.get("moe") is not None:
        d["moe"] = MoEConfig(**d["moe"])
    for k in ("block_pattern", "prefix_pattern"):
        if d.get(k) is not None:
            d[k] = tuple(d[k])
    return ModelConfig(**d)


# ---------------------------------------------------------------------------
# checksums / buffer records
# ---------------------------------------------------------------------------

def byte_view(arr) -> np.ndarray:
    """Flat uint8 view of an array's raw bytes. ``memoryview(...).cast("B")``
    rejects non-standard element formats (ml_dtypes bfloat16 etc.); a uint8
    reinterpret-view is dtype-agnostic and still zero-copy for contiguous
    input."""
    return np.ascontiguousarray(np.atleast_1d(arr)).view(np.uint8).reshape(-1)


def checksum(data) -> int:
    """crc32 of a buffer's raw bytes (cheap, catches bit-flips/truncation)."""
    return zlib.crc32(byte_view(data)) & 0xFFFFFFFF


def buffer_record(shard: str, offset: int, arr: np.ndarray) -> Dict[str, Any]:
    """Manifest entry for one raw buffer inside a shard file."""
    return {
        "shard": shard,
        "offset": int(offset),
        "nbytes": int(arr.nbytes),
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "crc32": checksum(arr),
    }


def align_up(n: int, align: int = SHARD_ALIGN) -> int:
    return (n + align - 1) // align * align
