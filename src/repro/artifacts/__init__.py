"""Trit-plane artifact store: quantize once, serve many.

The deployable unit of a PTQTP model is a **versioned artifact directory**
— the packed ternary checkpoint the paper's "single-hour quantization,
model-agnostic deployment" story implies. Server processes boot from it with
``np.memmap`` (no FP weights touched, no re-quantization, no second host
copy), and the streaming writer produces it with peak incremental host
memory O(largest kernel).

Directory layout::

    artifact/
        manifest.json       the contract (schema below)
        shard_00000.bin     raw little-endian tensor bytes, 64-byte aligned
        shard_00001.bin     ... (rolled at shard_max_bytes boundaries)

**Manifest schema (stable contract, format_version 1).** Top-level keys:

  ``format``          literal ``"ptqtp-artifact"``
  ``format_version``  integer; readers must reject other versions
  ``complete``        bool; writers only publish ``true`` (atomic rename)
  ``arch``            architecture identifier (the ``repro.configs``
                      registry key for registry models; informational —
                      readers rebuild the model from ``model_config``)
  ``model_config``    ``ModelConfig`` as JSON (``dataclasses.asdict``)
  ``ptqtp_config``    ``PTQTPConfig`` as JSON
  ``shards``          ``[{"file", "nbytes"}]`` in creation order
  ``tensors``         ``{tree_path: record}`` — tree_path is the params-tree
                      path (``/blocks/b0/attn/q/kernel``); record is either

                      * ``kind="fp"``: ``buffers={"data": buf}`` — an
                        unquantized leaf (norms, embeddings, routers, ...);
                      * ``kind="ptqtp"``: ``buffers={"t1p","t2p","alpha"}``
                        (packed uint8 trit-planes + group scales),
                        ``meta={"d_in","d_out","group_size"}``,
                        ``source={"shape","dtype"}`` of the FP kernel, and
                        ``error={"rel_fro_error"}`` — the progressive
                        search's relative Frobenius approximation error;

                      every ``buf`` is ``{"shard", "offset", "nbytes",
                      "shape", "dtype", "crc32"}``
  ``stats``           aggregate byte/tensor counts (``bytes_per_weight`` is
                      the on-disk quantized bytes per source weight)

Compatibility rules: additions land as new optional keys; any change to the
meaning of existing keys or to the shard byte layout bumps
``format_version``. ``runtime/checkpoint.py`` shares this package's
``QuantizedKernel`` leaf codec, so checkpoint and artifact encodings of
quantized kernels cannot drift.
"""

from repro.artifacts.format import ArtifactError
from repro.artifacts.reader import (VERIFY_MODES, check_shard_sizes,
                                    load_artifact, load_model_config,
                                    read_manifest, verify_artifact)
from repro.artifacts.writer import (ArtifactWriter, iter_checkpoint_leaves,
                                    write_artifact)

__all__ = [
    "ArtifactError", "ArtifactWriter", "VERIFY_MODES", "check_shard_sizes",
    "iter_checkpoint_leaves", "load_artifact", "load_model_config",
    "read_manifest", "verify_artifact", "write_artifact",
]
