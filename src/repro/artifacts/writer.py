"""Streaming artifact writer: quantize one kernel at a time, commit as you go.

Memory posture: the walk holds host copies of *one* leaf's buffers at a time
(plus the transient dequantized copy used for the error stat), so the writer's
peak incremental host allocation is O(largest kernel), not O(model) — asserted
by ``tests/test_artifacts.py`` with tracemalloc.

Durability posture (same idiom as ``runtime/checkpoint.py``):

  * data is appended to shard files under ``<out>.staging/``; every
    ``commit_every`` tensors (group commit) the dirty shards are fsync'd
    and *then* the staging manifest is atomically replaced (tmp +
    ``os.replace``) — a tensor is *committed* iff it appears in the
    on-disk staging manifest, which only ever advances after the data it
    references is durable;
  * a crash mid-group leaves at worst an uncommitted tail past the last
    committed shard length; resume truncates it and re-quantizes only the
    tensors of the torn group (committed ones are ``skipped`` in the
    progress stream);
  * ``finalize()`` flushes any pending group, marks the manifest complete
    and ``os.rename``s the staging directory onto the final path — readers
    never observe a partial artifact.

``commit_every=1`` recovers the PR-3 per-tensor fsync behavior (maximum
resume granularity); the default batches fsyncs, removing the write path's
main durability overhead (1.18x over per-tensor at smoke scale) and
bringing streaming quantization to parity with the in-memory tree walk
(measured in ``benchmarks/bench_artifacts.py``).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.artifacts import format as afmt
from repro.artifacts.format import (MANIFEST_NAME, ArtifactError,
                                    align_up, buffer_record)
from repro.core.quantize_model import QuantizedKernel

ProgressFn = Callable[[Dict[str, Any]], None]


def _fsync_dir(path: Path):
    """Durably persist a directory entry (rename/replace targets)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform without directory fds — best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class ArtifactWriter:
    """Incremental, resumable writer for one artifact directory."""

    DEFAULT_COMMIT_EVERY = 8

    def __init__(self, out_dir: str | Path, *, arch: str,
                 model_config: Dict[str, Any], ptqtp_config: Dict[str, Any],
                 resume: bool = True, overwrite: bool = False,
                 shard_max_bytes: int = 1 << 28,
                 commit_every: Optional[int] = None):
        self.final = Path(out_dir)
        self.stage = self.final.with_name(self.final.name + ".staging")
        self.shard_max_bytes = int(shard_max_bytes)
        self.commit_every = max(1, int(commit_every
                                       if commit_every is not None
                                       else self.DEFAULT_COMMIT_EVERY))
        self._pending = 0        # tensors appended since the last durable commit
        self._dirty: set = set()  # shard files with appended-but-unfsynced data
        # An existing artifact is only replaced at finalize() — a crash
        # mid-quantize must never destroy the fleet's last good artifact.
        self._overwrite = overwrite
        if self.final.exists() and not overwrite:
            raise ArtifactError(
                f"artifact already exists: {self.final} "
                "(pass overwrite=True / --overwrite to replace)")
        if overwrite and self.stage.exists():  # overwrite restarts cleanly
            shutil.rmtree(self.stage)

        # JSON-canonical header (tuples → lists, etc.) so a resume compares
        # equal against the manifest it reads back from disk
        header = json.loads(json.dumps({
            "format": afmt.FORMAT_NAME,
            "format_version": afmt.FORMAT_VERSION,
            "arch": arch,
            "model_config": model_config,
            "ptqtp_config": ptqtp_config,
        }))
        if resume and (self.stage / MANIFEST_NAME).exists():
            self.manifest = self._resume(header)
        else:
            if self.stage.exists():
                shutil.rmtree(self.stage)
            self.stage.mkdir(parents=True)
            self.manifest = dict(header, complete=False, created=time.time(),
                                 shards=[], tensors={})
            # commit the header immediately: even under group commit (where
            # tensor commits are batched) a staging dir always records the
            # config it was written with, so resume can reject mismatches
            self._commit_manifest()

    # ------------------------------------------------------------- resume
    def _resume(self, header: Dict[str, Any]) -> Dict[str, Any]:
        with open(self.stage / MANIFEST_NAME) as f:
            manifest = json.load(f)
        for key, want in header.items():
            if manifest.get(key) != want:
                raise ArtifactError(
                    f"staging dir {self.stage} was written with a different "
                    f"{key!r} (have {manifest.get(key)!r}, want {want!r}); "
                    "remove it or pass overwrite=True to restart")
        # Drop any torn tail past the last committed tensor: the manifest's
        # per-shard nbytes only advances on commit, so truncating to it makes
        # the shard byte-exact with the committed record set.
        for rec in manifest["shards"]:
            p = self.stage / rec["file"]
            if not p.exists() or p.stat().st_size < rec["nbytes"]:
                raise ArtifactError(
                    f"shard {p} is shorter than its committed length "
                    f"({rec['nbytes']}); staging dir is corrupt — remove it")
            os.truncate(p, rec["nbytes"])
        return manifest

    # ------------------------------------------------------------ internals
    def _shard_for(self, nbytes: int) -> Dict[str, Any]:
        """Current shard record, rolling to a new file when adding `nbytes`
        would push the current one past shard_max_bytes (tensors never
        split across shards)."""
        shards = self.manifest["shards"]
        if shards and (shards[-1]["nbytes"] + nbytes <= self.shard_max_bytes
                       or shards[-1]["nbytes"] == 0):
            return shards[-1]
        rec = {"file": f"shard_{len(shards):05d}.bin", "nbytes": 0}
        (self.stage / rec["file"]).touch()
        shards.append(rec)
        return rec

    def _append_buffers(self, arrays: Dict[str, np.ndarray]
                        ) -> Dict[str, Dict[str, Any]]:
        """Append host arrays to the current shard; returns buffer records.
        The shard record's nbytes is only advanced here (in memory) — it
        reaches disk with the manifest commit, after the data is fsync'd
        (possibly a few tensors later, under group commit)."""
        total = sum(align_up(a.nbytes) for a in arrays.values())
        shard = self._shard_for(total)
        records = {}
        with open(self.stage / shard["file"], "r+b") as f:
            f.seek(shard["nbytes"])
            off = shard["nbytes"]
            for name, arr in arrays.items():
                arr = np.ascontiguousarray(arr)
                pad = align_up(off) - off
                if pad:
                    f.write(b"\0" * pad)
                    off += pad
                records[name] = buffer_record(shard["file"], off, arr)
                f.write(afmt.byte_view(arr))
                off += arr.nbytes
            f.flush()
        shard["nbytes"] = off
        self._dirty.add(shard["file"])
        return records

    def _tensor_added(self):
        """Group-commit bookkeeping: count the tensor, flush every N."""
        self._pending += 1
        if self._pending >= self.commit_every:
            self._commit_group()

    def _commit_group(self):
        """Make everything appended so far durable: fsync dirty shards
        first, then (and only then) advance the on-disk manifest — the
        commit invariant the resume path relies on."""
        for name in sorted(self._dirty):
            fd = os.open(self.stage / name, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        self._dirty.clear()
        self._commit_manifest()
        self._pending = 0

    def _commit_manifest(self):
        # fsync file-then-dir so "committed iff in the manifest" holds even
        # across power loss: the replace must never land with torn content
        tmp = self.stage / (MANIFEST_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self.manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.stage / MANIFEST_NAME)
        _fsync_dir(self.stage)

    # ------------------------------------------------------------------ API
    def committed(self, path: str) -> bool:
        return path in self.manifest["tensors"]

    def add_fp(self, path: str, arr) -> None:
        """Commit one unquantized FP leaf."""
        arr = np.asarray(arr)
        bufs = self._append_buffers({"data": arr})
        self.manifest["tensors"][path] = {"kind": "fp", "buffers": bufs}
        self._tensor_added()

    def add_quantized(self, path: str, qk: QuantizedKernel, *,
                      source_shape: Tuple[int, ...], source_dtype: str,
                      error: Optional[Dict[str, float]] = None) -> None:
        """Commit one quantized kernel (packed planes + scales + meta/stats)."""
        arrays = {name: np.asarray(getattr(qk, name))
                  for name in afmt.QK_BUFFERS}
        bufs = self._append_buffers(arrays)
        self.manifest["tensors"][path] = {
            "kind": "ptqtp",
            "meta": {"d_in": qk.d_in, "d_out": qk.d_out,
                     "group_size": qk.group_size},
            "source": {"shape": list(source_shape), "dtype": source_dtype},
            "error": error or {},
            "buffers": bufs,
        }
        self._tensor_added()

    def finalize(self) -> Path:
        """Compute summary stats, mark complete, atomically publish."""
        stats = {"n_tensors": 0, "n_quantized": 0, "fp_bytes": 0,
                 "quantized_bytes": 0, "quantized_weight_count": 0,
                 "source_fp16_bytes": 0}
        for rec in self.manifest["tensors"].values():
            stats["n_tensors"] += 1
            nbytes = sum(b["nbytes"] for b in rec["buffers"].values())
            if rec["kind"] == "ptqtp":
                stats["n_quantized"] += 1
                stats["quantized_bytes"] += nbytes
                n_w = int(np.prod(rec["source"]["shape"]))
                stats["quantized_weight_count"] += n_w
                stats["source_fp16_bytes"] += n_w * 2
            else:
                stats["fp_bytes"] += nbytes
        stats["total_bytes"] = stats["fp_bytes"] + stats["quantized_bytes"]
        if stats["quantized_weight_count"]:
            stats["bytes_per_weight"] = (stats["quantized_bytes"]
                                         / stats["quantized_weight_count"])
        self.manifest["stats"] = stats
        self.manifest["complete"] = True
        self.manifest["finalized"] = time.time()
        self._commit_group()  # flush any pending tensors with the final commit
        if self.final.exists():
            if not self._overwrite:
                raise ArtifactError(
                    f"artifact appeared at {self.final} during the write "
                    "(pass overwrite=True / --overwrite to replace it)")
            shutil.rmtree(self.final)  # old artifact survives until here
        os.rename(self.stage, self.final)
        _fsync_dir(self.final.parent)
        return self.final


# ---------------------------------------------------------------------------
# streaming quantization driver
# ---------------------------------------------------------------------------

def write_artifact(out_dir: str | Path, *, arch: str, model_cfg, ptqtp_cfg,
                   params: Any, predicate=None, compute_error: bool = True,
                   progress: Optional[ProgressFn] = None, resume: bool = True,
                   overwrite: bool = False,
                   shard_max_bytes: int = 1 << 28,
                   commit_every: Optional[int] = None) -> Path:
    """Quantize a model into an artifact, one kernel at a time.

    ``params`` is either a nested-dict tree (walked lazily leaf by leaf) or
    an iterable of ``(path, leaf)`` pairs — e.g.
    :func:`iter_checkpoint_leaves`, which streams straight out of a training
    checkpoint so the FP tree is never materialized in host memory at all.
    Tensors already committed in a staging manifest are skipped (resume).
    ``commit_every`` sets the fsync group-commit size (1 → per-tensor
    durability, default ``ArtifactWriter.DEFAULT_COMMIT_EVERY``).
    """
    import jax.numpy as jnp

    from repro.core import ptqtp as ptqtp_mod
    from repro.core.quantize_model import (default_predicate,
                                           dequantize_kernel, quantize_kernel)

    cfg = ptqtp_cfg or ptqtp_mod.PTQTPConfig()
    predicate = predicate or default_predicate
    writer = ArtifactWriter(
        out_dir, arch=arch,
        model_config=afmt.model_config_to_json(model_cfg),
        ptqtp_config=afmt.ptqtp_config_to_json(cfg),
        resume=resume, overwrite=overwrite, shard_max_bytes=shard_max_bytes,
        commit_every=commit_every)

    leaves: Iterable[Tuple[str, Any]]
    leaves = afmt.iter_tree_leaves(params) if isinstance(params, dict) \
        else params
    t0 = time.time()
    for idx, (path, leaf) in enumerate(leaves):
        info = {"index": idx, "path": path,
                "shape": tuple(np.shape(leaf)), "elapsed": time.time() - t0}
        if writer.committed(path):
            progress and progress(dict(info, action="skip"))
            continue
        if predicate(path, leaf, cfg.group_size):
            qk = quantize_kernel(leaf, cfg)
            error = None
            if compute_error:
                w_hat = dequantize_kernel(qk, jnp.float32)
                rel = float(jnp.linalg.norm(leaf - w_hat)
                            / jnp.maximum(jnp.linalg.norm(leaf), 1e-30))
                error = {"rel_fro_error": rel}
            writer.add_quantized(
                path, qk, source_shape=tuple(np.shape(leaf)),
                source_dtype=str(getattr(leaf, "dtype", "float32")),
                error=error)
            progress and progress(dict(info, action="quantize", error=error))
        else:
            writer.add_fp(path, leaf)
            progress and progress(dict(info, action="fp"))
    return writer.finalize()


def iter_checkpoint_leaves(ckpt_dir: str | Path, subtree: str = "params"
                           ) -> Iterable[Tuple[str, Any]]:
    """Stream FP leaves lazily out of a ``runtime/checkpoint.py`` checkpoint.

    ``np.load`` on an npz decompresses arrays on access, so this holds one
    tensor at a time — the quantize-from-checkpoint path never needs the
    model in host RAM twice (or even once, fully).
    """
    from repro.runtime.checkpoint import _SEP, latest_step

    ckpt_dir = Path(ckpt_dir)
    step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no LATEST in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    prefix = f"{subtree}{_SEP}"
    for shard in sorted(d.glob("host*.npz")):
        with np.load(shard) as z:
            for key in z.files:
                if not key.startswith(prefix):
                    continue
                path = "/" + key[len(prefix):].replace(_SEP, "/")
                yield path, z[key]
