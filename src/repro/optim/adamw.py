"""AdamW with configurable moment dtype (bf16 moments let 405B fit 256 chips)
and global-norm gradient clipping. Functional API mirroring optax:

  opt = AdamW(lr=..., moment_dtype=jnp.bfloat16)
  state = opt.init(params)
  new_params, new_state = opt.update(grads, state, params)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Union[float, Callable[[jax.Array], jax.Array]] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        count = state["count"] + 1
        lr = self.lr(count) if callable(self.lr) else self.lr

        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            vf = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            mhat = mf / b1c
            vhat = vf / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (new_p.astype(p.dtype), mf.astype(self.moment_dtype),
                    vf.astype(self.moment_dtype))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v, "count": count}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    """Linear warmup then cosine decay to floor·peak."""

    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup, warm, cos)

    return lr
