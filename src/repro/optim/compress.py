"""Int8 gradient compression with error feedback.

Distributed-optimization trick for bandwidth-bound fleets: gradients are
quantized to int8 with a per-tensor scale before the (all-)reduce, and the
quantization residual is carried to the next step (error feedback), which
keeps SGD/Adam convergence unbiased to first order.

In-graph usage (composes with any optimizer):

    cstate = init_error_feedback(params)
    grads_c, cstate = compress_decompress(grads, cstate)
    ... opt.update(grads_c, ...)

The compress→decompress round-trip stays in the compiled graph; on a real
mesh the int8 representation is what crosses ICI/DCN (4× fewer collective
bytes — the roofline collective-term lever measured in §Perf).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (decompressed int8-round-tripped grads, new error state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _q8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    out = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
