"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape_name)`` returns weak-type-correct, shardable specs
with NO device allocation, for all four assigned input shapes:

  train_4k     {"tokens"/"embeddings", "labels"}           (train_step)
  prefill_32k  {"tokens"/"embeddings"}                     (prefill)
  decode_32k   (state, tokens)  — one new token, 32k cache (serve_step)
  long_500k    (state, tokens)  — one new token, 512k cache (serve_step)

[audio]/[vlm] archs have a stub modality frontend: their specs carry
precomputed frame/patch embeddings (B, S, d_model) instead of token ids.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.models import init_decode_state
from repro.models.common import dtype_of


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, shape_name: str) -> Dict[str, Any]:
    """Train/prefill batch specs."""
    info = SHAPES[shape_name]
    b, s = info["global_batch"], info["seq_len"]
    adt = dtype_of(cfg.activation_dtype)
    if cfg.embed_inputs:
        batch = {"tokens": _sds((b, s), jnp.int32)}
    else:
        batch = {"embeddings": _sds((b, s, cfg.d_model), adt)}
    if info["kind"] == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def decode_state_specs(cfg, shape_name: str):
    """(state_specs, token_specs) for serve_step lowering."""
    info = SHAPES[shape_name]
    assert info["kind"] == "decode"
    b, s = info["global_batch"], info["seq_len"]
    # b/s must stay static (they are shapes): close over them, no args.
    state = jax.eval_shape(lambda: init_decode_state(cfg, b, s))
    adt = dtype_of(cfg.activation_dtype)
    if cfg.embed_inputs:
        tokens = _sds((b,), jnp.int32)
    else:
        tokens = _sds((b, cfg.d_model), adt)
    return state, tokens


def params_specs(cfg, key=None):
    from repro.models import init_params

    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_params, cfg), key)


def quantized_params_specs(cfg, key=None):
    """Specs for the PTQTP-quantized serving params (paper technique)."""
    from repro.core.ptqtp import PTQTPConfig
    from repro.core.quantize_model import quantize_tree

    dense = params_specs(cfg, key)

    def q(tree):
        out, _ = quantize_tree(tree, PTQTPConfig())
        return out

    return jax.eval_shape(q, dense)
