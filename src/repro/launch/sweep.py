"""Dry-run sweep driver: every runnable (arch × shape × mesh) cell.

Each cell runs in its own subprocess (isolates XLA state + failures); results
are cached as JSON in benchmarks/results/dryrun/, so re-running the sweep only
fills the gaps. ``--quantized`` adds the PTQTP-serving variants for the
inference shapes (the paper-technique roofline rows).
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
RESULTS_DIR = REPO / "benchmarks" / "results" / "dryrun"


def cells(include_quantized: bool):
    from repro import configs  # safe: no device state touched

    out = []
    for arch, shape in configs.runnable_cells():
        for mesh in ("single", "multi"):
            out.append((arch, shape, mesh, False))
        if include_quantized and shape in ("prefill_32k", "decode_32k",
                                           "long_500k"):
            out.append((arch, shape, "single", True))
    return out


def tag_of(arch, shape, mesh, quantized):
    return f"{arch}__{shape}__{mesh}" + ("__q" if quantized else "")


def run_one(arch, shape, mesh, quantized, timeout_s=3600):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh]
    if quantized:
        cmd.append("--quantized")
    t0 = time.time()
    proc = subprocess.run(
        cmd, cwd=str(REPO), capture_output=True, text=True, timeout=timeout_s,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
    )
    dt = time.time() - t0
    return proc.returncode, dt, proc.stdout[-2000:], proc.stderr[-4000:]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quantized", action="store_true",
                    help="also run PTQTP-quantized inference cells")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args(argv)

    todo = cells(args.quantized)
    if args.only_arch:
        todo = [c for c in todo if c[0] == args.only_arch]
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    failures = []
    for arch, shape, mesh, q in todo:
        tag = tag_of(arch, shape, mesh, q)
        out = RESULTS_DIR / f"{tag}.json"
        if out.exists() and not args.force:
            n_skip += 1
            continue
        print(f"[sweep] {tag} ...", flush=True)
        try:
            rc, dt, so, se = run_one(arch, shape, mesh, q)
        except subprocess.TimeoutExpired:
            rc, dt, so, se = -9, float("nan"), "", "TIMEOUT"
        if rc == 0 and out.exists():
            n_ok += 1
            print(f"[sweep] {tag} OK ({dt:.0f}s)", flush=True)
        else:
            n_fail += 1
            failures.append(tag)
            print(f"[sweep] {tag} FAILED rc={rc}\n{se}", flush=True)
    print(f"[sweep] done: ok={n_ok} cached={n_skip} failed={n_fail}")
    if failures:
        print("[sweep] failures:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
