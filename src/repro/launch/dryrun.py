import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is locked above) --------
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.configs.base import SHAPES                        # noqa: E402
from repro.launch import specs as specs_mod                  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import decode_step, prefill                # noqa: E402
from repro.optim.adamw import AdamW                          # noqa: E402
from repro.roofline import analysis as roofline              # noqa: E402
from repro.sharding import partition as part                 # noqa: E402
from repro.sharding.api import activation_sharding           # noqa: E402
from repro.training.train_step import make_train_step        # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _named_rules(mesh, mode):
    rules = part.activation_rules(mesh, mode=mode)
    return {k: (NamedSharding(mesh, v) if v is not None else None)
            for k, v in rules.items()}


def _effective_microbatches(cfg, batch: int, dp_size: int) -> int:
    m = max(1, cfg.microbatches)
    while m > 1 and not (batch % m == 0 and (batch // m) % dp_size == 0):
        m -= 1
    return m


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               quantized: bool = False, donate: bool = True,
               cfg_override=None, policy: str = "tp", kv8: bool = False):
    """Lower + compile one (arch × shape × mesh) cell; return artifacts."""
    cfg = cfg_override if cfg_override is not None \
        else configs.get_config(arch)
    if kv8:
        cfg = cfg.scaled(kv_cache_dtype="int8")
    info = SHAPES[shape_name]
    with part.parallelism_policy(policy):
        return _lower_cell_inner(arch, shape_name, cfg, info,
                                 multi_pod=multi_pod, quantized=quantized,
                                 donate=donate)


def _lower_cell_inner(arch, shape_name, cfg, info, *, multi_pod, quantized,
                      donate):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    dp = part._axis_size(mesh, part.dp_axes(mesh))
    kind = info["kind"]

    if quantized:
        params_s = specs_mod.quantized_params_specs(cfg)
    else:
        params_s = specs_mod.params_specs(cfg)
    params_p = part.param_pspecs(params_s, mesh)
    params_sh = part.named(params_p, mesh)

    if kind == "train":
        m_eff = _effective_microbatches(cfg, info["global_batch"], dp)
        if m_eff != cfg.microbatches:
            cfg = cfg.scaled(microbatches=m_eff)
        opt = AdamW(lr=3e-4, moment_dtype=cfg.optimizer_dtype)
        opt_s = jax.eval_shape(opt.init, params_s)
        state_s = {"params": params_s, "opt": opt_s,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_sh = {"m": params_sh, "v": params_sh,
                  "count": NamedSharding(mesh, P())}
        state_sh = {"params": params_sh, "opt": opt_sh,
                    "step": NamedSharding(mesh, P())}
        batch_s = specs_mod.batch_specs(cfg, shape_name)
        batch_sh = part.named(part.batch_pspecs(batch_s, mesh), mesh)
        fn = make_train_step(cfg, opt)
        metrics_sh = {"loss": NamedSharding(mesh, P())}
        with mesh, activation_sharding(_named_rules(mesh, "train")):
            jitted = jax.jit(
                fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_s, batch_s)
    elif kind == "prefill":
        batch_s = specs_mod.batch_specs(cfg, shape_name)
        batch_sh = part.named(part.batch_pspecs(batch_s, mesh), mesh)
        seq = info["seq_len"]
        state_out_s = jax.eval_shape(
            lambda: None) if False else None  # structure from prefill itself
        def fn(params, batch):
            return prefill(params, cfg, batch, capacity=seq)
        # output shardings: logits + decode-state rules
        import functools
        from repro.models import init_decode_state
        b = info["global_batch"]
        st_s = jax.eval_shape(lambda: init_decode_state(cfg, b, seq))
        st_sh = part.named(
            part.state_pspecs(st_s, mesh, sequence_sharded=False), mesh)
        logits_sh = NamedSharding(mesh, P(part.dp_axes(mesh), "model"))
        with mesh, activation_sharding(_named_rules(mesh, "prefill")):
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                             out_shardings=(logits_sh, st_sh))
            lowered = jitted.lower(params_s, batch_s)
    else:  # decode
        seq_sharded = shape_name == "long_500k"
        state_s, tok_s = specs_mod.decode_state_specs(cfg, shape_name)
        state_sh = part.named(
            part.state_pspecs(state_s, mesh, sequence_sharded=seq_sharded),
            mesh)
        dp_ax = part.dp_axes(mesh)
        b = info["global_batch"]
        tok_spec = ((part._maybe(mesh, dp_ax, b),) +
                    (None,) * (len(tok_s.shape) - 1))
        tok_sh = NamedSharding(mesh, P(*tok_spec))
        logits_sh = NamedSharding(
            mesh, P(part._maybe(mesh, dp_ax, b), "model"))
        mode = "decode_long" if seq_sharded else "decode"

        def fn(params, state, tokens):
            return decode_step(params, cfg, state, tokens)

        with mesh, activation_sharding(_named_rules(mesh, mode)):
            jitted = jax.jit(
                fn, in_shardings=(params_sh, state_sh, tok_sh),
                out_shardings=(logits_sh, state_sh),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_s, state_s, tok_s)

    compiled = lowered.compile()
    extras = {"dequant_temp_bytes_per_chip":
              _dequant_temp_bytes(params_s, params_sh) if quantized else 0.0}
    if kind == "decode" and cfg.kv_cache_dtype == "int8":
        extras["cache_dequant_bytes_per_chip"] = _cache_dequant_bytes(
            state_s, state_sh)
    return cfg, mesh, lowered, compiled, extras


def _dequant_temp_bytes(params_s, params_sh) -> float:
    """Per-chip HBM traffic of the XLA grouped backend's unpack temps, which
    the Pallas ternary_matmul kernel eliminates (hillclimb iteration 4).

    The grouped path materializes both trit-planes as bf16 before the dot:
    per plane shard, 4 trits/packed-byte × 2 B × (write + read) = 16× the
    packed shard bytes. The Pallas kernel (kernels/ternary_matmul, validated
    vs the jnp oracle) reads the PACKED bytes into VMEM and unpacks
    in-register, so its HBM traffic excludes these temps entirely.
    """
    import numpy as _np

    from repro.core.quantize_model import QuantizedKernel as _QK

    total = 0.0

    def walk(spec_node, sh_node):
        nonlocal total
        if isinstance(spec_node, _QK):
            for buf, sh in ((spec_node.t1p, sh_node.t1p),
                            (spec_node.t2p, sh_node.t2p)):
                shard = sh.shard_shape(buf.shape) if sh is not None \
                    else buf.shape
                packed_bytes = float(_np.prod(shard))  # uint8
                total += 16.0 * packed_bytes
            return
        if isinstance(spec_node, dict):
            for k in spec_node:
                walk(spec_node[k], sh_node[k])

    walk(params_s, params_sh)
    return total


def _cache_dequant_bytes(state_s, state_sh) -> float:
    """Per-chip traffic of int8-KV dequant temps (4 B per cached element:
    bf16 write + read), which a fused int8 decode-attention kernel removes
    (§Perf it. 5) — same accounting pattern as _dequant_temp_bytes."""
    import numpy as _np

    total = 0.0

    def walk(spec_node, sh_node, path=""):
        nonlocal total
        if isinstance(spec_node, dict):
            for k in spec_node:
                walk(spec_node[k], sh_node[k], f"{path}/{k}")
            return
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v") and spec_node.dtype == jnp.int8:
            shard = sh_node.shard_shape(spec_node.shape) \
                if sh_node is not None else spec_node.shape
            total += 4.0 * float(_np.prod(shard))

    walk(state_s, state_sh)
    return total


def choose_policy(arch: str, shape_name: str, multi_pod: bool = False) -> str:
    """Arch-aware parallelism (hillclimb it. 2): pick fsdp_all for a train
    cell when FSDP's param-all-gather traffic undercuts TP's per-layer
    activation all-reduces.

    Napkin model (per chip per step, bf16):
      TP    ≈ 6 collectives/layer × tokens_per_chip × d_model × 2 B
              (fwd + remat-recompute + bwd-dx, attn-out + mlp-out each)
      FSDP  ≈ 4 B × total_params — measured (EXPERIMENTS §Perf it. 2b):
              XLA CSEs the param all-gathers across fwd/remat/bwd, so the
              realized cost is ~2 bf16 traversals (gather + grad
              reduce-scatter), not the naive 4 traversals
    """
    cfg = configs.get_config(arch)
    info = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    if info["kind"] != "train" or info["global_batch"] % n_chips != 0:
        return "tp"
    total, _ = cfg.param_counts()
    dp_under_tp = n_chips // 16
    tokens_per_chip = info["global_batch"] * info["seq_len"] / dp_under_tp
    tp_bytes = 6 * cfg.n_layers * tokens_per_chip * cfg.d_model * 2
    fsdp_bytes = 4 * total
    return "fsdp_all" if fsdp_bytes < tp_bytes else "tp"


def _bf16_promo(cfg) -> float:
    """The CPU backend promotes bf16 compute to f32 (verified on a bare bf16
    dot: internal buffers + collectives appear as f32). Interface args/outputs
    keep bf16, but temps and collective payloads double. For bf16-activation
    models we therefore scale temp-traffic and collective bytes by 0.5 to
    recover the TPU-dtype numbers (EXPERIMENTS.md §Perf iteration 0)."""
    return 0.5 if cfg.activation_dtype == "bfloat16" else 1.0


def _traffic_bytes(compiled, promo: float = 1.0):
    """(traffic, interface) HBM-byte proxies.

    traffic   = args + outputs + 2×temps (each temp written once + read once;
                temps scaled by the bf16-promotion factor). The roofline
                memory term. Per-op operand sums ("bytes accessed") count
                every fusion-internal edge — 10-30× pessimistic vs a fusing
                TPU backend — so we use this allocation proxy (both reported).
    interface = args + outputs only: the PERFECT-FUSION streaming floor —
                what hand-written kernels (Pallas ternary matmul, fused
                int8-KV decode attention) approach, with all temps in VMEM.
    """
    try:
        mem = compiled.memory_analysis()
        args = float(getattr(mem, "argument_size_in_bytes", 0) or 0)
        outs = float(getattr(mem, "output_size_in_bytes", 0) or 0)
        temps = float(getattr(mem, "temp_size_in_bytes", 0) or 0)
        return args + outs + 2.0 * promo * temps, args + outs
    except Exception:  # noqa: BLE001
        return 0.0, 0.0


def _cost_analysis(compiled):
    """compiled.cost_analysis() as a flat dict — newer jax returns a
    one-element list of per-computation dicts, older jax the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cell_costs(compiled, promo: float = 1.0):
    """(flops, op-bytes, (traffic, interface)-bytes, per-op coll bytes)."""
    cost = _cost_analysis(compiled)
    coll = roofline.parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            _traffic_bytes(compiled, promo),
            {k: v * promo for k, v in coll.by_op.items()})


def scan_corrected_costs(arch, shape_name, *, multi_pod, quantized,
                         policy: str = "tp", kv8: bool = False):
    """Exact per-step costs, correcting XLA's count-scan-body-once bias.

    cost_analysis() counts a ``lax.scan`` body exactly once regardless of
    trip count (verified empirically — see EXPERIMENTS.md §Perf iteration 0),
    so deep scanned models under-report FLOPs/bytes/collectives by ~n_periods.
    We lower two small UNROLLED variants (k=1 and k=2 periods, microbatches=1)
    with identical prefix/remainder/embed/head structure:

        body = cost(k=2) - cost(k=1);  true = cost(k=1) + (N-1) * body
    """
    cfg = configs.get_config(arch)
    if cfg.n_periods <= 1 and cfg.microbatches <= 1:
        return None  # nothing to correct

    promo = _bf16_promo(cfg)

    def variant(k):
        n_layers = (len(cfg.prefix_pattern) + k * cfg.period
                    + len(cfg.remainder_pattern))
        vcfg = cfg.scaled(n_layers=n_layers, scan_layers=False,
                          microbatches=1,
                          **({"kv_cache_dtype": "int8"} if kv8 else {}))
        _, _, _, compiled, _ = lower_cell(
            arch, shape_name, multi_pod=multi_pod, quantized=quantized,
            cfg_override=vcfg, policy=policy)
        return _cell_costs(compiled, promo)

    f1, b1, (t1, i1), c1 = variant(1)
    f2, b2, (t2, i2), c2 = variant(2)
    n = cfg.n_periods
    flops = f1 + (n - 1) * (f2 - f1)
    nbytes = b1 + (n - 1) * (b2 - b1)
    traffic = t1 + (n - 1) * (t2 - t1)
    interface = i1 + (n - 1) * (i2 - i1)
    coll = {k: c1[k] + (n - 1) * (c2[k] - c1[k]) for k in c1}
    return {"flops": flops, "bytes": nbytes, "traffic": traffic,
            "interface": interface, "collectives": coll,
            "variant1": {"flops": f1, "bytes": b1, "traffic": t1,
                         "collectives": c1},
            "variant2": {"flops": f2, "bytes": b2, "traffic": t2,
                         "collectives": c2}}


def analyze(arch, shape_name, cfg, mesh, lowered, compiled, *, quantized,
            lower_s, compile_s, corrected=None, extras=None):
    info = SHAPES[shape_name]
    n_chips = mesh.size
    cost = _cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo)

    if corrected is not None:  # scan-corrected exact costs (see §Perf it. 0)
        flops_dev = corrected["flops"]
        bytes_dev = corrected["bytes"]
        traffic_dev = corrected["traffic"]
        interface_dev = corrected["interface"]
        coll_dev = float(sum(corrected["collectives"].values()))
        coll_by_op = corrected["collectives"]
    else:
        promo = _bf16_promo(cfg)
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        traffic_dev, interface_dev = _traffic_bytes(compiled, promo)
        coll_dev = float(coll.total_bytes) * promo
        coll_by_op = {k: v * promo for k, v in coll.by_op.items()}
    # memory term = allocation-traffic proxy; operand-sum kept for reference
    terms = roofline.roofline_terms(flops_dev, traffic_dev, coll_dev)
    terms["memory_opsum_s"] = bytes_dev / roofline.HBM_BW
    # fused-kernel memory floor (it. 4/5): perfect-fusion streaming bound —
    # every buffer crosses HBM exactly once (args + outputs; temps in VMEM).
    # The Pallas ternary matmul / a fused int8-KV decode-attention kernel
    # approach this bound; the XLA grouped path pays the dequant temps.
    fused = roofline.roofline_terms(flops_dev, interface_dev, coll_dev)
    terms["memory_fused_s"] = fused["memory_s"]
    terms["dominant_fused"] = fused["dominant"]
    terms["step_lower_bound_fused_s"] = fused["step_lower_bound_s"]
    mf = roofline.model_flops(cfg, info, train=(info["kind"] == "train"))

    result = {
        "arch": arch,
        "shape": shape_name,
        "policy": part.current_policy(),
        "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "quantized": quantized,
        "kind": info["kind"],
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "corrected": corrected is not None,
        "flops_per_chip": flops_dev,
        "bytes_per_chip": bytes_dev,
        "traffic_bytes_per_chip": traffic_dev,
        "collective_bytes_per_chip": coll_dev,
        "memory_analysis": mem_d,
        "collectives": {"total_bytes": coll_dev, "by_op": coll_by_op,
                        "raw_scanned": coll.to_dict()},
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev else None,
        "lower_s": lower_s,
        "compile_s": compile_s,
        "hlo_bytes": len(hlo),
    }
    return result


def run_cell(arch, shape_name, mesh_kind, quantized, out_dir: Path,
             policy: str = "auto", kv8: bool = False):
    t0 = time.time()
    multi = mesh_kind == "multi"
    if policy == "auto":
        policy = choose_policy(arch, shape_name, multi_pod=multi)
    t_lower0 = time.time()
    cfg, mesh, lowered, compiled, extras = lower_cell(
        arch, shape_name, multi_pod=multi, quantized=quantized, policy=policy,
        kv8=kv8)
    t_done = time.time()
    corrected = scan_corrected_costs(arch, shape_name, multi_pod=multi,
                                     quantized=quantized, policy=policy,
                                     kv8=kv8)
    res = analyze(arch, shape_name, cfg, mesh, lowered, compiled,
                  quantized=quantized, lower_s=t_done - t_lower0,
                  compile_s=t_done - t_lower0, corrected=corrected,
                  extras=extras)
    mem = res["memory_analysis"]
    print(f"memory_analysis: {mem}")
    print(f"cost_analysis: flops={res['cost_analysis'].get('flops')} "
          f"bytes={res['cost_analysis'].get('bytes accessed')}")
    print(f"collectives: {res['collectives']['by_op']}")
    print(f"roofline: {res['roofline']}")
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = (f"{arch}__{shape_name}__{mesh_kind}" + ("__q" if quantized else "")
           + ("__kv8" if kv8 else ""))
    with open(out_dir / f"{tag}.json", "w") as f:
        json.dump(res, f, indent=1)
    print(f"[dryrun] {tag} OK in {time.time() - t0:.1f}s "
          f"(dominant={res['roofline']['dominant']})")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--quantized", action="store_true",
                    help="serve with PTQTP-quantized weights (paper path)")
    ap.add_argument("--policy", choices=("auto", "tp", "fsdp_all"),
                    default="tp", help="parallelism policy (§Perf it. 2)")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (§Perf it. 5, beyond-paper)")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args(argv)
    run_cell(args.arch, args.shape, args.mesh, args.quantized,
             Path(args.out), policy=args.policy, kv8=args.kv8)


if __name__ == "__main__":
    main()
