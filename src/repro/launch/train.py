"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real training (smoke-scale by default — this container is CPU-only) with
the full production substrate: sharded deterministic data, jit'd microbatched
train step, atomic checkpointing with resume, preemption handling, heartbeats.
``--mesh single|multi`` lowers onto the production mesh instead (dry-run-style
execution is not possible on one CPU device; use launch/dryrun.py for that).
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced smoke config (default on CPU)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--run-dir", default=None)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if cfg.embed_inputs:
        # byte tokenizer vocab (259) padded to the smoke vocab if larger
        pass
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=max(args.steps // 10, 1),
                                   total=args.steps))
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.batch,
                      seed=args.seed)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval, run_dir=args.run_dir,
        grad_compress=args.grad_compress, seed=args.seed)
    trainer = Trainer(cfg, opt, dcfg, tcfg)
    state = trainer.fit()
    last = trainer.history[-1]["loss"] if trainer.history else float("nan")
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    print(f"[train] done: step {int(state['step'])} "
          f"loss {first:.4f} -> {last:.4f}")
    return state, trainer


if __name__ == "__main__":
    main()
