"""Offline quantization CLI: FP weights → trit-plane artifact, streamed.

``python -m repro.launch.quantize --arch qwen2-1.5b --out artifacts/qwen``

The production half of "quantize once, serve many": walk the params tree one
kernel at a time (peak incremental host memory O(largest kernel)), append
packed trit-planes to the artifact shards, and commit each tensor atomically
— an interrupted run resumes from the staging manifest, skipping everything
already committed. Serve from the result with
``python -m repro.launch.serve --artifact <out>`` (no FP weights, no
re-quantization at boot).

Weight sources: ``--seed`` initialization (smoke/demo) or
``--from-checkpoint DIR`` (a ``runtime/checkpoint.py`` training checkpoint,
streamed lazily out of the npz so the FP tree is never fully materialized).
"""

from __future__ import annotations

import argparse
import time

from repro import configs
from repro.artifacts import (iter_checkpoint_leaves, verify_artifact,
                             write_artifact)
from repro.core.ptqtp import PTQTPConfig


def _progress_printer(every: int = 1):
    state = {"quantized": 0, "skipped": 0, "fp": 0}

    def progress(ev):
        state[{"quantize": "quantized", "skip": "skipped"}.get(
            ev["action"], "fp")] += 1
        if ev["action"] == "quantize":
            err = (ev.get("error") or {}).get("rel_fro_error")
            err_s = f" err={err:.4f}" if err is not None else ""
            if state["quantized"] % every == 0:
                print(f"[quantize] #{ev['index']:>3} {ev['path']} "
                      f"shape={ev['shape']}{err_s} "
                      f"({ev['elapsed']:.1f}s)", flush=True)
        elif ev["action"] == "skip" and state["skipped"] == 1:
            print("[quantize] resuming: skipping tensors already committed "
                  "in the staging manifest", flush=True)

    progress.state = state
    return progress


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--out", required=True, help="artifact directory to write")
    ap.add_argument("--config", choices=("smoke", "full"), default="smoke",
                    help="model size: smoke (default) or the paper-scale "
                         "config (needs the weights to exist!)")
    ap.add_argument("--from-checkpoint", default=None, metavar="DIR",
                    help="stream FP weights out of a training checkpoint "
                         "instead of --seed initialization")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--group-size", type=int, default=0,
                    help="PTQTP group size G (0 → min(128, d_model))")
    ap.add_argument("--t-max", type=int, default=20)
    ap.add_argument("--commit-every", type=int, default=None, metavar="N",
                    help="fsync group-commit size: make tensors durable "
                         "every N commits (1 = per-tensor, the slowest but "
                         "finest-grained resume; default 8)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore any staging manifest and restart")
    ap.add_argument("--overwrite", action="store_true",
                    help="replace an existing artifact at --out")
    ap.add_argument("--no-error-stats", action="store_true",
                    help="skip the per-kernel approximation-error pass")
    ap.add_argument("--verify", action="store_true",
                    help="re-checksum the finished artifact")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.config == "smoke"
           else configs.get_config(args.arch))
    gs = args.group_size or min(128, cfg.d_model)
    pcfg = PTQTPConfig(group_size=gs, t_max=args.t_max)

    if args.from_checkpoint:
        params = iter_checkpoint_leaves(args.from_checkpoint)
        src = f"checkpoint {args.from_checkpoint}"
    else:
        import jax

        from repro.models import init_params

        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        src = f"seed {args.seed}"

    print(f"[quantize] {args.arch} ({args.config}) from {src} "
          f"→ {args.out}  G={gs} t_max={args.t_max}", flush=True)
    progress = _progress_printer()
    t0 = time.time()
    out = write_artifact(
        args.out, arch=args.arch, model_cfg=cfg, ptqtp_cfg=pcfg,
        params=params, compute_error=not args.no_error_stats,
        progress=progress, resume=not args.no_resume,
        overwrite=args.overwrite, commit_every=args.commit_every)
    dt = time.time() - t0

    from repro.artifacts import read_manifest

    stats = read_manifest(out)["stats"]
    st = progress.state
    print(f"[quantize] done in {dt:.1f}s: {st['quantized']} kernels "
          f"quantized, {st['fp']} FP leaves, {st['skipped']} resumed; "
          f"{stats['total_bytes'] / 1e6:.2f} MB on disk "
          f"({stats.get('bytes_per_weight', float('nan')):.4f} B/weight, "
          f"{stats['source_fp16_bytes'] / max(stats['quantized_bytes'], 1):.2f}x "
          f"vs fp16)", flush=True)
    if args.verify:
        verify_artifact(out)
        print("[quantize] verify: all checksums OK", flush=True)
    return out


if __name__ == "__main__":
    main()
