"""Serving launcher: quantize with PTQTP (or boot a prebuilt artifact), then
serve batched requests through the v1 request API.

``python -m repro.launch.serve --arch qwen2-1.5b --requests 8``
``python -m repro.launch.serve --artifact artifacts/qwen --temperature 0.8``

Pipeline: init (or load) weights → PTQTP-quantize every linear (the paper's
single-pass, calibration-free recipe) → continuous-batching engine drives
bucketed/chunked prefill + fused decode with the multiplication-free ternary
representation. Requests go through ``submit(prompt, SamplingParams(...))``
→ ``RequestHandle`` (the Serving API v1 surface — per-request seed, top-k/
top-p, stop ids, streaming, cancellation); ``--stream`` consumes the first
request token by token through ``handle.tokens()`` to demonstrate the
streaming path. Prompts longer than ``--capacity`` are clipped at admission
— the handle's ``truncated`` flag surfaces it and this launcher warns
instead of dropping tokens invisibly. ``--artifact PATH`` replaces the
first two stages with a memory-mapped load of a ``repro.launch.quantize``
artifact — the server never touches FP weights and pays no quantization at
boot (the "quantize once, serve many" deployment path; the startup summary
breaks the boot down per phase so the win is visible); ``--verify-artifact
sizes`` stat-checks shard lengths at boot and ``--verify-artifact`` (or
``=full``) re-checksums every buffer. ``--scheduler serial`` selects the
PR-1 serial-admit baseline (one jit per prompt length) for A/B comparison.

Robustness knobs (v1.1): ``--deadline`` / ``--ttft-deadline`` give every
request a wall budget (expired requests retire with finish_reason
``"timeout"``); ``--max-queue`` / ``--max-resident-tokens`` bound admission
with ``--admission-policy`` choosing shed-on-submit (``reject``, the
default) vs progress-coupled blocking (``block``). The final line prints
``engine.health().summary()`` — the same one-line snapshot a monitor
scrapes.

Paged KV (v1.2): ``--kv-layout paged`` serves from fixed-size physical KV
pages (``--page-size``, pool ``--max-pages``) with copy-on-write prefix
reuse across requests (``--prefix-cache`` / ``--no-prefix-cache``); the
boot breakdown prints the page pool and the health line gains page-pool
gauges. Outputs are bit-identical to ``--kv-layout ring``.

Observability (v1.3): ``--trace-out trace.json`` records the per-request
lifecycle + per-step engine-phase trace (load it in ui.perfetto.dev or
chrome://tracing; boot phases appear on their own track);
``--metrics-out metrics.prom`` writes the Prometheus text exposition at
shutdown plus a ``.jsonl`` snapshot stream next to it;
``--metrics-interval N`` prints a one-line stats digest (req/s, resident
slots, pages free, p99 TTFT so far) every N engine steps and appends a
registry snapshot to the JSONL stream. The shutdown summary prints a
per-request latency table (queue wait, TTFT, total) and the non-zero
registry metrics. All of it is zero-perturbation: tokens are
bit-identical with tracing on, off, or unconfigured.

HTTP frontend (v1.4): ``--http HOST:PORT`` serves network traffic
instead of the built-in prompt list — one ``EngineDriver`` thread owns
the engine, the asyncio frontend exposes ``POST /v1/completions`` (SSE
streaming, cancel-on-disconnect), ``GET /healthz``, and ``GET
/metrics``, and admission runs deficit-weighted round-robin across
tenants (``--tenant-quantum`` / ``--tenant-weights`` /
``--max-pending`` / ``--tenant-max-resident-tokens``). Either mode
shuts down gracefully on SIGINT/SIGTERM: stop admitting, finish (or
deadline-out) residents, flush ``--trace-out`` / ``--metrics-out``, and
print the drain tables; a second signal force-quits.
"""

from __future__ import annotations

import argparse
import contextlib
import os
import signal
import threading
import time
from pathlib import Path

import jax

from repro import configs
from repro.artifacts import load_artifact, load_model_config
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_params
from repro.serving import (EngineConfig, SamplingParams, SerialAdmitEngine,
                           ServingEngine)
from repro.serving.observability import TRACK_BOOT, Observability

PROMPTS = [
    "the model computes two trit planes",
    "count 5 6 7",
    "slot 42 holds 7 ;",
    "12 plus 30 equals",
]


@contextlib.contextmanager
def _boot_phase(obs, boot, name, **span_args):
    """Time one boot phase into the printed breakdown dict *and* record it
    as a span on the trace's boot track (when tracing is on)."""
    t0 = time.time()
    with obs.span(name, track=TRACK_BOOT, cat="boot", args=span_args or None):
        yield
    boot[name] = time.time() - t0


def _install_drain_signals(on_signal):
    """SIGINT/SIGTERM → graceful drain (``on_signal()``); a second signal
    force-quits with rc ``128+signum`` — distinct from the graceful
    drain's 0, so a process manager can tell a forced kill from a clean
    shutdown. Returns the previous handlers."""
    fired = {"n": 0}

    def _handler(signum, _frame):
        fired["n"] += 1
        if fired["n"] > 1:
            # operator really means it: exit immediately with a nonzero
            # rc wherever the main thread is blocked (drain join, step
            # loop, Event.wait). os._exit skips flushes by design — this
            # is the no-more-waiting path, not a shutdown.
            print(f"[serve] force quit (rc {128 + signum})", flush=True)
            os._exit(128 + signum)
        print(f"[serve] {signal.Signals(signum).name}: draining "
              "(signal again to force quit)", flush=True)
        on_signal()

    return [(s, signal.signal(s, _handler))
            for s in (signal.SIGINT, signal.SIGTERM)]


def _drain_report(results, engine, tok, args, dt, jsonl_f, jsonl_path):
    """The shutdown tables + file flushes, shared by the cooperative and
    HTTP paths (and by signal-triggered drains): per-request latency,
    registry summary, health line, then --metrics-out/--trace-out."""
    reg = engine.obs.registry
    n_tok = sum(len(r.tokens) for r in results)
    stats = engine.compile_stats()
    print(f"[serve] {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, {engine.steps} decode steps, "
          f"{engine.prefill_steps} prefill steps)")
    ttft = sorted(1e3 * r.ttft for r in results if r.t_first)
    if ttft:
        print(f"[serve] ttft ms: median {ttft[len(ttft) // 2]:.1f} "
              f"max {ttft[-1]:.1f}; compiles: {stats['n_prefill_compiles']} "
              f"prefill {sorted(stats['prefill_bucket_lengths'])} "
              f"+ {stats['n_decode_compiles']} decode "
              f"{stats['decode_chunk_lengths']}")
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        print(f"  [{r.uid}] ({r.finish_reason}) -> "
              f"{tok.decode(list(r.tokens))!r}")

    # per-request latency table from the handles' own timestamps (the same
    # numbers the trace spans are built from, so the two always reconcile)
    print("[serve] request latency (ms):")
    print(f"  {'uid':>4} {'reason':>9} {'tok':>4} {'queue':>8} "
          f"{'ttft':>8} {'total':>8}")
    for r in sorted(results, key=lambda r: r.uid):
        total = (r.t_done - r.t_submit) if r.t_done else 0.0
        print(f"  {r.uid:>4} {r.finish_reason:>9} {len(r.tokens):>4} "
              f"{1e3 * r.queue_wait:>8.1f} {1e3 * r.ttft:>8.1f} "
              f"{1e3 * total:>8.1f}")
    print("[serve] metrics summary:")
    for line in reg.summary_table().splitlines():
        print(f"  {line}")
    print(f"[serve] health: {engine.health().summary()}")

    if jsonl_f is not None:
        jsonl_f.write(reg.jsonl_line() + "\n")  # final snapshot
        jsonl_f.close()
        print(f"[serve] metrics snapshots -> {jsonl_path}")
    if args.metrics_out:
        Path(args.metrics_out).write_text(reg.render_prometheus())
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        engine.obs.trace.write(args.trace_out)
        print(f"[serve] trace ({len(engine.obs.trace)} events) -> "
              f"{args.trace_out}")


def _serve_http(engine, tok, args, stop, factory=None):
    """``--http`` mode: hand the engine to an ``EngineDriver`` (the only
    thread that touches it from here on), serve the v1.4 endpoints, block
    until SIGINT/SIGTERM, then drain gracefully and print the same
    shutdown report as the cooperative path. With ``--supervise`` the
    driver lifecycle is wrapped in an ``EngineSupervisor``: engine death
    rebuilds from ``factory`` and replays in-flight requests (v1.5)."""
    from repro.serving.frontend import (EngineDriver, EngineSupervisor,
                                        FairScheduler, ThreadedHttpServer)

    host, _, port = args.http.rpartition(":")
    host = host or "127.0.0.1"
    weights = {}
    for pair in (args.tenant_weights or "").split(","):
        if pair.strip():
            name, _, w = pair.partition("=")
            weights[name.strip()] = float(w or 1.0)

    def make_fair():
        return FairScheduler(
            quantum=args.tenant_quantum, weights=weights,
            max_pending=args.max_pending,
            tenant_max_resident_tokens=args.tenant_max_resident_tokens)

    if args.supervise:
        driver = EngineSupervisor(
            factory, engine=engine, fairness_factory=make_fair,
            max_restarts=args.max_restarts,
            restart_backoff_s=args.restart_backoff,
            watchdog_step_timeout_s=args.watchdog_step_timeout).start()
    else:
        driver = EngineDriver(engine, fairness=make_fair()).start()
    srv = ThreadedHttpServer(driver, host, int(port)).start()
    print(f"[serve] http: listening on http://{srv.host}:{srv.port} "
          "(POST /v1/completions, GET /healthz, GET /metrics"
          f"{'; supervised' if args.supervise else ''})", flush=True)

    t0 = time.time()
    interval = max(args.metrics_interval, 0)
    jsonl_path = (Path(args.metrics_out).with_suffix(".jsonl")
                  if args.metrics_out and interval else None)
    jsonl_f = open(jsonl_path, "w") if jsonl_path else None
    # in HTTP mode --metrics-interval is seconds between digests (there is
    # no cooperative step loop to count); engine reads go through the
    # driver so they can never race a step
    while not stop.wait(interval if interval else None):
        try:
            print(driver.call(lambda eng: _stats_line(eng, t0)), flush=True)
            if jsonl_f is not None:
                jsonl_f.write(driver.call(
                    lambda eng: eng.obs.registry.jsonl_line()) + "\n")
        except (RuntimeError, TimeoutError) as e:
            # supervised mode: the engine may be mid-rebuild (or dead)
            # when the digest tick fires — report, don't crash the loop
            print(f"[serve] stats unavailable: {e}", flush=True)

    srv.stop()                      # stop accepting connections first,
    driver.drain(timeout=300.0)     # then let offered work finish
    driver.close()
    dt = time.time() - t0
    results = driver.results()
    front = driver.stats()
    print(f"[serve] drained: {front['retired']} retired "
          f"({front['frontend_sheds']} frontend sheds, "
          f"{front['frontend_cancelled']} cancelled pre-admission)")
    if args.supervise:
        sup = driver.supervisor_status()
        print(f"[serve] supervisor: generation {sup['generation']}, "
              f"{sup['restarts']} restarts, {sup['replayed']} replayed, "
              f"degraded={sup['degraded']}, "
              f"blacklisted={sup['blacklisted']}")
        engine = driver.engine  # report against the surviving generation
    _drain_report(results, engine, tok, args, dt, jsonl_f, jsonl_path)
    return results


def _stats_line(engine, t_serve0):
    """The periodic one-line digest: everything read off the registry, so
    what the operator watches and what a scraper collects can't diverge."""
    reg = engine.obs.registry
    elapsed = max(time.time() - t_serve0, 1e-9)
    done = reg.value("serving_requests_completed_total")
    line = (f"[serve] step {engine.engine_steps}: "
            f"{done / elapsed:.2f} req/s "
            f"resident={reg.value('serving_resident_slots')} "
            f"queue={reg.value('serving_queue_depth')} "
            f"tokens={reg.value('serving_tokens_generated_total')}")
    if "serving_pages_free" in reg:
        line += f" pages_free={reg.value('serving_pages_free')}"
    ttft = reg.get_histogram("serving_ttft_seconds")
    if ttft.count:
        line += f" p99_ttft={1e3 * ttft.percentile(99):.1f}ms"
    return line


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="boot from a prebuilt trit-plane artifact "
                         "(repro.launch.quantize) instead of init+quantize; "
                         "--arch and the quantize flags are ignored")
    ap.add_argument("--verify-artifact", nargs="?", const="full",
                    choices=("off", "sizes", "full"), default="off",
                    help="artifact integrity check at boot: 'sizes' "
                         "stat-checks shard lengths without reading tensor "
                         "bytes; 'full' (also the value when the flag is "
                         "given bare) re-checksums every buffer")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k truncation (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus sampling mass (1.0 = off)")
    ap.add_argument("--stream", action="store_true",
                    help="consume the first request token-by-token through "
                         "RequestHandle.tokens()")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request end-to-end wall budget in seconds; an "
                         "expired request retires with finish_reason "
                         "'timeout', keeping the tokens it already produced")
    ap.add_argument("--ttft-deadline", type=float, default=None, metavar="S",
                    help="per-request budget for the first token, seconds")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="admission cap on waiting requests (load shedding)")
    ap.add_argument("--max-resident-tokens", type=int, default=None,
                    metavar="N",
                    help="admission cap on the committed token footprint "
                         "(clipped prompt + generation budget) over queued "
                         "plus resident work")
    ap.add_argument("--admission-policy", choices=("reject", "block"),
                    default="reject",
                    help="what submit() does past a cap: 'reject' sheds the "
                         "request (finish_reason 'rejected'), 'block' drives "
                         "engine steps until it fits")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens consumed per slot per engine step")
    ap.add_argument("--scheduler", choices=("bucketed", "serial"),
                    default="bucketed",
                    help="bucketed/chunked admission (default) or the "
                         "serial per-length-jit baseline")
    ap.add_argument("--attn-backend",
                    choices=("auto", "pallas", "stream", "materialized"),
                    default="auto",
                    help="ring-cache attention backend (repro.kernels."
                         "chunk_attention): auto = Pallas on TPU, the "
                         "streaming online-softmax fallback elsewhere; "
                         "materialized = the full-score-block baseline")
    ap.add_argument("--kv-layout", choices=("ring", "paged"), default="ring",
                    help="KV-cache storage: 'ring' = contiguous per-slot "
                         "(baseline + bit-identity oracle); 'paged' = "
                         "fixed-size pages from a shared pool with COW "
                         "prefix reuse (serving contract v1.2)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per physical KV page (paged layout); must "
                         "divide --capacity and align with the attention "
                         "tile selection")
    ap.add_argument("--max-pages", type=int, default=None, metavar="N",
                    help="physical page pool size (paged layout; default "
                         "slots*capacity/page_size = the ring footprint; "
                         "lower overcommits against prefix sharing)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="COW prefix-page reuse across requests (paged "
                         "layout; cache-hit prompt pages skip prefill)")
    ap.add_argument("--warmup", action="store_true",
                    help="precompile every dispatch bucket before serving")
    ap.add_argument("--no-quantize", action="store_true",
                    help="serve FP weights (baseline)")
    ap.add_argument("--t-max", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed; request i samples from its own "
                         "stream seeded seed+i (reproducible regardless "
                         "of co-batched traffic)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace.json of boot "
                         "phases, engine step phases, and per-request "
                         "lifecycle spans at shutdown (zero-perturbation: "
                         "tokens are bit-identical with tracing off)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "metrics registry at shutdown; a .jsonl snapshot "
                         "stream is written next to it when "
                         "--metrics-interval is set")
    ap.add_argument("--metrics-interval", type=int, default=0, metavar="N",
                    help="print a one-line stats digest (and append a "
                         "registry snapshot to the JSONL stream) every N "
                         "engine steps while draining (0 = off); in --http "
                         "mode, every N seconds")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="serve over HTTP instead of the built-in prompt "
                         "list: a single EngineDriver thread owns the "
                         "engine and an asyncio frontend exposes POST "
                         "/v1/completions (SSE streaming), GET /healthz, "
                         "GET /metrics; SIGINT/SIGTERM drains gracefully. "
                         "':0' picks a free port")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the driver in an EngineSupervisor (--http "
                         "mode): engine death or a hung step rebuilds the "
                         "engine (from --artifact when given, else "
                         "re-quantizing in-process) under a new generation "
                         "id and replays in-flight requests bit-identically "
                         "(serving contract v1.5)")
    ap.add_argument("--max-restarts", type=int, default=3, metavar="N",
                    help="crash-loop circuit breaker: N crashes within the "
                         "crash window open the breaker (degraded mode: new "
                         "submits shed with HTTP 503 + Retry-After while "
                         "replayable work finishes)")
    ap.add_argument("--restart-backoff", type=float, default=0.5,
                    metavar="S",
                    help="base seconds between engine death and rebuild; "
                         "doubles per crash in the window")
    ap.add_argument("--watchdog-step-timeout", type=float, default=None,
                    metavar="S",
                    help="flag an engine step running longer than S seconds "
                         "(on the injectable clock) as hung and recover as "
                         "if it crashed (default: watchdog off)")
    ap.add_argument("--tenant-quantum", type=int, default=256, metavar="TOK",
                    help="DRR deficit replenished per tenant per round, in "
                         "committed tokens (--http mode fairness)")
    ap.add_argument("--tenant-weights", default=None, metavar="T=W,...",
                    help="per-tenant DRR weight overrides, e.g. "
                         "'paid=4,free=1' (default weight 1.0)")
    ap.add_argument("--max-pending", type=int, default=None, metavar="N",
                    help="frontend cap on requests waiting in the fair "
                         "queue across all tenants; past it submits shed "
                         "with HTTP 429 (--http mode)")
    ap.add_argument("--tenant-max-resident-tokens", type=int, default=None,
                    metavar="N",
                    help="per-tenant cap on committed tokens concurrently "
                         "inside the engine (--http mode fairness)")
    args = ap.parse_args(argv)

    if args.supervise and args.http is None:
        ap.error("--supervise requires --http (the batch path has no "
                 "driver to supervise)")
    if args.kv_layout == "paged":
        if args.scheduler == "serial":
            ap.error("--kv-layout paged requires the bucketed scheduler "
                     "(the serial baseline prefills into a private ring)")
        if args.capacity % args.page_size:
            ap.error(f"--capacity {args.capacity} must be a whole number "
                     f"of pages (--page-size {args.page_size})")
        # page boundaries must align with the attention tile walk: the
        # paged kernels tile at paged_tile(page_size, L) which divides the
        # page by construction, and bit-identity with the ring baseline
        # additionally wants the ring tile to land on page boundaries
        from repro.kernels.chunk_attention import paged_tile
        from repro.kernels.chunk_attention.ops import _select_tile
        for L in (1, args.prefill_chunk):
            t_ring = _select_tile(args.capacity, L)
            t_paged = paged_tile(args.page_size, L)
            if args.page_size % t_paged:
                ap.error(f"--page-size {args.page_size} admits no clean "
                         f"attention tile at chunk length {L}")
            if t_ring % args.page_size and args.page_size % t_ring:
                ap.error(f"--page-size {args.page_size} does not divide "
                         f"the attention tile selection cleanly (ring "
                         f"tile {t_ring} at chunk length {L}); pick a "
                         "power-of-two page size dividing --capacity")

    # one observability bundle for the whole process: boot spans land on
    # its trace before the engine exists, then bind_engine() (inside the
    # constructor) attaches the registry to the engine's counters
    obs = Observability(trace=args.trace_out is not None)

    boot = {}  # phase -> seconds (startup breakdown)
    t_boot = time.time()
    if args.artifact:
        with _boot_phase(obs, boot, "artifact_load",
                         verify=args.verify_artifact):
            params, manifest = load_artifact(args.artifact,
                                             verify=args.verify_artifact,
                                             obs=obs)
            cfg = load_model_config(manifest)
        if not cfg.embed_inputs:
            ap.error(f"artifact model {cfg.name} has a stub modality "
                     "frontend; token serving applies to LM archs")
        stats = manifest.get("stats", {})
        print(f"[serve] artifact: {manifest['arch']} "
              f"({stats.get('n_quantized', '?')} quantized kernels, "
              f"{stats.get('total_bytes', 0) / 1e6:.2f} MB memory-mapped, "
              f"{boot['artifact_load'] * 1e3:.0f}ms)")
    else:
        cfg = configs.get_smoke_config(args.arch)
        if not cfg.embed_inputs:  # reject stub archs before any boot work
            ap.error(f"{args.arch} has a stub modality frontend; token "
                     "serving applies to LM archs (see launch/dryrun.py "
                     "for its cells)")
        with _boot_phase(obs, boot, "weight_init"):
            params = init_params(cfg, jax.random.PRNGKey(args.seed))
        if not args.no_quantize:
            with _boot_phase(obs, boot, "quantize", t_max=args.t_max):
                gs = min(128, cfg.d_model)
                params, report = quantize_tree(
                    params, PTQTPConfig(group_size=gs, t_max=args.t_max))
            tot = report["__total__"]
            print(f"[serve] PTQTP: {tot['n_quantized']} kernels, "
                  f"{tot['compression']:.2f}x compression, "
                  f"{boot['quantize']:.1f}s")

    tok = ByteTokenizer()
    cls = ServingEngine if args.scheduler == "bucketed" else SerialAdmitEngine
    ecfg = EngineConfig(
        max_slots=args.slots, capacity=args.capacity,
        prefill_chunk=args.prefill_chunk, attn_backend=args.attn_backend,
        max_queue=args.max_queue,
        max_resident_tokens=args.max_resident_tokens,
        admission_policy=args.admission_policy,
        kv_layout=args.kv_layout, page_size=args.page_size,
        max_pages=args.max_pages, prefix_cache=args.prefix_cache)
    with _boot_phase(obs, boot, "engine_init", scheduler=args.scheduler):
        engine = cls(params, cfg, ecfg, observability=obs)

    def engine_factory():
        # supervised recovery rebuild: reload params from the artifact when
        # one was given (the mmap re-open is cheap and sheds any state the
        # dying generation may have corrupted), else reuse the in-memory
        # quantized tree; each generation gets a fresh Observability so
        # bind_engine's single-bind invariant holds
        p = params
        if args.artifact:
            p, _ = load_artifact(args.artifact, verify="off")
        return cls(p, cfg, ecfg, observability=Observability(
            trace=args.trace_out is not None))

    mem = engine.memory_stats()
    if args.kv_layout == "paged":
        print(f"[serve] paged KV: pool {engine.alloc.n_pages} pages x "
              f"{args.page_size} tokens ({mem['kv_pool_bytes'] / 1e6:.2f} MB"
              f", {mem['kv_page_bytes'] / 1e3:.1f} KB/page across layers), "
              f"prefix cache {'on' if engine._prefix_reuse else 'off'}; "
              f"resident KV {mem['kv_resident_bytes'] / 1e6:.2f} MB")
    if mem["preunpack_decode"]:
        # honest resident-state accounting: pre-unpacked decode planes are
        # int8 trits, 4x the packed bytes a weight-only count would suggest
        print(f"[serve] resident planes "
              f"{mem['resident_plane_bytes'] / 1e6:.2f} MB "
              f"({mem['preunpack_ratio']:.1f}x packed "
              f"{mem['packed_plane_bytes'] / 1e6:.2f} MB, preunpack_decode); "
              f"decode state {mem['decode_state_bytes'] / 1e6:.2f} MB; "
              f"total resident {mem['resident_total_bytes'] / 1e6:.2f} MB")
    if args.warmup:
        with _boot_phase(obs, boot, "warmup"):
            engine.warmup()
        print(f"[serve] warmup: {engine.compile_stats()['n_prefill_compiles']}"
              f" prefill programs in {boot['warmup']:.1f}s")
    breakdown = " ".join(f"{k}={v:.2f}s" for k, v in boot.items())
    # graceful drain on SIGINT/SIGTERM, armed before the boot line prints
    # so an operator (or a supervisor) can signal the moment boot is
    # announced: stop admitting (cancel what is still queued), finish or
    # deadline-out residents, then fall through to the normal report +
    # file flushes instead of dying mid-step
    draining = threading.Event()
    _install_drain_signals(draining.set)
    print(f"[serve] boot {time.time() - t_boot:.2f}s ({breakdown})",
          flush=True)

    if args.http is not None:
        return _serve_http(engine, tok, args, stop=draining,
                           factory=engine_factory)

    handles = []
    for i in range(args.requests):
        prompt = tok.encode(PROMPTS[i % len(PROMPTS)], eos=False)
        h = engine.submit(prompt, SamplingParams(
            max_new_tokens=args.max_new, temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p, seed=args.seed + i,
            deadline_s=args.deadline, ttft_deadline_s=args.ttft_deadline))
        if h.done:  # shed at submit (admission-policy reject past a cap)
            print(f"[serve] WARNING: request {h.uid} {h.finish_reason}: "
                  f"{h.error}")
            handles.append(h)
            continue
        if h.truncated:
            print(f"[serve] WARNING: request {h.uid} prompt "
                  f"({len(prompt)} tokens) exceeds --capacity "
                  f"{args.capacity}; only the last {args.capacity} tokens "
                  "will be served (result carries truncated=True)")
        handles.append(h)

    t0 = time.time()
    if args.stream and handles and not handles[0].done:
        # the streaming path: tokens arrive in the engine step that produced
        # them (first one in the step its prefill completed); the rest of
        # the fleet advances through the same steps
        pieces = []
        for t in handles[0].tokens():
            pieces.append(tok.decode([t]))
        print(f"[serve] streamed [{handles[0].uid}] -> {''.join(pieces)!r} "
              f"(ttft {1e3 * (handles[0].t_first - handles[0].t_submit):.1f}"
              "ms)")

    # explicit drive loop (rather than letting result() drive implicitly)
    # so the periodic stats digest and JSONL snapshots can interleave with
    # engine steps at a known cadence
    interval = max(args.metrics_interval, 0)
    jsonl_path = (Path(args.metrics_out).with_suffix(".jsonl")
                  if args.metrics_out and interval else None)
    jsonl_f = open(jsonl_path, "w") if jsonl_path else None
    reg = engine.obs.registry
    while engine.queue or any(s is not None for s in engine.slots):
        if draining.is_set():
            for h in list(engine.queue):  # stop admitting: queued work
                engine.cancel(h)          # never reaches a slot
        engine.step()
        if interval and engine.engine_steps % interval == 0:
            print(_stats_line(engine, t0))
            if jsonl_f is not None:
                jsonl_f.write(reg.jsonl_line() + "\n")
    results = [h.result() for h in handles]  # all retired; just collects
    dt = time.time() - t0
    if draining.is_set():
        n_cancelled = sum(r.finish_reason == "cancelled" for r in results)
        print(f"[serve] drained: {len(results) - n_cancelled} finished, "
              f"{n_cancelled} cancelled in queue")
    _drain_report(results, engine, tok, args, dt, jsonl_f, jsonl_path)
    return results


if __name__ == "__main__":
    main()
