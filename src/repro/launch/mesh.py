"""Production meshes. A FUNCTION (not a module constant) so importing this
module never touches jax device state (required by the dry-run contract).
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_mesh(shape, axes):
    """Elastic helper: arbitrary mesh over a prefix of available devices."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
