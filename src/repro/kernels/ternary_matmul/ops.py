"""Jitted public wrapper for the PTQTP ternary matmul.

Backends:
  * ``auto``    — platform-aware selection (the default): the Pallas hand
                  kernel compiled on TPU, the XLA ``grouped`` path elsewhere
                  (Pallas cannot lower for the CPU host platform; interpret
                  mode is for validation only, never for serving).
  * ``pallas``  — the fused TPU kernels.  Decode batches (m < 128) take the
                  small-m fast path (`ternary_matvec_pallas`): no padding of
                  m to MXU tiles, both trit-planes fused into a single MXU
                  pass per k step, VMEM scratch accumulation.  Larger m uses
                  the 128-aligned tile kernel.
  * ``grouped`` — XLA path over *packed* planes: unpack + grouped einsum.
                  This is what the multi-pod dry-run lowers, and is what XLA
                  itself would fuse on TPU absent the hand kernel.
  * ``ref``     — full-dequant oracle (testing only).

The grouped einsum applies α to per-group partial sums, never materializing
the dequantized Ŵ at matmul precision for the whole matrix at once:

  y[b, n] = Σ_g α¹[n,g]·(Σ_{j∈g} x[b,j]·T¹[n,j]) + α²[...]·(...)

Tile selection is shape-cached (`_select_tiles`): block sizes are pure
functions of (m, n) and the per-shape answer is memoized so the dispatch
adds no per-call Python cost on the decode hot path.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.packing import pack_trits, unpack_trits
from repro.kernels.ternary_matmul import ref as _ref
from repro.kernels.ternary_matmul.kernel import (
    ternary_matmul_pallas,
    ternary_matvec_pallas,
)

DEFAULT_BACKEND = "auto"
# Below this m the batch is decode-shaped: padding to a 128-row MXU tile
# would waste > (1 - m/128) of every pass, so take the matvec fast path.
SMALL_M_THRESHOLD = 128


def resolve_backend(backend: str | None = None, platform: str | None = None) -> str:
    """Map 'auto'/None to the fastest backend for the current platform."""
    if backend in (None, "auto"):
        platform = platform or jax.default_backend()
        return "pallas" if platform == "tpu" else "grouped"
    return backend


@functools.lru_cache(maxsize=None)
def _largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap.

    Fast paths: gcd catches every n with a divisor structure aligned to cap
    (cap itself, and — cap being a power of two — the full 2-adic part of n
    via the n & -n bit trick folded into gcd).  The general case enumerates
    divisor pairs in O(√n) instead of the seed's linear countdown scan.
    Memoized: tile selection asks once per weight shape.
    """
    if n <= cap:
        return n
    g = math.gcd(n, cap)
    if g == cap:
        return cap
    best = g  # gcd(n, pow2-cap) == min(n & -n, cap): the bit-trick lower bound
    i = 1
    while i * i <= n:
        if n % i == 0:
            for d in (i, n // i):
                if best < d <= cap:
                    best = d
        i += 1
    return best


@functools.lru_cache(maxsize=None)
def _select_tiles(m: int, n: int) -> tuple:
    """Per-shape (small_m, block_m, block_n) choice, memoized.

    block_n divides n exactly (Pallas grids need exact tiling on the weight
    axis); block_m is the MXU tile for the large-m kernel, with the residual
    rows handled by padding in the caller.
    """
    small = m < SMALL_M_THRESHOLD
    bm = m if small else 128
    bn = _largest_divisor_at_most(n, 128)
    return small, bm, bn


def _grouped(x, t1p, t2p, alpha, group_size):
    *lead, d = x.shape
    n = t1p.shape[0]
    g = group_size
    ng = d // g
    xf = x.reshape(-1, ng, g)
    if t1p.dtype == jnp.uint8:  # packed: 4 trits / byte
        t1, t2 = unpack_trits(t1p), unpack_trits(t2p)
    else:  # pre-unpacked int8 planes (the decode loop hoists the unpack)
        t1, t2 = t1p, t2p
    t1 = t1.reshape(n, ng, g).astype(x.dtype)
    t2 = t2.reshape(n, ng, g).astype(x.dtype)
    # (B, ng, g) x (n, ng, g) -> (B, ng, n) partial sums per group
    p1 = jnp.einsum("bgk,ngk->bgn", xf, t1, preferred_element_type=jnp.float32)
    p2 = jnp.einsum("bgk,ngk->bgn", xf, t2, preferred_element_type=jnp.float32)
    a = alpha.astype(jnp.float32)
    y = jnp.einsum("bgn,ng->bn", p1, a[..., 0]) + jnp.einsum(
        "bgn,ng->bn", p2, a[..., 1]
    )
    return y.reshape(*lead, n)


def _pallas(x, t1p, t2p, alpha, group_size, interpret):
    *lead, d = x.shape
    x2 = x.reshape(-1, d)
    m = x2.shape[0]
    n = t1p.shape[0]
    small, bm, bn = _select_tiles(m, n)
    if small:
        y = ternary_matvec_pallas(
            x2, t1p, t2p, alpha,
            group_size=group_size, block_n=bn, interpret=interpret,
        )
    else:
        pad = (-m) % bm
        if pad:
            x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        y = ternary_matmul_pallas(
            x2, t1p, t2p, alpha,
            group_size=group_size, block_m=bm, block_n=bn, interpret=interpret,
        )
        if pad:
            y = y[:m]
    return y.reshape(*lead, n)


def ternary_matmul(
    x: jax.Array,
    t1p: jax.Array,
    t2p: jax.Array,
    alpha: jax.Array,
    *,
    group_size: int = 128,
    backend: str = DEFAULT_BACKEND,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """y = x @ Ŵᵀ. x: (..., d); packed planes (n, d//4); alpha (n, d//G, 2).

    ``backend='auto'`` selects Pallas (compiled) on TPU and the grouped XLA
    path elsewhere.  ``interpret=None`` likewise resolves per platform, so an
    explicit ``backend='pallas'`` still validates on CPU via the interpreter.

    Plane dtype doubles as the storage tag: uint8 means packed (4 trits per
    byte, what every backend expects), int8 means raw ±1/0 trits that a
    caller already unpacked (the serving decode loop hoists the unpack out
    of its scan) — only the grouped einsum consumes those directly.
    """
    if t1p.dtype != jnp.uint8:
        # Raw planes: only the grouped einsum consumes them. 'auto' adapts;
        # an explicit ask for another backend is a misconfiguration (e.g.
        # preunpack_decode=True on TPU would silently bypass the hand
        # kernel), so fail loudly instead of overriding the choice.
        if backend not in (None, "auto", "grouped"):
            raise ValueError(
                f"backend {backend!r} requires packed uint8 trit-planes; "
                "pre-unpacked int8 planes are served by the grouped backend")
        backend = "grouped"
    else:
        backend = resolve_backend(backend)
    if backend == "ref":
        y = _ref.ternary_matmul_packed_ref(x, t1p, t2p, alpha, group_size)
    elif backend == "grouped":
        y = _grouped(x, t1p, t2p, alpha, group_size)
    elif backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        y = _pallas(x, t1p, t2p, alpha, group_size, interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y.astype(out_dtype) if out_dtype is not None else y


def quantized_from_dense(w_t: jax.Array, alpha: jax.Array):
    """Pack int8 planes -> uint8 packed buffers. w_t: tuple (t1, t2)."""
    t1, t2 = w_t
    return pack_trits(t1), pack_trits(t2)
