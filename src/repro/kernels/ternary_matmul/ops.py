"""Jitted public wrapper for the PTQTP ternary matmul.

Backends:
  * ``pallas``  — the fused TPU kernel (interpret=True on CPU for validation).
  * ``grouped`` — XLA path over *packed* planes: unpack + grouped einsum.
                  This is what the multi-pod dry-run lowers (Pallas cannot
                  lower for the CPU host platform), and is what XLA itself
                  would fuse on TPU absent the hand kernel.
  * ``ref``     — full-dequant oracle (testing only).

The grouped einsum applies α to per-group partial sums, never materializing
the dequantized Ŵ at matmul precision for the whole matrix at once:

  y[b, n] = Σ_g α¹[n,g]·(Σ_{j∈g} x[b,j]·T¹[n,j]) + α²[...]·(...)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import pack_trits, unpack_trits
from repro.kernels.ternary_matmul import ref as _ref
from repro.kernels.ternary_matmul.kernel import ternary_matmul_pallas

DEFAULT_BACKEND = "grouped"


def _grouped(x, t1p, t2p, alpha, group_size):
    *lead, d = x.shape
    n = t1p.shape[0]
    g = group_size
    ng = d // g
    xf = x.reshape(-1, ng, g)
    t1 = unpack_trits(t1p).reshape(n, ng, g).astype(x.dtype)
    t2 = unpack_trits(t2p).reshape(n, ng, g).astype(x.dtype)
    # (B, ng, g) x (n, ng, g) -> (B, ng, n) partial sums per group
    p1 = jnp.einsum("bgk,ngk->bgn", xf, t1, preferred_element_type=jnp.float32)
    p2 = jnp.einsum("bgk,ngk->bgn", xf, t2, preferred_element_type=jnp.float32)
    a = alpha.astype(jnp.float32)
    y = jnp.einsum("bgn,ng->bn", p1, a[..., 0]) + jnp.einsum(
        "bgn,ng->bn", p2, a[..., 1]
    )
    return y.reshape(*lead, n)


def ternary_matmul(
    x: jax.Array,
    t1p: jax.Array,
    t2p: jax.Array,
    alpha: jax.Array,
    *,
    group_size: int = 128,
    backend: str = DEFAULT_BACKEND,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    """y = x @ Ŵᵀ. x: (..., d); packed planes (n, d//4); alpha (n, d//G, 2)."""
    if backend == "ref":
        y = _ref.ternary_matmul_packed_ref(x, t1p, t2p, alpha, group_size)
    elif backend == "grouped":
        y = _grouped(x, t1p, t2p, alpha, group_size)
    elif backend == "pallas":
        *lead, d = x.shape
        x2 = x.reshape(-1, d)
        m = x2.shape[0]
        n = t1p.shape[0]
        # pad m to a tile multiple
        bm = 128 if m >= 128 else _pow2_at_most(m)
        pad = (-m) % bm
        if pad:
            x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        bn = 128 if n % 128 == 0 else _largest_divisor_at_most(n, 128)
        y = ternary_matmul_pallas(
            x2, t1p, t2p, alpha,
            group_size=group_size, block_m=bm, block_n=bn, interpret=interpret,
        )
        if pad:
            y = y[:m]
        y = y.reshape(*lead, n)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y.astype(out_dtype) if out_dtype is not None else y


def _pow2_at_most(m: int) -> int:
    b = 1
    while b * 2 <= m:
        b *= 2
    return b


def _largest_divisor_at_most(n: int, cap: int) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def quantized_from_dense(w_t: jax.Array, alpha: jax.Array):
    """Pack int8 planes -> uint8 packed buffers. w_t: tuple (t1, t2)."""
    t1, t2 = w_t
    return pack_trits(t1), pack_trits(t2)
