"""Pure-jnp oracle for the fused PTQTP ternary matmul.

Computes  y = x @ Ŵᵀ  with  Ŵ = α¹∘T¹ + α²∘T²  (group-wise α, G columns per
group). This is the semantic ground truth the Pallas kernel and the XLA
grouped path are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import unpack_trits


def dequantize(t1, t2, alpha, group_size: int):
    """Materialize Ŵ (n, d) from int8 planes + (n, d//G, 2) scales."""
    n, d = t1.shape
    g = group_size
    t1 = t1.reshape(n, d // g, g).astype(jnp.float32)
    t2 = t2.reshape(n, d // g, g).astype(jnp.float32)
    a = alpha.astype(jnp.float32)
    return (t1 * a[..., 0:1] + t2 * a[..., 1:2]).reshape(n, d)


def ternary_matmul_ref(x, t1, t2, alpha, group_size: int = 128):
    """Oracle: full dequant + dense matmul.

    Args:
      x:     (..., d) activations.
      t1,t2: (n, d) int8 trit-planes.
      alpha: (n, d // group_size, 2) float scales.
    Returns:
      (..., n) float32.
    """
    w_hat = dequantize(t1, t2, alpha, group_size)
    return jnp.einsum(
        "...d,nd->...n", x.astype(jnp.float32), w_hat, preferred_element_type=jnp.float32
    )


def ternary_matmul_packed_ref(x, t1p, t2p, alpha, group_size: int = 128):
    """Oracle for the packed-input variant (uint8 planes, 4 trits/byte)."""
    t1 = unpack_trits(t1p)
    t2 = unpack_trits(t2p)
    return ternary_matmul_ref(x, t1, t2, alpha, group_size)
