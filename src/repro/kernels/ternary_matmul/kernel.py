"""Pallas TPU kernel: fused unpack + grouped ternary matmul + per-group scale.

TPU adaptation of PTQTP's multiplication-free inference (DESIGN.md §2):
packed 2-bit trit-planes stream HBM→VMEM (0.5 B/weight instead of 2 B),
are unpacked with shifts/masks on the VPU, promoted to the activation dtype
and fed to the MXU in 128-aligned tiles; the per-group α pair scales the
128-wide partial sums before accumulation.

Grid layout: (M // bm, N // bn, D // G)  — the k axis steps one weight group
(G = 128 = MXU tile edge) at a time, so each k step is exactly one scaled
MXU pass per plane:

    acc += (x_g @ T¹_gᵀ) * α¹[:, g]  +  (x_g @ T²_gᵀ) * α²[:, g]

BlockSpecs keep the working set in VMEM:
  x      (bm, G)        activations tile
  t1p/t2p(bn, G // 4)   packed trits (uint8)
  alpha  (bn, 1, 2)     group scales
  out    (bm, bn)       f32 accumulator (revisited across k steps)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_block(packed_i32, bn: int, g: int):
    """(bn, G//4) int32 packed bytes -> (bn, G) f32 trits in {-1,0,1}."""
    fields = [(packed_i32 >> (2 * i)) & 0x3 for i in range(4)]
    # field: 0 -> 0, 1 -> +1, 2 -> -1
    trits = [
        (f == 1).astype(jnp.float32) - (f == 2).astype(jnp.float32) for f in fields
    ]
    stacked = jnp.stack(trits, axis=-1)  # (bn, G//4, 4): trit j = byte j//4 field j%4
    return stacked.reshape(bn, g)


def _ternary_matmul_kernel(x_ref, t1_ref, t2_ref, a_ref, o_ref, *, bm, bn, g,
                           acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(acc_dtype)                      # (bm, G)
    t1 = _unpack_block(t1_ref[...].astype(jnp.int32), bn, g).astype(acc_dtype)
    t2 = _unpack_block(t2_ref[...].astype(jnp.int32), bn, g).astype(acc_dtype)
    a = a_ref[...].astype(acc_dtype)                      # (bn, 1, 2)
    a1 = a[:, 0, 0]                                       # (bn,)
    a2 = a[:, 0, 1]

    p1 = jax.lax.dot_general(
        x, t1, (((1,), (1,)), ((), ())), preferred_element_type=acc_dtype
    )                                                     # (bm, bn)
    p2 = jax.lax.dot_general(
        x, t2, (((1,), (1,)), ((), ())), preferred_element_type=acc_dtype
    )
    o_ref[...] += p1 * a1[None, :] + p2 * a2[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "block_m", "block_n", "interpret"),
)
def ternary_matmul_pallas(
    x: jax.Array,
    t1p: jax.Array,
    t2p: jax.Array,
    alpha: jax.Array,
    *,
    group_size: int = 128,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ Ŵᵀ from packed trit-planes.

    Args:
      x:     (m, d) activations (f32/bf16).
      t1p:   (n, d // 4) uint8 packed plane 1.
      t2p:   (n, d // 4) uint8 packed plane 2.
      alpha: (n, d // group_size, 2) f32.
    Returns:
      (m, n) f32.
    """
    m, d = x.shape
    n = t1p.shape[0]
    g = group_size
    assert d % g == 0, (d, g)
    assert t1p.shape == (n, d // 4)
    assert alpha.shape == (n, d // g, 2)

    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)

    grid = (m // bm, n // bn, d // g)
    kernel = functools.partial(
        _ternary_matmul_kernel, bm=bm, bn=bn, g=g, acc_dtype=jnp.float32
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, g), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, g // 4), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, g // 4), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1, 2), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, t1p, t2p, alpha)
