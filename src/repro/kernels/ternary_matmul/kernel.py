"""Pallas TPU kernels: fused unpack + grouped ternary matmul + per-group scale.

TPU adaptation of PTQTP's multiplication-free inference (DESIGN.md §2):
packed 2-bit trit-planes stream HBM→VMEM (0.5 B/weight instead of 2 B),
are unpacked with shifts/masks on the VPU, promoted to the activation dtype
and fed to the MXU; the per-group α pair scales the partial sums before
accumulation.

Two variants share the unpack helper:

``ternary_matmul_pallas`` — the prefill/training tile kernel.
Grid layout: (M // bm, N // bn, D // G)  — the k axis steps one weight group
(G = 128 = MXU tile edge) at a time, so each k step is exactly one scaled
MXU pass per plane:

    acc += (x_g @ T¹_gᵀ) * α¹[:, g]  +  (x_g @ T²_gᵀ) * α²[:, g]

BlockSpecs keep the working set in VMEM:
  x      (bm, G)        activations tile
  t1p/t2p(bn, G // 4)   packed trits (uint8)
  alpha  (bn, 1, 2)     group scales
  out    (bm, bn)       f32 accumulator (revisited across k steps)

``ternary_matvec_pallas`` — the decode fast path (m < 128).  Decode batches
are a handful of rows, so padding m to a 128-row tile wastes ≥ 96% of every
MXU pass and the two-passes-per-plane schedule doubles the weight traffic's
compute shadow.  The small-m kernel instead:

  * keeps all m rows resident in VMEM for the whole kernel (no m padding,
    no m grid axis);
  * fuses both trit-planes into a *single* MXU pass per k step by
    concatenating T¹/T² along the n axis and folding the α pair into one
    (2·bn,) scale vector:

        p = x_g @ [T¹_g ; T²_g]ᵀ            # one (m, 2·bn) pass
        acc += (p ∘ [α¹ ; α²])[:, :bn] + (p ∘ [α¹ ; α²])[:, bn:]

  * accumulates in a VMEM scratch ref and writes the output block exactly
    once (the tile kernel revisits its HBM-backed output block every k step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_block(packed_i32, bn: int, g: int):
    """(bn, G//4) int32 packed bytes -> (bn, G) f32 trits in {-1,0,1}."""
    fields = [(packed_i32 >> (2 * i)) & 0x3 for i in range(4)]
    # field: 0 -> 0, 1 -> +1, 2 -> -1
    trits = [
        (f == 1).astype(jnp.float32) - (f == 2).astype(jnp.float32) for f in fields
    ]
    stacked = jnp.stack(trits, axis=-1)  # (bn, G//4, 4): trit j = byte j//4 field j%4
    return stacked.reshape(bn, g)


def _ternary_matmul_kernel(x_ref, t1_ref, t2_ref, a_ref, o_ref, *, bm, bn, g,
                           acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(acc_dtype)                      # (bm, G)
    t1 = _unpack_block(t1_ref[...].astype(jnp.int32), bn, g).astype(acc_dtype)
    t2 = _unpack_block(t2_ref[...].astype(jnp.int32), bn, g).astype(acc_dtype)
    a = a_ref[...].astype(acc_dtype)                      # (bn, 1, 2)
    a1 = a[:, 0, 0]                                       # (bn,)
    a2 = a[:, 0, 1]

    p1 = jax.lax.dot_general(
        x, t1, (((1,), (1,)), ((), ())), preferred_element_type=acc_dtype
    )                                                     # (bm, bn)
    p2 = jax.lax.dot_general(
        x, t2, (((1,), (1,)), ((), ())), preferred_element_type=acc_dtype
    )
    o_ref[...] += p1 * a1[None, :] + p2 * a2[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "block_m", "block_n", "interpret"),
)
def ternary_matmul_pallas(
    x: jax.Array,
    t1p: jax.Array,
    t2p: jax.Array,
    alpha: jax.Array,
    *,
    group_size: int = 128,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ Ŵᵀ from packed trit-planes.

    Args:
      x:     (m, d) activations (f32/bf16).
      t1p:   (n, d // 4) uint8 packed plane 1.
      t2p:   (n, d // 4) uint8 packed plane 2.
      alpha: (n, d // group_size, 2) f32.
    Returns:
      (m, n) f32.
    """
    m, d = x.shape
    n = t1p.shape[0]
    g = group_size
    assert d % g == 0, (d, g)
    assert t1p.shape == (n, d // 4)
    assert alpha.shape == (n, d // g, 2)

    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)

    grid = (m // bm, n // bn, d // g)
    kernel = functools.partial(
        _ternary_matmul_kernel, bm=bm, bn=bn, g=g, acc_dtype=jnp.float32
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, g), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, g // 4), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, g // 4), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1, 2), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, t1p, t2p, alpha)


# ---------------------------------------------------------------------------
# decode fast path: small-m fused kernel
# ---------------------------------------------------------------------------

def _ternary_matvec_kernel(x_ref, t1_ref, t2_ref, a_ref, o_ref, acc_ref, *,
                           bn, g):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                       # (m, G)
    t1 = _unpack_block(t1_ref[...].astype(jnp.int32), bn, g)
    t2 = _unpack_block(t2_ref[...].astype(jnp.int32), bn, g)
    tcat = jnp.concatenate([t1, t2], axis=0)                 # (2·bn, G)
    a = a_ref[...].astype(jnp.float32)                       # (bn, 1, 2)
    scale = jnp.concatenate([a[:, 0, 0], a[:, 0, 1]], axis=0)  # (2·bn,)

    # One MXU pass covers both planes; α folds in on the VPU afterwards.
    p = jax.lax.dot_general(
        x, tcat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale[None, :]                                       # (m, 2·bn)
    acc_ref[...] += p[:, :bn] + p[:, bn:]

    @pl.when(k == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("group_size", "block_n", "interpret")
)
def ternary_matvec_pallas(
    x: jax.Array,
    t1p: jax.Array,
    t2p: jax.Array,
    alpha: jax.Array,
    *,
    group_size: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Decode-shape y = x @ Ŵᵀ for small m (no padding of m to MXU tiles).

    Args:
      x:     (m, d) activations, m < 128 (decode batch).
      t1p:   (n, d // 4) uint8 packed plane 1.
      t2p:   (n, d // 4) uint8 packed plane 2.
      alpha: (n, d // group_size, 2) f32.
    Returns:
      (m, n) f32.
    """
    m, d = x.shape
    n = t1p.shape[0]
    g = group_size
    assert d % g == 0, (d, g)
    assert t1p.shape == (n, d // 4)
    assert alpha.shape == (n, d // g, 2)

    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)

    grid = (n // bn, d // g)  # k innermost: the scratch acc stays live per j
    kernel = functools.partial(_ternary_matvec_kernel, bn=bn, g=g)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, g), lambda j, k: (0, k)),
            pl.BlockSpec((bn, g // 4), lambda j, k: (j, k)),
            pl.BlockSpec((bn, g // 4), lambda j, k: (j, k)),
            pl.BlockSpec((bn, 1, 2), lambda j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        interpret=interpret,
    )(x, t1p, t2p, alpha)
