"""Flash chunk-prefill attention over the int8 ring cache.

One backend-dispatched op serves every attention read the serving engine
performs — bucketed chunk prefill, the fused decode loop (the L = 1 case),
and the serial admitter's decode — against (pre-write ring ∪ in-chunk keys)
with **online softmax**: the (L, cap + L) score block is never materialized,
and the int8 ring streams to the compute unit as int8, dequantized per tile
(halving attention weight traffic vs a full f32 dequant of the cache).

Op contract (stable; ``ops.chunk_attention``)
---------------------------------------------
::

  chunk_attention(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale,
                  pos_buf, positions, lengths, *, window=None,
                  backend="auto", tile=None, interpret=None)
      -> (B, L, KV, G, hd) float32

Inputs:
  q:          (B, L, KV, G, hd) rotary-applied queries, grouped per kv head
              (head h = kv * G + g, matching ``models.attention``).
  k_new/v_new:(B, L, KV, hd) the chunk's fresh keys/values (float — scored
              at full activation precision, *before* any cache write).
  k_cache/v_cache: (B, cap, KV, hd) the ring **before** this chunk is
              written — int8 (with per-(slot, kv-head) absmax ``k_scale``/
              ``v_scale`` (B, cap, KV) f32) or float (scales = None).
  pos_buf:    (B, cap) int32 absolute position held by each ring slot
              (-1 = empty).
  positions:  (B, L) int32 absolute position of each chunk query.
  lengths:    (B,) int32 valid token count per row. Rows with length 0 are
              no-ops (their output is unconsumed garbage, finite by
              construction); key j of row r participates iff j < lengths[r].

Masking (the *exact* part of the contract — every backend must agree
bitwise on the visible set; floats may reorder):
  A query at absolute position p sees key at position s iff
  ``0 <= p - s < reach`` where ``reach = min(window or cap, cap)`` —
  i.e. causal, sliding-window-clipped, and never further back than the
  ring can faithfully hold. Ring entries additionally require
  ``pos_buf >= 0``; in-chunk keys additionally require validity
  (j < lengths[r]). This single rule reproduces the write-then-attend
  decode semantics at L = 1 (the entry at distance exactly ``cap`` is the
  one the token's own write evicts, so it is masked rather than read) and
  covers ring wrap and per-row chunk offsets with no special cases.

Backends:
  * ``pallas``       — one grid program per (batch, kv-head); the ring
                       stays int8 in VMEM and is dequantized per ``tile``
                       on the VPU inside an online-softmax ``fori_loop``
                       (validated in interpret mode off-TPU, like
                       ``ternary_matvec_pallas``).
  * ``stream``       — CPU/XLA fallback: a jitted ``fori_loop`` over
                       fixed-size ring tiles (sliced from the cache in
                       place) carrying running (max, sum, acc) state. Peak
                       attention allocation is O(L·tile) per layer instead
                       of O(L·(cap+L)); the scan dequantizes one int8 tile
                       at a time.
  * ``materialized`` — the pre-PR-5 path (full score block + full-ring
                       dequant, one softmax), kept as the measured baseline
                       and the parity oracle (``ref.chunk_attention_ref``).
  * ``auto``         — ``pallas`` on TPU, ``stream`` elsewhere.

``ops.tracked_block_bytes`` gives the analytic peak score-block bytes per
(shape, backend) — what the long-context benchmark and the O(L·tile) test
assert; ``ops.peak_tracked_bytes()`` records the same figure at trace time.

Paged variant (stable; ``ops.chunk_attention_paged``)
-----------------------------------------------------
::

  chunk_attention_paged(q, k_new, v_new, k_pool, k_scale, v_pool, v_scale,
                        pos_pool, table, positions, lengths, *,
                        window=None, backend="auto", tile=None,
                        interpret=None)
      -> (B, L, KV, G, hd) float32

The KV ring virtualized into fixed-size pages: ``k_pool``/``v_pool`` are
(P, page_size, KV, hd) *physical* pages shared by the whole batch (int8
with (P, page_size, KV) scales, or float with scales None), ``pos_pool``
(P, page_size) their per-entry absolute positions, and ``table``
(B, n_pages) int32 maps each row's logical page to a physical one. The op
computes exactly ``chunk_attention`` over the virtual ring
``ring[b, p·ps + o] = pool[table[b, p], o]`` (``ref.gather_pages``) with
capacity ``n_pages · page_size`` — the same mask rule in logical
positions, so prefill, decode (L = 1), ring wrap, and sliding windows are
unchanged. Physical page 0 is the reserved **null page** (pos ≡ -1, never
written): unmapped table entries point at it and gather safely, masked by
the pos >= 0 rule — length-0 rows and partially mapped rings need no
special cases. Backends mirror the contiguous op; ``stream``/``pallas``
walk logical tiles through the table (tile divides page_size, one dynamic
page index per tile — pages are just non-contiguous tiles), and with
matching ``tile`` each backend is bit-identical to its contiguous-ring
counterpart (``materialized`` is gather-then-oracle, bit-identical by
construction). ``ops.paged_tile`` is the paged tile selector.
"""

from repro.kernels.chunk_attention.ops import (
    chunk_attention,
    chunk_attention_paged,
    paged_tile,
    peak_tracked_bytes,
    reset_tracking,
    resolve_chunk_backend,
    tracked_block_bytes,
)
from repro.kernels.chunk_attention.ref import gather_pages

__all__ = [
    "chunk_attention", "chunk_attention_paged", "gather_pages", "paged_tile",
    "resolve_chunk_backend", "tracked_block_bytes",
    "peak_tracked_bytes", "reset_tracking",
]
