"""Public wrapper for flash chunk-prefill attention over the ring cache.

Backends (see the package docstring for the full contract):
  * ``auto``         — ``pallas`` on TPU, ``stream`` elsewhere.
  * ``pallas``       — the fused TPU kernel (interpret mode off-TPU).
  * ``stream``       — XLA fallback: a jitted ``fori_loop`` over
                       fixed-size ring tiles carrying running (max, sum,
                       acc) online-softmax state; peak attention
                       allocation O(L·tile), the ring sliced and
                       dequantized one int8 tile at a time.
  * ``materialized`` — the pre-PR-5 full-block path (``ref.py``), kept as
                       the measured baseline and parity oracle.

Tile selection: one tile is sized so the live score block stays near
``_TILE_ELEMS`` elements per (kv-head, group) — so decode (L = 1) gets a
single full-ring tile (no loop overhead on the hot path) while a 64-token
prefill chunk against a 32k ring walks 128 tiles. Tiles must divide cap
exactly (same rule as the ternary-matmul grid).

``tracked_block_bytes`` / ``peak_tracked_bytes`` expose the analytic score
-block footprint — the number the long-context benchmark reports and the
O(L·tile) test asserts (trace-time recording survives jit caching because
the figure is a pure function of static shapes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.chunk_attention import ref as _ref
from repro.kernels.chunk_attention.kernel import (chunk_attention_paged_pallas,
                                                  chunk_attention_pallas)
from repro.kernels.chunk_attention.ref import NEG_INF, gather_pages, reach_of

DEFAULT_BACKEND = "auto"
# target elements per (G·L, tile) score block — balances scan trip count
# against peak allocation; at L=1 (decode) any cap <= 8192 is one tile.
_TILE_ELEMS = 8192


def resolve_chunk_backend(backend: Optional[str] = None,
                          platform: Optional[str] = None) -> str:
    """Map 'auto'/None to the fastest backend for the current platform."""
    if backend in (None, "auto"):
        platform = platform or jax.default_backend()
        return "pallas" if platform == "tpu" else "stream"
    return backend


@functools.lru_cache(maxsize=None)
def _select_tile(cap: int, L: int) -> int:
    """Largest divisor of cap with L·tile <= _TILE_ELEMS.

    Tiles must divide cap exactly (no padded ring reads). A cap with no
    useful divisor structure (e.g. prime) would degenerate into a
    per-slot scan, so such caps take the whole ring as one tile — correct,
    just without the O(L·tile) bound; engine capacities are powers of two
    in practice.
    """
    target = max(1, _TILE_ELEMS // max(L, 1))
    if cap <= target:
        return cap
    best = 1
    i = 1
    while i * i <= cap:
        if cap % i == 0:
            for d in (i, cap // i):
                if best < d <= target:
                    best = d
        i += 1
    return best if best >= min(target, 64) else cap


@functools.lru_cache(maxsize=None)
def paged_tile(page_size: int, L: int) -> int:
    """Largest divisor of page_size with L·tile <= _TILE_ELEMS.

    Paged tiles must divide the page (one tile never spans two physical
    pages — the gather stays a single dynamic slice), the paged analogue of
    the divide-cap rule above. Page sizes are powers of two in practice, so
    this is page_size itself until L·page_size crosses _TILE_ELEMS.
    """
    target = max(1, _TILE_ELEMS // max(L, 1))
    if page_size <= target:
        return page_size
    best = 1
    i = 1
    while i * i <= page_size:
        if page_size % i == 0:
            for d in (i, page_size // i):
                if best < d <= target:
                    best = d
        i += 1
    return best


def tracked_block_bytes(b: int, kv: int, g: int, L: int, cap: int, *,
                        backend: str, tile: Optional[int] = None) -> int:
    """Analytic peak f32 score-block bytes for one op call."""
    if backend == "materialized":
        width = cap + L
    else:
        width = tile if tile is not None else _select_tile(cap, L)
    return 4 * b * kv * g * L * width


_TRACK = {"peak_bytes": 0}


def reset_tracking() -> None:
    _TRACK["peak_bytes"] = 0


def peak_tracked_bytes() -> int:
    """Largest score-block footprint recorded at trace time since the last
    ``reset_tracking()`` (0 if every call since hit a cached jit trace —
    use ``tracked_block_bytes`` for shape-analytic accounting)."""
    return _TRACK["peak_bytes"]


def _stream_update(qf, carry, k, v, valid):
    """One online-softmax accumulation step, shared by the contiguous-ring
    and paged stream paths (one implementation ⇒ the two walks are
    bit-identical whenever they see the same logical tile sequence).

    qf: (B, KV, G, L, hd) pre-scaled f32 queries; k/v: (B, C, KV, hd) f32;
    valid: (B, L, C) bool; carry (m, l, acc).
    """
    m, l, acc = carry
    s = jnp.einsum("bkgld,bckd->bkglc", qf, k)               # (B,KV,G,L,C)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.where(valid[:, None, None],
                  jnp.exp(s - m_new[..., None]), 0.0)
    acc = acc * alpha[..., None] + jnp.einsum("bkglc,bckd->bkgld", p, v)
    l = l * alpha + jnp.sum(p, axis=-1)
    return m_new, l, acc


def _stream_carry0(b, kv, g, L, hd):
    return (jnp.full((b, kv, g, L), NEG_INF, jnp.float32),
            jnp.zeros((b, kv, g, L), jnp.float32),
            jnp.zeros((b, kv, g, L, hd), jnp.float32))


def _stream_finish(qf, carry, k_new, v_new, positions, lengths, reach):
    """Fold the chunk's own keys in as the final tile and normalize."""
    m, l, acc = _stream_update(qf, carry, k_new.astype(jnp.float32),
                               v_new.astype(jnp.float32),
                               _ref.chunk_mask(positions, lengths, reach))
    out = acc / jnp.maximum(l, 1e-30)[..., None]             # 0s if unseen
    return out.transpose(0, 3, 1, 2, 4)                      # (B,L,KV,G,hd)


def _stream(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale, pos_buf,
            positions, lengths, *, window, tile):
    """Online-softmax loop over ring tiles; chunk keys fold in last.

    Tiles are ``dynamic_slice``d out of the (B, cap, ...) ring in place —
    no upfront reshape/transpose copy of the cache, which would be a
    second full pass over exactly the HBM bytes this path exists to not
    touch twice.
    """
    b, L, kv, g, hd = q.shape
    cap = k_cache.shape[1]
    reach = reach_of(cap, window)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).transpose(0, 2, 3, 1, 4) * scale  # (B,KV,G,L,hd)

    def ring_tile(i, carry):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * tile, tile, axis=1)
        k = _ref._deq(sl(k_cache), sl(k_scale) if k_scale is not None
                      else None)                             # (B, tile, KV, hd)
        v = _ref._deq(sl(v_cache), sl(v_scale) if v_scale is not None
                      else None)
        pt = sl(pos_buf)
        d = positions[:, :, None] - pt[:, None, :]           # (B, L, tile)
        valid = (pt[:, None, :] >= 0) & (d >= 0) & (d < reach)
        return _stream_update(qf, carry, k, v, valid)

    n_tiles = cap // tile
    carry0 = _stream_carry0(b, kv, g, L, hd)
    if n_tiles == 1:  # decode fast path: no loop machinery for one tile
        carry = ring_tile(0, carry0)
    else:
        carry = jax.lax.fori_loop(0, n_tiles, ring_tile, carry0)
    return _stream_finish(qf, carry, k_new, v_new, positions, lengths, reach)


def _stream_paged(q, k_new, v_new, k_pool, k_scale, v_pool, v_scale,
                  pos_pool, table, positions, lengths, *, window, tile):
    """Paged stream path: the same online-softmax walk over *logical* tiles,
    each gathered through the page table (tile divides page_size, so one
    tile never spans two physical pages). Tile i covers logical slots
    [i·tile, (i+1)·tile) of the virtual ring ``gather_pages`` defines; with
    equal tile sizes the (k, v, valid) sequence matches the contiguous-ring
    walk exactly, so the two are bit-identical per backend.
    """
    b, L, kv, g, hd = q.shape
    ps = k_pool.shape[1]
    n_pages = table.shape[1]
    cap = n_pages * ps
    reach = reach_of(cap, window)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).transpose(0, 2, 3, 1, 4) * scale
    tpp = ps // tile                                         # tiles per page

    def page_tile(i, carry):
        pidx = i // tpp
        off = (i % tpp) * tile
        phys = jax.lax.dynamic_index_in_dim(table, pidx, axis=1,
                                            keepdims=False)  # (B,)
        sl = lambda pool: jax.lax.dynamic_slice_in_dim(
            jnp.take(pool, phys, axis=0), off, tile, axis=1)
        k = _ref._deq(sl(k_pool), sl(k_scale) if k_scale is not None
                      else None)                             # (B, tile, KV, hd)
        v = _ref._deq(sl(v_pool), sl(v_scale) if v_scale is not None
                      else None)
        pt = sl(pos_pool)
        d = positions[:, :, None] - pt[:, None, :]           # (B, L, tile)
        valid = (pt[:, None, :] >= 0) & (d >= 0) & (d < reach)
        return _stream_update(qf, carry, k, v, valid)

    n_tiles = n_pages * tpp
    carry0 = _stream_carry0(b, kv, g, L, hd)
    if n_tiles == 1:
        carry = page_tile(0, carry0)
    else:
        carry = jax.lax.fori_loop(0, n_tiles, page_tile, carry0)
    return _stream_finish(qf, carry, k_new, v_new, positions, lengths, reach)


def chunk_attention(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale,
                    pos_buf, positions, lengths, *,
                    window: Optional[int] = None,
                    backend: str = DEFAULT_BACKEND,
                    tile: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Chunk-prefill attention vs (pre-write ring ∪ in-chunk keys).

    Shapes/masks: package docstring. Returns (B, L, KV, G, hd) float32.
    ``k_scale``/``v_scale`` are None for float (bf16/f32) ring caches.
    """
    b, L, kv, g, hd = q.shape
    cap = k_cache.shape[1]
    backend = resolve_chunk_backend(backend)
    t = tile if tile is not None else _select_tile(cap, L)
    t = min(t, cap)
    while cap % t:  # tiles must divide cap exactly — a remainder tile would
        t -= 1      # silently drop ring slots from the visible set
    _TRACK["peak_bytes"] = max(
        _TRACK["peak_bytes"],
        tracked_block_bytes(b, kv, g, L, cap, backend=backend, tile=t))
    if backend == "materialized":
        return _ref.chunk_attention_ref(
            q, k_new, v_new, k_cache, k_scale, v_cache, v_scale,
            pos_buf, positions, lengths, window=window)
    if backend == "stream":
        return _stream(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale,
                       pos_buf, positions, lengths, window=window, tile=t)
    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = chunk_attention_pallas(
            q.transpose(0, 2, 3, 1, 4), k_new, v_new, k_cache, k_scale,
            v_cache, v_scale, pos_buf, positions,
            lengths.astype(jnp.int32), window=window, tile=t,
            interpret=interpret)
        return out.transpose(0, 3, 1, 2, 4)
    raise ValueError(f"unknown chunk-attention backend {backend!r}")


def chunk_attention_paged(q, k_new, v_new, k_pool, k_scale, v_pool, v_scale,
                          pos_pool, table, positions, lengths, *,
                          window: Optional[int] = None,
                          backend: str = DEFAULT_BACKEND,
                          tile: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """Chunk attention over a *paged* ring: identical semantics to
    ``chunk_attention`` on the virtual ring ``ref.gather_pages(pool,
    table)`` defines (mask rule unchanged, expressed in logical positions
    carried by ``pos_pool`` — prefill and decode L=1 stay unified).

    Extra operands vs the contiguous op: ``k_pool``/``v_pool`` are
    (P, page_size, KV, hd) physical pages (int8 with (P, page_size, KV)
    scales, or float with scales None), ``pos_pool`` (P, page_size) the
    per-entry absolute positions, ``table`` (B, n_pages) int32 physical
    page ids per logical page. Physical page 0 is the reserved null page
    (pos ≡ -1, never written): unmapped entries point at it and mask out.

    Backends mirror the contiguous op: ``materialized`` gathers the pages
    into a contiguous ring and runs ``chunk_attention_ref`` (the oracle by
    construction); ``stream``/``pallas`` walk logical tiles through the
    table without materializing the gather — with matching ``tile`` each
    is bit-identical to its contiguous-ring counterpart.
    """
    b, L, kv, g, hd = q.shape
    ps = k_pool.shape[1]
    n_pages = table.shape[1]
    cap = n_pages * ps
    backend = resolve_chunk_backend(backend)
    t = tile if tile is not None else paged_tile(ps, L)
    t = min(t, ps)
    while ps % t:  # tiles must divide the page — a spanning tile would need
        t -= 1     # a two-page gather
    _TRACK["peak_bytes"] = max(
        _TRACK["peak_bytes"],
        tracked_block_bytes(b, kv, g, L, cap, backend=backend, tile=t))
    if backend == "materialized":
        return _ref.chunk_attention_ref(
            q, k_new, v_new, gather_pages(k_pool, table),
            None if k_scale is None else gather_pages(k_scale, table),
            gather_pages(v_pool, table),
            None if v_scale is None else gather_pages(v_scale, table),
            gather_pages(pos_pool, table), positions, lengths, window=window)
    if backend == "stream":
        return _stream_paged(q, k_new, v_new, k_pool, k_scale, v_pool,
                             v_scale, pos_pool, table, positions, lengths,
                             window=window, tile=t)
    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = chunk_attention_paged_pallas(
            q.transpose(0, 2, 3, 1, 4), k_new, v_new, k_pool, k_scale,
            v_pool, v_scale, pos_pool, table, positions,
            lengths.astype(jnp.int32), window=window, tile=t,
            interpret=interpret)
        return out.transpose(0, 3, 1, 2, 4)
    raise ValueError(f"unknown chunk-attention backend {backend!r}")
