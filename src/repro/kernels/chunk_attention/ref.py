"""Pure-jnp oracle for chunk-prefill attention over the ring cache.

This is the *materialized* implementation: the full (L, cap + L) score
block and a full-ring f32 dequant, one softmax — exactly the pre-PR-5
serving path, restated against the package's mask contract. It doubles as
the ``backend="materialized"`` baseline (it is jit-friendly) and as the
parity oracle for the Pallas kernel and the streaming fallback.

The mask helpers here are the single source of truth for the visible set;
``ops`` and ``kernel`` reimplement them tile-wise and the tests assert the
reimplementations agree bitwise.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reach_of(cap: int, window: Optional[int]) -> int:
    """Maximum causal distance a query may look back.

    ``min(window or cap, cap)``: sliding-window layers clip at the window,
    and nothing sees further back than the ring can faithfully hold — the
    entry at distance exactly ``cap`` is the one the query's own write
    evicts (write-then-attend decode semantics, generalized to chunks).
    """
    return min(window, cap) if window else cap


def history_mask(pos_buf, positions, reach: int):
    """(B, L, cap) bool: chunk query l of row b sees ring slot s."""
    d = positions[:, :, None] - pos_buf[:, None, :]
    return (pos_buf[:, None, :] >= 0) & (d >= 0) & (d < reach)


def chunk_mask(positions, lengths, reach: int):
    """(B, L, L) bool: chunk query l sees in-chunk key j (causal + valid)."""
    L = positions.shape[1]
    valid = jnp.arange(L)[None, None, :] < lengths[:, None, None]
    d = positions[:, :, None] - positions[:, None, :]
    return valid & (d >= 0) & (d < reach)


def _deq(c, scale):
    c = c.astype(jnp.float32)
    return c if scale is None else c * scale[..., None].astype(jnp.float32)


def gather_pages(pool, table):
    """Materialize the logical (B, n_pages·page_size, ...) ring of a paged
    cache: ``ring[b, p*ps + o] = pool[table[b, p], o]``.

    ``pool`` is (P, ps, ...) physical pages; ``table`` (B, n_pages) int32
    physical page ids. Physical page 0 is the reserved *null page* (pos ≡
    -1, never written), so unmapped table entries gather safely and the
    mask rule hides them — no special cases anywhere downstream. This
    helper defines paged semantics: every paged backend must equal
    ``chunk_attention_ref`` over this gather.
    """
    b, n = table.shape
    flat = pool[table.reshape(-1)]                           # (B·n, ps, ...)
    return flat.reshape((b, n * pool.shape[1]) + pool.shape[2:])


def chunk_attention_ref(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale,
                        pos_buf, positions, lengths, *,
                        window: Optional[int] = None):
    """Materialized chunk attention; see the package docstring for shapes.

    Returns (B, L, KV, G, hd) float32.
    """
    b, L, kv, g, hd = q.shape
    cap = k_cache.shape[1]
    reach = reach_of(cap, window)
    scale = hd ** -0.5

    qf = q.astype(jnp.float32) * scale
    kc = _deq(k_cache, k_scale)                              # (B, cap, KV, hd)
    vc = _deq(v_cache, v_scale)
    s_hist = jnp.einsum("blkgd,bskd->bkgls", qf, kc)         # (B,KV,G,L,cap)
    m_hist = history_mask(pos_buf, positions, reach)         # (B, L, cap)
    s_hist = jnp.where(m_hist[:, None, None], s_hist, NEG_INF)

    knf = k_new.astype(jnp.float32)
    s_self = jnp.einsum("blkgd,bjkd->bkglj", qf, knf)        # (B,KV,G,L,L)
    m_self = chunk_mask(positions, lengths, reach)           # (B, L, L)
    s_self = jnp.where(m_self[:, None, None], s_self, NEG_INF)

    p = jax.nn.softmax(jnp.concatenate([s_hist, s_self], axis=-1), axis=-1)
    v_all = jnp.concatenate([vc, v_new.astype(jnp.float32)], axis=1)
    out = jnp.einsum("bkgls,bskd->blkgd", p, v_all)          # (B,L,KV,G,hd)
    return out
