"""Fused int8-KV flash chunk-prefill attention (Pallas, TPU target).

One grid program per (batch, kv-head) — the GQA grouping: all G query
heads that share a kv head ride in one program, so the int8 ring block is
read once per kv head, not once per query head. The kernel walks the ring
in ``tile``-slot chunks with an online-softmax accumulator, dequantizing
int8→f32 **in-register** per tile (HBM traffic = packed int8 bytes +
scales + q/chunk/out — the attention analogue of the ternary-matmul
streaming floor), then folds the chunk's own keys in as a final tile. The
(G·L, cap) score block never exists: scores live as (G·L, tile) in VMEM.

Ring wrap, sliding windows, and right-padding are all mask regions of the
same rule (see the package docstring): visible iff 0 <= qpos - kpos <
reach, ring slots additionally pos >= 0, chunk keys additionally
j < length. Like ``ternary_matvec_pallas`` this is validated in interpret
mode off-TPU; compiled-TPU runs only reshape leading (sublane) dims.

VMEM budget per program (hd=128, L=64, tile=512): resident int8 ring
blocks 2·cap·hd B (8 MB at cap=32k) + (G·L, tile) f32 scores — inside the
~16 MB v5e VMEM; longer rings shard over the mesh first.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _online_update(q2, k, v, valid, m, l, acc):
    """One online-softmax step. q2: (G·L, hd); k/v: (C, hd) f32;
    valid: (G·L, C) bool; carry m/l: (G·L,), acc: (G·L, hd)."""
    logits = jnp.dot(q2, k.T, preferred_element_type=jnp.float32)
    logits = jnp.where(valid, logits, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
    alpha = jnp.exp(m - m_new)
    # explicit re-mask: when a row has seen nothing yet (m_new == NEG_INF)
    # the subtraction cancels and exp() would emit 1s for masked slots
    p = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
    acc = acc * alpha[:, None] + jnp.dot(p, v,
                                         preferred_element_type=jnp.float32)
    l = l * alpha + jnp.sum(p, axis=-1)
    return m_new, l, acc


def _kernel(q_ref, kn_ref, vn_ref, k8_ref, ks_ref, v8_ref, vs_ref,
            posb_ref, pos_ref, len_ref, o_ref, *, tile: int, scale: float,
            reach: int, scaled: bool):
    # block shapes carry leading singleton (batch, kv) dims — index them away
    g, L, hd = q_ref.shape[-3:]
    cap = k8_ref.shape[1]
    n_tiles = cap // tile
    q2 = (q_ref[0, 0].astype(jnp.float32) * scale).reshape(g * L, hd)
    qpos = pos_ref[0]                                        # (L,)
    length = len_ref[0]

    def ring_tile(i, carry):
        off = i * tile
        k = k8_ref[0, pl.dslice(off, tile), 0, :].astype(jnp.float32)
        v = v8_ref[0, pl.dslice(off, tile), 0, :].astype(jnp.float32)
        if scaled:  # int8 ring: per-(slot, kv-head) absmax in-reg dequant
            k = k * ks_ref[0, pl.dslice(off, tile), 0][:, None]
            v = v * vs_ref[0, pl.dslice(off, tile), 0][:, None]
        pb = posb_ref[0, pl.dslice(off, tile)]
        d = qpos[:, None] - pb[None, :]                      # (L, tile)
        valid = (pb[None, :] >= 0) & (d >= 0) & (d < reach)
        validg = jnp.broadcast_to(valid[None], (g, L, tile)).reshape(
            g * L, tile)
        return _online_update(q2, k, v, validg, *carry)

    m0 = jnp.full((g * L,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g * L,), jnp.float32)
    acc0 = jnp.zeros((g * L, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, ring_tile, (m0, l0, acc0))

    # the chunk's own keys: one final (G·L, L) tile at activation precision
    kn = kn_ref[0, :, 0, :].astype(jnp.float32)              # (L, hd)
    vn = vn_ref[0, :, 0, :].astype(jnp.float32)
    jidx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    d = qpos[:, None] - qpos[None, :]
    valid = (jidx < length) & (d >= 0) & (d < reach)
    validg = jnp.broadcast_to(valid[None], (g, L, L)).reshape(g * L, L)
    m, l, acc = _online_update(q2, kn, vn, validg, m, l, acc)

    out = acc / jnp.maximum(l, 1e-30)[:, None]               # 0s if unseen
    o_ref[0, 0] = out.reshape(g, L, hd)


def _paged_kernel(q_ref, kn_ref, vn_ref, kp_ref, ks_ref, vp_ref, vs_ref,
                  posp_ref, pt_ref, pos_ref, len_ref, o_ref, *, tile: int,
                  scale: float, reach: int, scaled: bool):
    """Page-gather variant of ``_kernel``: same grid (one program per
    (batch, kv-head)), same online-softmax walk over *logical* tiles, but
    each tile is loaded through the page table with a dynamic page index
    (``pl.dslice`` start) instead of a contiguous ring offset. Tile divides
    page_size, so a tile never spans two physical pages. The mask rule is
    untouched — positions come from the gathered pos page, so ring wrap,
    windows, and the null page (pos ≡ -1) all fall out of the one rule.
    """
    g, L, hd = q_ref.shape[-3:]
    ps = kp_ref.shape[1]
    n_pages = pt_ref.shape[1]
    tpp = ps // tile
    q2 = (q_ref[0, 0].astype(jnp.float32) * scale).reshape(g * L, hd)
    qpos = pos_ref[0]                                        # (L,)
    length = len_ref[0]

    def page_tile(i, carry):
        pidx = i // tpp
        off = (i % tpp) * tile
        pid = pt_ref[0, pl.dslice(pidx, 1)][0]
        k = kp_ref[pl.dslice(pid, 1), pl.dslice(off, tile), 0, :][0]
        v = vp_ref[pl.dslice(pid, 1), pl.dslice(off, tile), 0, :][0]
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        if scaled:  # int8 pages: per-(entry, kv-head) absmax in-reg dequant
            k = k * ks_ref[pl.dslice(pid, 1), pl.dslice(off, tile), 0][0][:, None]
            v = v * vs_ref[pl.dslice(pid, 1), pl.dslice(off, tile), 0][0][:, None]
        pb = posp_ref[pl.dslice(pid, 1), pl.dslice(off, tile)][0]
        d = qpos[:, None] - pb[None, :]                      # (L, tile)
        valid = (pb[None, :] >= 0) & (d >= 0) & (d < reach)
        validg = jnp.broadcast_to(valid[None], (g, L, tile)).reshape(
            g * L, tile)
        return _online_update(q2, k, v, validg, *carry)

    m0 = jnp.full((g * L,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g * L,), jnp.float32)
    acc0 = jnp.zeros((g * L, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pages * tpp, page_tile,
                                  (m0, l0, acc0))

    # the chunk's own keys: identical to the contiguous kernel's final tile
    kn = kn_ref[0, :, 0, :].astype(jnp.float32)              # (L, hd)
    vn = vn_ref[0, :, 0, :].astype(jnp.float32)
    jidx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    d = qpos[:, None] - qpos[None, :]
    valid = (jidx < length) & (d >= 0) & (d < reach)
    validg = jnp.broadcast_to(valid[None], (g, L, L)).reshape(g * L, L)
    m, l, acc = _online_update(q2, kn, vn, validg, m, l, acc)

    out = acc / jnp.maximum(l, 1e-30)[:, None]               # 0s if unseen
    o_ref[0, 0] = out.reshape(g, L, hd)


def chunk_attention_paged_pallas(q, k_new, v_new, k_pool, k_scale, v_pool,
                                 v_scale, pos_pool, table, positions,
                                 lengths, *, window=None, tile: int = 512,
                                 interpret: bool = True):
    """Paged Pallas chunk attention. q is (B, KV, G, L, hd) (grid layout);
    the public op transposes. Pools are (P, page_size, KV, hd) with
    (P, page_size, KV) scales (int8) or scales None (float); table is
    (B, n_pages) physical page ids. Returns (B, KV, G, L, hd) f32.
    """
    P, ps, kv, hd = k_pool.shape
    b, n_pages = table.shape
    g, L = q.shape[2], q.shape[3]
    cap = n_pages * ps
    t = min(tile, ps)
    while ps % t:
        t -= 1
    reach = min(window, cap) if window else cap
    scale = hd ** -0.5
    scaled = k_scale is not None
    if not scaled:  # float pages: 1-entry placeholder refs, never read
        k_scale = v_scale = jnp.ones((1, 1, kv), jnp.float32)
    sP, sps = (P, ps) if scaled else (1, 1)

    kern = functools.partial(_paged_kernel, tile=t, scale=scale, reach=reach,
                             scaled=scaled)
    return pl.pallas_call(
        kern,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, L, hd), lambda i, j: (i, j, 0, 0, 0)),  # q
            pl.BlockSpec((1, L, 1, hd), lambda i, j: (i, 0, j, 0)),   # k_new
            pl.BlockSpec((1, L, 1, hd), lambda i, j: (i, 0, j, 0)),   # v_new
            pl.BlockSpec((P, ps, 1, hd), lambda i, j: (0, 0, j, 0)),  # k pool
            pl.BlockSpec((sP, sps, 1), lambda i, j: (0, 0, j)),       # ks
            pl.BlockSpec((P, ps, 1, hd), lambda i, j: (0, 0, j, 0)),  # v pool
            pl.BlockSpec((sP, sps, 1), lambda i, j: (0, 0, j)),       # vs
            pl.BlockSpec((P, ps), lambda i, j: (0, 0)),               # pos pool
            pl.BlockSpec((1, n_pages), lambda i, j: (i, 0)),          # table
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),                # positions
            pl.BlockSpec((1,), lambda i, j: (i,)),                    # lengths
        ],
        out_specs=pl.BlockSpec((1, 1, g, L, hd), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, L, hd), jnp.float32),
        interpret=interpret,
    )(q, k_new, v_new, k_pool, k_scale, v_pool, v_scale, pos_pool,
      table.astype(jnp.int32), positions, lengths)


def chunk_attention_pallas(q, k_new, v_new, k_cache, k_scale, v_cache,
                           v_scale, pos_buf, positions, lengths, *,
                           window=None, tile: int = 512,
                           interpret: bool = True):
    """Pallas chunk attention. q here is (B, KV, G, L, hd) (grid layout);
    the public op transposes. Returns (B, KV, G, L, hd) f32.
    """
    b, cap, kv, hd = k_cache.shape
    g, L = q.shape[2], q.shape[3]
    t = min(tile, cap)
    while cap % t:
        t -= 1
    reach = min(window, cap) if window else cap
    scale = hd ** -0.5
    scaled = k_scale is not None
    if not scaled:  # float ring: 1-slot placeholder refs, never read
        k_scale = v_scale = jnp.ones((b, 1, kv), jnp.float32)
    scap = cap if scaled else 1

    kern = functools.partial(_kernel, tile=t, scale=scale, reach=reach,
                             scaled=scaled)
    return pl.pallas_call(
        kern,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, L, hd), lambda i, j: (i, j, 0, 0, 0)),  # q
            pl.BlockSpec((1, L, 1, hd), lambda i, j: (i, 0, j, 0)),   # k_new
            pl.BlockSpec((1, L, 1, hd), lambda i, j: (i, 0, j, 0)),   # v_new
            pl.BlockSpec((1, cap, 1, hd), lambda i, j: (i, 0, j, 0)), # k8
            pl.BlockSpec((1, scap, 1), lambda i, j: (i, 0, j)),       # ks
            pl.BlockSpec((1, cap, 1, hd), lambda i, j: (i, 0, j, 0)), # v8
            pl.BlockSpec((1, scap, 1), lambda i, j: (i, 0, j)),       # vs
            pl.BlockSpec((1, cap), lambda i, j: (i, 0)),              # pos_buf
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),                # positions
            pl.BlockSpec((1,), lambda i, j: (i,)),                    # lengths
        ],
        out_specs=pl.BlockSpec((1, 1, g, L, hd), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, L, hd), jnp.float32),
        interpret=interpret,
    )(q, k_new, v_new, k_cache, k_scale, v_cache, v_scale, pos_buf,
      positions, lengths)
