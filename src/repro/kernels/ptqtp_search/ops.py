"""Jitted wrapper for the fused trit-search kernel (CPU: interpret mode)."""

from __future__ import annotations

import jax

from repro.kernels.ptqtp_search.kernel import ptqtp_search_pallas


def ptqtp_search(w: jax.Array, alpha: jax.Array, *, interpret: bool = True):
    """(t1, t2) f32 planes for group-rows w (R, G) and scales alpha (R, 2)."""
    return ptqtp_search_pallas(w, alpha, interpret=interpret)
