"""Pallas TPU kernel: fused 9-candidate trit search (quantization-time hot loop).

For each element of a (R, G) group-row block held in VMEM, evaluates the
squared error of all 9 ternary pairs (c¹, c²) against w - α¹c¹ - α²c²
(paper Eq. 5 / Alg. 2 lines 14-21) with a fully unrolled compare-select chain
on the VPU — no gathers, no argmin reductions, 9 fused FMAs + selects per
element. Emits both planes in one pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (0,0) first so exact ties prefer the sparse assignment (matches core/ref).
_CANDIDATES = (
    (0.0, 0.0),
    (0.0, 1.0),
    (0.0, -1.0),
    (1.0, 0.0),
    (-1.0, 0.0),
    (1.0, 1.0),
    (-1.0, -1.0),
    (1.0, -1.0),
    (-1.0, 1.0),
)


def _search_kernel(w_ref, a_ref, t1_ref, t2_ref):
    w = w_ref[...].astype(jnp.float32)          # (br, G)
    a = a_ref[...].astype(jnp.float32)          # (br, 2)
    a1 = a[:, 0:1]                              # (br, 1) broadcast over G
    a2 = a[:, 1:2]

    best_err = jnp.full_like(w, jnp.inf)
    best_t1 = jnp.zeros_like(w)
    best_t2 = jnp.zeros_like(w)
    for c1, c2 in _CANDIDATES:
        r = w - (a1 * c1 + a2 * c2)
        e = r * r
        take = e < best_err                      # strict: first candidate wins ties
        best_err = jnp.where(take, e, best_err)
        best_t1 = jnp.where(take, c1, best_t1)
        best_t2 = jnp.where(take, c2, best_t2)
    t1_ref[...] = best_t1
    t2_ref[...] = best_t2


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ptqtp_search_pallas(
    w: jax.Array,
    alpha: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Fused trit search. w: (R, G); alpha: (R, 2) -> (t1, t2) f32 (R, G)."""
    r, g = w.shape
    br = min(block_rows, r)
    pad = (-r) % br
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
        alpha = jnp.pad(alpha, ((0, pad), (0, 0)))
    rp = w.shape[0]
    out = pl.pallas_call(
        _search_kernel,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, g), lambda i: (i, 0)),
            pl.BlockSpec((br, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, g), lambda i: (i, 0)),
            pl.BlockSpec((br, g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, g), jnp.float32),
            jax.ShapeDtypeStruct((rp, g), jnp.float32),
        ],
        interpret=interpret,
    )(w, alpha)
    t1, t2 = out
    if pad:
        t1, t2 = t1[:r], t2[:r]
    return t1, t2
