"""Pure-jnp oracle for the PTQTP 9-candidate trit search (paper Eq. 5)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Same candidate order as repro.core.ptqtp.CANDIDATES ((0,0) first for ties).
CANDIDATES = np.array(
    [[0, 0], [0, 1], [0, -1], [1, 0], [-1, 0], [1, 1], [-1, -1], [1, -1], [-1, 1]],
    dtype=np.float32,
)


def ptqtp_search_ref(w, alpha):
    """Per-element argmin over the 9 ternary pairs.

    Args:
      w:     (R, G) float32 group-rows.
      alpha: (R, 2) float32 scales.
    Returns:
      (t1, t2): (R, G) float32 planes in {-1, 0, 1}.
    """
    cand = jnp.asarray(CANDIDATES)
    vals = alpha.astype(jnp.float32) @ cand.T  # (R, 9)
    err = (w.astype(jnp.float32)[:, :, None] - vals[:, None, :]) ** 2
    best = jnp.argmin(err, axis=-1)
    return cand[best, 0], cand[best, 1]
