"""Public wrapper for fused int8-KV decode attention."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention import ref as _ref
from repro.kernels.decode_attention.kernel import decode_attention_pallas


def decode_attention(q, k8, k_scale, v8, v_scale, pos_buf, pos, *,
                     window=None, backend: str = "pallas",
                     interpret: bool = True):
    """(B, KV, G, hd) f32 decode attention over an int8 ring cache."""
    if backend == "ref":
        return _ref.decode_attention_ref(q, k8, k_scale, v8, v_scale,
                                         pos_buf, pos, window=window)
    return decode_attention_pallas(q, k8, k_scale, v8, v_scale,
                                   pos_buf, pos, window=window,
                                   interpret=interpret)
