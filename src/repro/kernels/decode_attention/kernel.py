"""Fused int8-KV flash-decode attention (Pallas, TPU target).

One grid program per (batch, kv-head). The int8 cache block (S, hd) and its
scales live in VMEM; the kernel walks the cache in chunks with an online-
softmax accumulator, dequantizing int8→f32 IN-REGISTER — the HBM traffic is
exactly the packed int8 bytes + scales + q/out, i.e. the §Perf iteration-5
streaming floor. Scores (G, C) stay in VMEM (never (G, S)).

VMEM budget per program (hd=128, C=512): k8+v8 chunks via the resident
(S, hd) int8 blocks — 2·S·hd B; at S=32k, hd=128 that is 8 MB + scales,
inside the ~16 MB v5e VMEM. Longer caches shard S over the mesh first
(partition.state_pspecs) so per-chip S stays bounded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k8_ref, ks_ref, v8_ref, vs_ref, posb_ref, pos_ref,
            o_ref, *, chunk: int, scale: float, w_eff: int):
    # block shapes carry leading singleton (batch, kv) dims — index them away
    g, hd = q_ref.shape[-2:]
    s = k8_ref.shape[1]
    n_chunks = s // chunk
    q = q_ref[0, 0].astype(jnp.float32) * scale         # (G, hd)
    pos = pos_ref[0]

    def body(i, carry):
        m, l, acc = carry
        off = i * chunk
        k8 = k8_ref[0, pl.dslice(off, chunk), 0, :]
        ks = ks_ref[0, pl.dslice(off, chunk), 0]
        pb = posb_ref[0, pl.dslice(off, chunk)]
        k = k8.astype(jnp.float32) * ks[:, None]        # (C, hd) dequant
        logits = q @ k.T                                # (G, C)
        valid = (pb >= 0) & (pb <= pos) & (pos - pb < w_eff)
        logits = jnp.where(valid[None, :], logits, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))     # (G,)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[:, None])                 # (G, C)
        v8 = v8_ref[0, pl.dslice(off, chunk), 0, :]
        vs = vs_ref[0, pl.dslice(off, chunk), 0]
        v = v8.astype(jnp.float32) * vs[:, None]             # (C, hd)
        acc = acc * alpha[:, None] + p @ v
        l = l * alpha + jnp.sum(p, axis=-1)
        return m_new, l, acc

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc0 = jnp.zeros((g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)[:, None]


def decode_attention_pallas(q, k8, k_scale, v8, v_scale, pos_buf, pos, *,
                            window=None, chunk: int = 512,
                            interpret: bool = True):
    """Same contract as ref.decode_attention_ref; returns (B, KV, G, hd) f32.

    Grid (B, KV); per-program blocks: q (G, hd), cache (S, hd) int8 + (S,)
    scales, pos_buf (S,), pos scalar.
    """
    b, s, kv, hd = k8.shape
    g = q.shape[2]
    c = min(chunk, s)
    while s % c:
        c -= 1
    w_eff = window if window else s + 1
    scale = hd ** -0.5

    kern = functools.partial(_kernel, chunk=c, scale=scale, w_eff=w_eff)
    return pl.pallas_call(
        kern,
        grid=(b, kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),   # q
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),   # k8
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),          # ks
            pl.BlockSpec((1, s, 1, hd), lambda i, j: (i, 0, j, 0)),   # v8
            pl.BlockSpec((1, s, 1), lambda i, j: (i, 0, j)),          # vs
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),                # pos_buf
            pl.BlockSpec((1,), lambda i, j: (i,)),                    # pos
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        interpret=interpret,
    )(q, k8, k_scale, v8, v_scale, pos_buf, pos)
