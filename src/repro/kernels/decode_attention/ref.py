"""Pure-jnp oracle for fused int8-KV decode attention (GQA, ring cache)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k8, k_scale, v8, v_scale, pos_buf, pos,
                         window=None):
    """One-token GQA decode attention over an int8 ring cache.

    Args:
      q:        (B, KV, G, hd) float — query heads grouped per kv head.
      k8, v8:   (B, S, KV, hd) int8 cache.
      k_scale, v_scale: (B, S, KV) f32 per-slot/head absmax scales.
      pos_buf:  (B, S) int32 absolute position per slot (-1 = empty).
      pos:      (B,) int32 current decode position.
      window:   sliding-window size (None = full).
    Returns:
      (B, KV, G, hd) f32 attention output.
    """
    b, s, kv, hd = k8.shape
    scale = hd ** -0.5
    k = k8.astype(jnp.float32) * k_scale[..., None]
    v = v8.astype(jnp.float32) * v_scale[..., None]
    logits = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k) * scale
    w_eff = window if window else s + 1
    valid = ((pos_buf >= 0) & (pos_buf <= pos[:, None])
             & (pos[:, None] - pos_buf < w_eff))  # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bkgs,bskd->bkgd", p, v)
