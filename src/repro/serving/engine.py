"""Continuous-batching serving engine behind the v1 request API: bucketed
batched prefill, chunked prefill interleaved with a fused multi-step decode
loop, per-request RNG, streaming handles, cancellation.

Request lifecycle (Serving API v1 — see ``repro.serving.api``):

  * ``submit(prompt, SamplingParams(...)) -> RequestHandle`` enqueues; the
    handle exposes ``tokens()`` (a generator that drives ``step()`` on
    demand and yields each token in the engine step that produced it),
    ``result()`` (block until finished), ``cancel()`` (frees the slot
    immediately, mid-prefill or mid-decode), plus ``t_submit/t_first/
    t_done`` and a ``truncated`` flag when the prompt was clipped to
    ``capacity``;
  * ``step()`` advances the whole fleet one engine step (admission +
    prefill chunk + decode chunk) and returns the handles that finished;
  * ``run()`` drives until drained (the batch-caller style; the pre-v1
    ``Request`` record shim is gone after its one PR of grace).

Scheduling (unchanged from PR 2): the batch has ``max_slots`` fixed slots →
one jit'd decode loop for the whole fleet; **bucketed admission** drains the
wait queue into all free slots per step and advances every mid-prompt row by
one power-of-two prefill-chunk bucket in a single fixed-shape dispatch
(prefill compile cache O(log prefill_chunk)); **chunked prefill** interleaves
long prompts with (shortened) decode chunks; finished or cancelled slots free
immediately and refill next step.

Per-request RNG (the v1 determinism contract): each slot carries its
request's ``SamplingParams.seed``; the i-th generated token is drawn with
``fold_in(PRNGKey(seed), i)`` *on device inside the decode scan* (and for
i = 0 by the prefill finisher / serial admitter). No draw touches
engine-global state, so a request's output is a pure function of (params,
prompt, SamplingParams) — invariant to fleet composition, scheduler
(`ServingEngine` vs `SerialAdmitEngine`), and chunk boundaries. Stop-token
ids (``SamplingParams.stop`` ∪ ``EngineConfig.eos_id``) freeze the row
on device and truncate the host-side stream at the first hit, wherever in a
chunk (or in the prefill-finisher sample) it lands.

Works identically for dense and PTQTP-quantized params (`dense` dispatches
on the kernel leaf type), which is the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import unpack_trits
from repro.core.quantize_model import QuantizedKernel
from repro.kernels.ternary_matmul.ops import resolve_backend
from repro.models import (decode_step, init_decode_state, prefill,
                          prefill_chunk)
from repro.models.common import matmul_backend
from repro.serving.api import (FINISH_CANCELLED, FINISH_LENGTH, FINISH_STOP,
                               RequestHandle, SamplingParams, make_handle)
from repro.serving.sampling import request_keys, sample_tokens_per_request

__all__ = ["EngineConfig", "ServingEngine", "SerialAdmitEngine",
           "SamplingParams", "RequestHandle"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide knobs. Per-request generation behavior (budget,
    temperature, top-k/top-p, seed, stop ids) lives in ``SamplingParams``;
    what remains here is fleet shape and scheduling.

    ``eos_id`` is the engine-wide stop token (tokenizer property, honored
    for every request in addition to its ``SamplingParams.stop``).
    ``attn_backend`` overrides the model's ring-cache attention backend
    (``repro.kernels.chunk_attention``: auto | pallas | stream |
    materialized) for every dispatch this engine compiles — the serving-
    level knob the launcher's ``--attn-backend`` flag sets.
    """

    max_slots: int = 4
    capacity: int = 256          # KV-cache length per slot
    eos_id: Optional[int] = None
    attn_backend: Optional[str] = None
    decode_chunk: int = 8        # tokens per jitted decode dispatch (K)
    prefill_chunk: int = 64      # max prompt tokens consumed per slot per step
    # decode chunk cap while any slot is mid-prefill: a long prompt reaches
    # its first token in ~L/prefill_chunk short engine steps instead of
    # waiting a full decode chunk between each of its prefill chunks
    # (TTFT-vs-TPOT balance, the chunked-prefill token-budget idea)
    decode_chunk_prefilling: int = 2
    # Pre-unpack trit-planes for the decode loop (None → auto: only when the
    # grouped XLA backend serves the quantized matmuls; the Pallas TPU kernel
    # unpacks in-kernel, where streaming packed planes IS the win). Trades
    # 4x plane bytes (int8 trits vs 2-bit fields, still 2x under fp16) for
    # not re-unpacking every weight at every decode step.
    preunpack_decode: Optional[bool] = None

    def __post_init__(self):
        assert self.max_slots >= 1 and self.capacity >= 1
        assert self.decode_chunk >= 1, "decode_chunk=0 would never emit"
        assert self.prefill_chunk >= 1, "prefill_chunk=0 would never admit"
        assert self.decode_chunk_prefilling >= 1


def _pow2ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _preunpack_params(params):
    """Replace packed QuantizedKernel planes with raw int8 trit-planes.

    The unpack is exact and the grouped einsum consumes either form with the
    identical contraction order, so decode outputs are bit-identical — the
    unpack work just moves from every decode step to engine init.
    """

    def unpack(leaf):
        if isinstance(leaf, QuantizedKernel):
            return dataclasses.replace(
                leaf, t1p=unpack_trits(leaf.t1p), t2p=unpack_trits(leaf.t2p))
        return leaf

    return jax.tree.map(unpack, params,
                        is_leaf=lambda x: isinstance(x, QuantizedKernel))


def _merge_slot_impl(batch_state, one_state, slot):
    """Write a batch=1 decode state into slot `slot` of the batch state.

    Jitted (slot is a traced scalar): one dispatch per admit instead of one
    per state leaf — the leaf-by-leaf eager version dominated admit latency.
    The batch state is donated on accelerators so the one-slot write never
    copies the other slots' KV caches. (Serial-admit path only; the bucketed
    scheduler prefills straight into the batch state and never merges.)
    """

    def walk(dst, src, path):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k], f"{path}/{k}") for k in dst}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=axis).astype(dst.dtype))

    return walk(batch_state, one_state, "")


_merge_jit = None


def _merge_slot(batch_state, one_state, slot):
    """Jitted merge, donation decided lazily (first call, not import time —
    importing this module must not initialize the JAX platform)."""
    global _merge_jit
    if _merge_jit is None:
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _merge_jit = jax.jit(_merge_slot_impl, donate_argnums=donate)
    return _merge_jit(batch_state, one_state, slot)


def _reset_rows_impl(state, mask):
    """Clear the per-row decode state for rows in `mask` (new admissions).

    Ring-cache position leaves reset to -1 (nothing valid), everything else
    (KV, recurrent states, absolute pos) to zero — one fused dispatch no
    matter how many rows reset, so a burst of admits costs one round-trip.
    """

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        shape = [1] * node.ndim
        shape[axis] = node.shape[axis]
        reset = -1 if (path.endswith("/pos") and path != "/pos") else 0
        return jnp.where(mask.reshape(shape),
                         jnp.asarray(reset, node.dtype), node)

    return walk(state, "")


def _decode_loop(params, state, tokens, temps, active, seeds, gen_idx,
                 top_k, top_p, stops, *, cfg, n_steps, use_mask):
    """K fused decode steps with on-device per-request sampling.

    Args:
      tokens:  (B,) int32 last token per slot.
      temps:   (B,) f32 per-slot temperature (0 → greedy for that row).
      active:  (B,) bool — decoding slots; inactive slots (free, mid-prefill,
        or stop-frozen) repeat their token and their state is left untouched.
      seeds:   (B,) uint32 per-request RNG seed (``SamplingParams.seed``).
      gen_idx: (B,) int32 tokens already generated per request — the i-th
        token draws ``fold_in(PRNGKey(seed), i)``, so resuming a request at
        any chunk boundary continues the identical stream.
      top_k:   (B,) int32, 0 disables per row (traced iff ``use_mask``).
      top_p:   (B,) f32, 1.0 disables per row (traced iff ``use_mask``).
      stops:   (B, W) int32 stop-token ids, -1-padded (W static; a hit
        freezes the row exactly like the pre-v1 EOS check).
    Returns:
      (new_state, toks) with toks (n_steps, B) — the sampled token per step.
    """

    def body(carry, _):
        state, tok, active, gen = carry
        logits, state = decode_step(params, cfg, state, tok, active)
        keys = request_keys(seeds, gen)
        nxt = sample_tokens_per_request(
            logits, keys, temps,
            top_k=top_k if use_mask else None,
            top_p=top_p if use_mask else None)
        nxt = jnp.where(active, nxt, tok)  # frozen slots repeat (host drops)
        gen = gen + active.astype(gen.dtype)
        hit = jnp.any(nxt[:, None] == stops, axis=-1)
        active = jnp.logical_and(active, jnp.logical_not(hit))
        return (state, nxt, active, gen), nxt

    # Full unroll: the scan body is op-overhead-bound at decode shapes, and
    # unrolling lets XLA fuse across steps (measured ~40% per-token on CPU).
    (state, _, _, _), toks = jax.lax.scan(
        body, (state, tokens, active, gen_idx), None, length=n_steps,
        unroll=min(n_steps, 16))
    return state, toks


class ServingEngine:
    """Bucketed/chunked-prefill scheduler behind the v1 handle API (see
    module docstring)."""

    def __init__(self, params, model_cfg, engine_cfg: EngineConfig):
        self.params = params
        if engine_cfg.attn_backend is not None:
            model_cfg = dataclasses.replace(
                model_cfg, attn_backend=engine_cfg.attn_backend)
        self.cfg = model_cfg
        self.ecfg = engine_cfg
        self.queue: deque[RequestHandle] = deque()
        self.slots: List[Optional[RequestHandle]] = [None] * engine_cfg.max_slots
        self.state = init_decode_state(model_cfg, engine_cfg.max_slots,
                                       engine_cfg.capacity)
        self.last_tokens = np.zeros((engine_cfg.max_slots,), np.int32)
        pre = engine_cfg.preunpack_decode
        if pre is None:
            pre = resolve_backend(matmul_backend()) == "grouped"
        # serve-side params: prefill and decode both read these, so the
        # unpack is paid once per engine, not once per dispatch
        self._serve_params = _preunpack_params(params) if pre else params
        self.preunpack_decode = pre
        self._loop_cache: Dict[Tuple[int, bool, int], Any] = {}
        self._prefill_cache: Dict[int, Any] = {}
        self._reset_jit = None
        # per-slot prompt progress: clipped prompt + tokens already consumed
        self._prompts: List[Optional[List[int]]] = [None] * engine_cfg.max_slots
        self._cursor: List[int] = [0] * engine_cfg.max_slots
        self._admit_finished: List[RequestHandle] = []
        self._slot_arrays = None  # fleet array cache; None → slots dirty
        self._next_uid = 0
        self.steps = 0           # decode steps dispatched (tokens per slot)
        self.prefill_steps = 0   # prefill_chunk dispatches
        self.admits = 0

    # ------------------------------------------------------------------ API
    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               uid: Optional[int] = None) -> RequestHandle:
        """Enqueue a request; returns its :class:`RequestHandle`.

        ``prompt`` is a token-id list; ``params`` is its
        ``SamplingParams`` (default greedy).
        """
        if uid is None:
            uid, self._next_uid = self._next_uid, self._next_uid + 1
        h = make_handle(self, prompt, params, uid)
        self._next_uid = max(self._next_uid, h.uid + 1)  # explicit uids must
        # not collide with auto-assigned ones
        stop = frozenset(h.params.stop)
        if self.ecfg.eos_id is not None:
            stop |= {self.ecfg.eos_id}
        h._stop_ids = stop
        # the truncation that _admit will apply, surfaced at submit time
        h.truncated = len(h.prompt) > self.ecfg.capacity
        self.queue.append(h)
        return h

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a request (``RequestHandle.cancel`` delegates here).

        Queued → removed before it ever admits; resident → its slot frees
        *immediately*, mid-prefill or mid-decode, and the next admission
        reuses it (the admission row-reset clears whatever the cancelled
        request left in the KV cache, so neighbors never see it). Already
        finished → no-op, returns False.
        """
        if handle.done:
            return False
        try:
            self.queue.remove(handle)
        except ValueError:
            slot = next((i for i, h in enumerate(self.slots) if h is handle),
                        None)
            if slot is None:
                return False  # not ours
            self._free_slot(slot)
        self._finish(handle, FINISH_CANCELLED, time.perf_counter())
        return True

    def run(self, max_steps: int = 10_000) -> List[RequestHandle]:
        """Drive until queue + slots drain; returns the finished handles.
        Cancelled requests are not returned."""
        finished: List[RequestHandle] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    def warmup(self):
        """Precompile every dispatch the engine can ever need.

        Feasible *because* the dispatch set is bounded: prefill buckets are
        the powers of two up to prefill_chunk and decode chunks the powers
        of two up to decode_chunk, each in a masked (top-k/top-p fleet) and
        unmasked sampling variant — a few dozen programs, not one per
        prompt length. (The only lazily compiled stragglers are stop-set
        width buckets > 1, for fleets using multi-token ``stop`` sets.)
        Every warm call is a semantic no-op on the live state (lengths=0
        rows / active=False rows / empty reset mask), so warmup can run at
        any point in the engine's life.
        """
        self._warm_prefill()
        nb = len(self.slots)
        chunks = {min(self.ecfg.decode_chunk, n)
                  for n in self._bucket_lengths(self.ecfg.decode_chunk)}
        chunks.add(min(self.ecfg.decode_chunk,
                       self.ecfg.decode_chunk_prefilling))
        idle = jnp.zeros((nb,), bool)
        z32 = jnp.zeros((nb,), jnp.int32)
        for n in sorted(chunks):
            for masked in (False, True):
                self.state, _ = self._loop_fn(n, masked, 1)(
                    self._serve_params, self.state,
                    jnp.asarray(self.last_tokens),
                    jnp.zeros((nb,), jnp.float32), idle,
                    jnp.zeros((nb,), jnp.uint32), z32, z32,
                    jnp.ones((nb,), jnp.float32),
                    jnp.full((nb, 1), -1, jnp.int32))
        self._reset_rows(np.zeros((nb,), bool))

    def _warm_prefill(self):
        nb = len(self.slots)
        for length in self._bucket_lengths(self.ecfg.prefill_chunk):
            _, self.state = self._prefill_fn(length)(
                self._serve_params, self.state,
                jnp.zeros((nb, length), jnp.int32),
                jnp.zeros((nb,), jnp.int32))

    @staticmethod
    def _bucket_lengths(top: int) -> List[int]:
        out = [1]
        while out[-1] < _pow2ceil(top):
            out.append(out[-1] * 2)
        return out

    def compile_stats(self) -> Dict[str, Any]:
        """Jit-cache occupancy — the compile-bound story, made observable.

        The bucketed scheduler's prefill entries are power-of-two chunk
        lengths ≤ prefill_chunk, so ``n_prefill_compiles`` is bounded by
        ``prefill_bucket_bound`` = log2(next_pow2(prefill_chunk)) + 1; the
        decode entries are (power-of-two chunk length ≤ decode_chunk,
        masked-sampling?, stop-width bucket) triples. The serial-admit
        baseline instead caches one prefill entry per distinct prompt
        length (up to `capacity` of them).
        """
        return {
            "prefill_bucket_lengths": sorted(self._prefill_cache),
            "n_prefill_compiles": len(self._prefill_cache),
            "prefill_bucket_bound":
                _pow2ceil(self.ecfg.prefill_chunk).bit_length(),
            "decode_chunk_lengths": sorted({k[0] for k in self._loop_cache}),
            "n_decode_compiles": len(self._loop_cache),
            "admits": self.admits,
            "prefill_steps": self.prefill_steps,
        }

    def memory_stats(self) -> Dict[str, Any]:
        """Resident serving-state byte accounting (the boot-breakdown /
        attention-memory-bench numbers, computed not estimated).

        ``preunpack_decode`` trades plane bytes for per-step unpack work:
        the resident planes are raw int8 trits (1 byte/trit) instead of the
        packed 2-bit fields (0.25 byte/trit), so ``resident_plane_bytes``
        is 4x ``packed_plane_bytes`` while it is on — and a bench that only
        counted the packed artifact would understate resident state by
        exactly that ratio. ``decode_state_bytes`` is the live batch state
        (KV rings + recurrent states + positions) at this engine's
        (max_slots, capacity).
        """
        def plane_bytes(tree) -> int:
            return sum(
                int(leaf.t1p.nbytes) + int(leaf.t2p.nbytes)
                for leaf in jax.tree.leaves(
                    tree, is_leaf=lambda x: isinstance(x, QuantizedKernel))
                if isinstance(leaf, QuantizedKernel))

        packed = plane_bytes(self.params)
        resident = plane_bytes(self._serve_params)
        param_bytes = sum(int(x.nbytes)
                          for x in jax.tree.leaves(self._serve_params))
        state_bytes = sum(int(x.nbytes) for x in jax.tree.leaves(self.state))
        return {
            "preunpack_decode": self.preunpack_decode,
            "packed_plane_bytes": packed,
            "resident_plane_bytes": resident,
            "preunpack_ratio": (resident / packed) if packed else 1.0,
            "param_bytes": param_bytes,
            "decode_state_bytes": state_bytes,
            "resident_total_bytes": param_bytes + state_bytes,
        }

    # ----------------------------------------------------------------- step
    def step(self) -> List[RequestHandle]:
        """Admit into all free slots, advance prefill one chunk, decode one
        chunk; returns the requests that finished this step.

        The decode chunk length adapts to the largest remaining token budget
        among decoding slots, rounded up to a power of two (compile count
        stays O(log K)) — a fleet that only needs 3 more tokens never pays
        for a 16-step dispatch.
        """
        self._admit()
        done_now = self._admit_finished
        self._admit_finished = []
        done_now = done_now + self._prefill_step()
        dec = [i for i in range(len(self.slots)) if self._decoding(i)]
        if not dec:
            return done_now
        remaining = max(self.slots[i].params.max_new_tokens
                        - len(self.slots[i].output) for i in dec)
        chunk = self.ecfg.decode_chunk
        if any(self._prefilling(i) for i in range(len(self.slots))):
            chunk = min(chunk, self.ecfg.decode_chunk_prefilling)
        n_steps = min(chunk, _pow2ceil(remaining))
        (temps, active, seeds, top_k, top_p, stops), use_mask, stop_w = \
            self._fleet_arrays()
        # tokens generated so far per row: the on-device draw for a row's
        # i-th token always uses fold_in(PRNGKey(seed), i), independent of
        # where the chunk boundaries fell
        gen0 = jnp.asarray([len(self.slots[i].output) if self._decoding(i)
                            else 0 for i in range(len(self.slots))], jnp.int32)
        self.state, toks = self._loop_fn(n_steps, use_mask, stop_w)(
            self._serve_params, self.state, jnp.asarray(self.last_tokens),
            temps, active, seeds, gen0, top_k, top_p, stops)
        self.steps += n_steps
        return done_now + self._collect(np.asarray(toks))

    # ------------------------------------------------------------- internals
    def _prefilling(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] < len(self._prompts[slot]))

    def _decoding(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] >= len(self._prompts[slot]))

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        self._prompts[slot] = None
        self._cursor[slot] = 0
        self._slot_arrays = None

    def _mark_first(self, h: RequestHandle, now: float):
        if not h.t_first:
            h.t_first = now

    def _finish(self, h: RequestHandle, reason: str, now: float):
        h.finish_reason = reason
        h.t_done = now

    def _fleet_arrays(self):
        """Per-slot device arrays for the decode dispatch, cached until the
        fleet changes: (temps, active, seeds, top_k, top_p, stops) plus the
        static (use_mask, stop_width) pair that keys the loop variant."""
        if self._slot_arrays is None:
            nb = len(self.slots)
            temps = np.zeros((nb,), np.float32)
            seeds = np.zeros((nb,), np.uint32)
            top_k = np.zeros((nb,), np.int32)
            top_p = np.ones((nb,), np.float32)
            stop_sets: List[List[int]] = [[] for _ in range(nb)]
            use_mask = False
            for i in range(nb):
                if not self._decoding(i):
                    continue
                p = self.slots[i].params
                temps[i] = p.temperature
                seeds[i] = p.seed & 0xFFFFFFFF
                top_k[i] = p.top_k
                top_p[i] = p.top_p
                stop_sets[i] = sorted(self.slots[i]._stop_ids)
                use_mask |= p.needs_mask
            stop_w = _pow2ceil(max(1, max(len(s) for s in stop_sets)))
            stops = np.full((nb, stop_w), -1, np.int32)
            for i, s in enumerate(stop_sets):
                stops[i, :len(s)] = s
            active = np.asarray([self._decoding(i) for i in range(nb)])
            self._slot_arrays = (
                tuple(jnp.asarray(a) for a in
                      (temps, active, seeds, top_k, top_p, stops)),
                use_mask, stop_w)
        return self._slot_arrays

    def _loop_fn(self, n_steps: int, use_mask: bool, stop_w: int):
        key = (n_steps, use_mask, stop_w)
        if key not in self._loop_cache:
            # Donating the decode state lets XLA update the KV caches in
            # place; CPU has no donation support and would warn per dispatch.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._loop_cache[key] = jax.jit(
                functools.partial(_decode_loop, cfg=self.cfg,
                                  n_steps=n_steps, use_mask=use_mask),
                donate_argnums=donate)
        return self._loop_cache[key]

    def _prefill_fn(self, length: int):
        """One jit per power-of-two chunk bucket (O(log prefill_chunk))."""
        if length not in self._prefill_cache:
            cfg = self.cfg
            donate = (1,) if jax.default_backend() != "cpu" else ()

            def impl(params, state, tokens, lengths):
                return prefill_chunk(params, cfg, state, {"tokens": tokens},
                                     lengths)

            self._prefill_cache[length] = jax.jit(impl, donate_argnums=donate)
        return self._prefill_cache[length]

    def _reset_rows(self, mask: np.ndarray):
        if self._reset_jit is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._reset_jit = jax.jit(_reset_rows_impl, donate_argnums=donate)
        self.state = self._reset_jit(self.state, jnp.asarray(mask))

    def _admit(self):
        """Drain the wait queue into *all* free slots in one go."""
        fresh = []
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            h = self.queue.popleft()
            self.slots[slot] = h
            self._prompts[slot] = list(h.prompt[-self.ecfg.capacity:])
            self._cursor[slot] = 0
            fresh.append(slot)
            self.admits += 1
        if fresh:
            mask = np.zeros((len(self.slots),), bool)
            mask[fresh] = True
            self._reset_rows(mask)
            self._slot_arrays = None

    def _sample_first(self, logits, rows: List[int]) -> np.ndarray:
        """Token 0 for every row in ``rows`` (whose prompt just completed),
        drawn from each request's own stream — ``fold_in(PRNGKey(seed), 0)``
        — with its top-k/top-p support; other rows ride along as greedy and
        are ignored by the caller."""
        nb = logits.shape[0]
        rs = set(rows)
        p = {i: self.slots[i].params for i in rows}
        temps = jnp.asarray([p[i].temperature if i in rs else 0.0
                             for i in range(nb)], jnp.float32)
        seeds = jnp.asarray([p[i].seed & 0xFFFFFFFF if i in rs else 0
                             for i in range(nb)], jnp.uint32)
        keys = request_keys(seeds, jnp.zeros((nb,), jnp.int32))
        tk = tp = None
        if any(p[i].needs_mask for i in rows):
            tk = jnp.asarray([p[i].top_k if i in rs else 0
                              for i in range(nb)], jnp.int32)
            tp = jnp.asarray([p[i].top_p if i in rs else 1.0
                              for i in range(nb)], jnp.float32)
        return np.asarray(sample_tokens_per_request(
            logits, keys, temps, top_k=tk, top_p=tp))

    def _prefill_step(self) -> List[RequestHandle]:
        """Advance every mid-prompt slot by one bucketed chunk.

        All prefilling rows share one fixed-(B, L) dispatch: L is the
        power-of-two bucket of the longest remaining need this step (capped
        at prefill_chunk); rows with shorter remainders right-pad, rows not
        prefilling ride along with length 0 (no-op). Rows whose prompt
        completes sample their first token here — so a streamed first token
        lands in the same engine step that finishes its prefill — and join
        the decode fleet the same step.
        """
        pf = [i for i in range(len(self.slots)) if self._prefilling(i)]
        if not pf:
            return []
        nb = len(self.slots)
        need = max(min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk) for i in pf)
        length = _pow2ceil(need)
        tokens = np.zeros((nb, length), np.int32)
        lengths = np.zeros((nb,), np.int32)
        for i in pf:
            # never consume more than prefill_chunk per step, even when the
            # pow2 bucket rounds past it (non-pow2 prefill_chunk configs)
            take = min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk)
            tokens[i, :take] = self._prompts[i][
                self._cursor[i]:self._cursor[i] + take]
            lengths[i] = take
        logits, self.state = self._prefill_fn(length)(
            self._serve_params, self.state, jnp.asarray(tokens),
            jnp.asarray(lengths))
        self.prefill_steps += 1
        finishers = [i for i in pf
                     if self._cursor[i] + int(lengths[i])
                     >= len(self._prompts[i])]
        for i in pf:
            self._cursor[i] += int(lengths[i])
        if not finishers:
            return []
        # the prompt's last logits yield the first generated token; one
        # vectorized sample covers every finishing row
        toks = self._sample_first(logits, finishers)
        now = time.perf_counter()
        finished: List[RequestHandle] = []
        for i in finishers:
            h = self.slots[i]
            tok = int(toks[i])
            h.output.append(tok)
            self._mark_first(h, now)
            # the prefill-sampled token may already terminate the request —
            # on eos_id *or* any SamplingParams.stop id
            if tok in h._stop_ids:
                self._finish(h, FINISH_STOP, now)
            elif len(h.output) >= h.params.max_new_tokens:
                self._finish(h, FINISH_LENGTH, now)
            else:
                self.last_tokens[i] = tok
                self._slot_arrays = None
                continue
            finished.append(h)
            self._free_slot(i)
        return finished

    def _collect(self, toks: np.ndarray) -> List[RequestHandle]:
        """Fold a (K, B) chunk of tokens into the per-slot requests.

        A slot stops at its first stop-token hit (any id in the request's
        ``stop`` set ∪ ``eos_id``) or at its token budget; anything the
        device generated past that point within the chunk is discarded (the
        slot's state is reset by the next admission). Slots still mid-prefill
        took no decode step — their repeated tokens are skipped entirely.
        """
        finished = []
        now = time.perf_counter()
        for slot, h in enumerate(self.slots):
            if h is None or not self._decoding(slot):
                continue
            for k in range(toks.shape[0]):
                tok = int(toks[k, slot])
                h.output.append(tok)
                self._mark_first(h, now)
                self.last_tokens[slot] = tok
                if tok in h._stop_ids:
                    self._finish(h, FINISH_STOP, now)
                elif len(h.output) >= h.params.max_new_tokens:
                    self._finish(h, FINISH_LENGTH, now)
                else:
                    continue
                finished.append(h)
                self._free_slot(slot)
                break
        return finished


class SerialAdmitEngine(ServingEngine):
    """The PR-1 admission path, kept as the measured baseline: each arriving
    request is prefilled *alone* through a jit cached per distinct prompt
    length (up to `capacity` compilations) and merged into its slot — the
    whole decode fleet stalls while the queue's prompts are consumed one by
    one. Decode (and the v1 handle/cancellation/per-request-RNG surface) is
    identical to `ServingEngine`, so a request's output is bit-identical
    across the two schedulers.
    """

    def _warm_prefill(self):
        # Best effort only: compiles the power-of-two prompt lengths, but
        # this engine's jit cache is keyed by *exact* prompt length — any
        # other arriving length still compiles at admission time, which is
        # exactly the TTFT pathology the bucketed scheduler removes.
        for length in self._bucket_lengths(self.ecfg.capacity):
            if length > self.ecfg.capacity:
                break
            self._prefill_len_fn(length)(
                self._serve_params, jnp.zeros((1, length), jnp.int32))

    def _merge(self, batch_state, one_state, slot):
        # hook: the decode benchmark's seed baseline overrides this with the
        # eager leaf-by-leaf merge it measures against
        return _merge_slot(batch_state, one_state, slot)

    @staticmethod
    def _sample_first_row(logits, keys, p: SamplingParams):
        """Token 0 for one batch-1 logits row — row-wise sampling is
        batch-size-invariant, so this matches the bucketed engine's fleet
        dispatch bit for bit."""
        tk = jnp.asarray([p.top_k], jnp.int32) if p.needs_mask else None
        tp = jnp.asarray([p.top_p], jnp.float32) if p.needs_mask else None
        return np.asarray(sample_tokens_per_request(
            logits, keys, jnp.asarray([p.temperature], jnp.float32),
            top_k=tk, top_p=tp))[0]

    def _prefill_len_fn(self, length: int):
        # one jit per distinct prompt length; prompts are clipped to
        # `capacity` on admit, so the cache is bounded by capacity entries
        if length not in self._prefill_cache:
            cfg, cap = self.cfg, self.ecfg.capacity

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, {"tokens": tokens}, capacity=cap)

            self._prefill_cache[length] = fn
        return self._prefill_cache[length]

    def _admit(self):
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            h = self.queue.popleft()
            self.admits += 1
            prompt = h.prompt[-self.ecfg.capacity:]
            fn = self._prefill_len_fn(len(prompt))
            logits, one_state = fn(self._serve_params,
                                   jnp.asarray([prompt], jnp.int32))
            self.state = self._merge(self.state, one_state, slot)
            self.prefill_steps += 1
            self.slots[slot] = h
            self._prompts[slot] = list(prompt)
            self._cursor[slot] = 0        # not decoding until token 0 lands
            # token 0 from the request's own stream (serial prefill logits
            # are batch-1: sample that one row directly)
            p = h.params
            keys = request_keys(jnp.asarray([p.seed & 0xFFFFFFFF],
                                            jnp.uint32),
                                jnp.zeros((1,), jnp.int32))
            tok = int(self._sample_first_row(logits, keys, p))
            now = time.perf_counter()
            h.output.append(tok)
            self._mark_first(h, now)
            # the prefill-sampled token may already terminate the request
            if tok in h._stop_ids:
                self._finish(h, FINISH_STOP, now)
            elif len(h.output) >= h.params.max_new_tokens:
                self._finish(h, FINISH_LENGTH, now)
            else:
                self.last_tokens[slot] = tok
                # mark the prompt consumed → base class sees a decoding row
                self._cursor[slot] = len(prompt)
                self._slot_arrays = None
                continue
            self._admit_finished.append(h)
            self._free_slot(slot)
