"""Continuous-batching serving engine.

Slot-based continuous batching (vLLM-style, adapted to fixed-shape JAX):

  * the decode batch has `max_slots` fixed slots → one jit'd `decode_step`
    for the whole fleet of in-flight requests (no recompilation as requests
    come and go);
  * an arriving request is prefilled alone (prompt lengths bucketed to powers
    of two to bound compile count) and its state is *merged* into a free slot;
  * finished slots (EOS / max_tokens) are freed immediately and refilled from
    the wait queue on the next step — decode never stalls on stragglers.

Works identically for dense and PTQTP-quantized params (`dense` dispatches on
the kernel leaf type), which is the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_state, prefill
from repro.serving.sampling import sample_token


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    capacity: int = 256          # KV-cache length per slot
    eos_id: Optional[int] = None
    seed: int = 0


def _merge_slot(batch_state, one_state, slot: int):
    """Write a batch=1 decode state into slot `slot` of the batch state."""

    def walk(dst, src, path):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k], f"{path}/{k}") for k in dst}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=axis).astype(dst.dtype))

    return walk(batch_state, one_state, "")


class ServingEngine:
    def __init__(self, params, model_cfg, engine_cfg: EngineConfig):
        self.params = params
        self.cfg = model_cfg
        self.ecfg = engine_cfg
        self.key = jax.random.PRNGKey(engine_cfg.seed)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_slots
        self.state = init_decode_state(model_cfg, engine_cfg.max_slots,
                                       engine_cfg.capacity)
        self.last_tokens = np.zeros((engine_cfg.max_slots,), np.int32)
        self._decode = jax.jit(
            functools.partial(decode_step, cfg=self.cfg))
        self._prefill_cache: Dict[int, Any] = {}
        self._admit_finished: List[Request] = []
        self.steps = 0

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain; returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        self._admit()
        done_now = self._admit_finished
        self._admit_finished = []
        if all(s is None for s in self.slots):
            return done_now
        tokens = jnp.asarray(self.last_tokens)
        logits, self.state = self._decode(
            params=self.params, state=self.state, tokens=tokens)
        self.key, sub = jax.random.split(self.key)
        temps = [s.temperature if s else 0.0 for s in self.slots]
        temp = max(temps)  # per-engine temperature (slots share a sampler)
        next_tok = np.asarray(sample_token(logits, sub, temperature=temp))
        self.steps += 1
        return done_now + self._collect(next_tok)

    # ------------------------------------------------------------- internals
    def _bucket(self, n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return min(b, self.ecfg.capacity)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            cfg, cap = self.cfg, self.ecfg.capacity

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, {"tokens": tokens}, capacity=cap)

            self._prefill_cache[length] = fn
        return self._prefill_cache[length]

    def _admit(self):
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = req.prompt[-self.ecfg.capacity:]
            fn = self._prefill_fn(len(prompt))
            logits, one_state = fn(self.params,
                                   jnp.asarray([prompt], jnp.int32))
            self.state = _merge_slot(self.state, one_state, slot)
            self.key, sub = jax.random.split(self.key)
            tok = int(np.asarray(
                sample_token(logits, sub, temperature=req.temperature))[0])
            req.output.append(tok)
            # the prefill-sampled token may already terminate the request
            hit_eos = (self.ecfg.eos_id is not None
                       and tok == self.ecfg.eos_id)
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self._admit_finished.append(req)
                continue
            self.last_tokens[slot] = tok
            self.slots[slot] = req

    def _collect(self, next_tok: np.ndarray) -> List[Request]:
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_tok[slot])
            req.output.append(tok)
            self.last_tokens[slot] = tok
            hit_eos = self.ecfg.eos_id is not None and tok == self.ecfg.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self.slots[slot] = None
        return finished
