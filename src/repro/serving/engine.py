"""Continuous-batching serving engine: bucketed batched prefill, chunked
prefill interleaved with a fused multi-step decode loop.

Slot-based continuous batching (vLLM-style, adapted to fixed-shape JAX):

  * the batch has `max_slots` fixed slots → one jit'd decode loop for the
    whole fleet of in-flight requests (no recompilation as requests come
    and go);
  * **bucketed admission** — each step the wait queue drains into *all*
    free slots at once; the newly admitted rows (plus any rows still
    consuming their prompt) advance through one `prefill_chunk` dispatch
    whose length is the power-of-two bucket of the longest remaining need,
    capped at ``prefill_chunk``. One compiled function serves every
    admission batch at a given bucket, so the prefill compile cache is
    O(log prefill_chunk) ⊆ O(log capacity) — not one entry per distinct
    prompt length (the PR-1 behavior, kept as `SerialAdmitEngine`);
  * **chunked prefill** — a prompt longer than ``prefill_chunk`` is
    consumed across successive steps, each interleaved with a decode chunk
    for the rows that are already generating: a long prompt no longer
    stalls the in-flight decode fleet. Rows mid-prefill ride through the
    decode dispatch with ``active=False`` (state frozen, cache writes
    dropped), and free/decoding rows ride through the prefill dispatch with
    ``lengths=0`` (complete no-op) — both dispatches keep one fixed shape;
  * finished slots (EOS / max_tokens) are freed immediately and refilled
    from the wait queue on the next step — decode never stalls on
    stragglers.

Decode fast path (PR 1, unchanged): ``decode_chunk`` tokens per host
round-trip via one jitted ``lax.scan`` fusing decode_step + on-device
per-slot sampling, state donated on accelerators, per-slot temperature and
EOS freezing on device.

Works identically for dense and PTQTP-quantized params (`dense` dispatches
on the kernel leaf type), which is the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import unpack_trits
from repro.core.quantize_model import QuantizedKernel
from repro.kernels.ternary_matmul.ops import resolve_backend
from repro.models import (decode_step, init_decode_state, prefill,
                          prefill_chunk)
from repro.models.common import matmul_backend
from repro.serving.sampling import sample_token, sample_tokens


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0        # perf_counter at submit()
    t_first: float = 0.0         # perf_counter at first output token (TTFT)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    capacity: int = 256          # KV-cache length per slot
    eos_id: Optional[int] = None
    seed: int = 0
    decode_chunk: int = 8        # tokens per jitted decode dispatch (K)
    prefill_chunk: int = 64      # max prompt tokens consumed per slot per step
    # decode chunk cap while any slot is mid-prefill: a long prompt reaches
    # its first token in ~L/prefill_chunk short engine steps instead of
    # waiting a full decode chunk between each of its prefill chunks
    # (TTFT-vs-TPOT balance, the chunked-prefill token-budget idea)
    decode_chunk_prefilling: int = 2
    # Pre-unpack trit-planes for the decode loop (None → auto: only when the
    # grouped XLA backend serves the quantized matmuls; the Pallas TPU kernel
    # unpacks in-kernel, where streaming packed planes IS the win). Trades
    # 4x plane bytes (int8 trits vs 2-bit fields, still 2x under fp16) for
    # not re-unpacking every weight at every decode step.
    preunpack_decode: Optional[bool] = None

    def __post_init__(self):
        assert self.max_slots >= 1 and self.capacity >= 1
        assert self.decode_chunk >= 1, "decode_chunk=0 would never emit"
        assert self.prefill_chunk >= 1, "prefill_chunk=0 would never admit"
        assert self.decode_chunk_prefilling >= 1


def _pow2ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _preunpack_params(params):
    """Replace packed QuantizedKernel planes with raw int8 trit-planes.

    The unpack is exact and the grouped einsum consumes either form with the
    identical contraction order, so decode outputs are bit-identical — the
    unpack work just moves from every decode step to engine init.
    """

    def unpack(leaf):
        if isinstance(leaf, QuantizedKernel):
            return dataclasses.replace(
                leaf, t1p=unpack_trits(leaf.t1p), t2p=unpack_trits(leaf.t2p))
        return leaf

    return jax.tree.map(unpack, params,
                        is_leaf=lambda x: isinstance(x, QuantizedKernel))


def _merge_slot_impl(batch_state, one_state, slot):
    """Write a batch=1 decode state into slot `slot` of the batch state.

    Jitted (slot is a traced scalar): one dispatch per admit instead of one
    per state leaf — the leaf-by-leaf eager version dominated admit latency.
    The batch state is donated on accelerators so the one-slot write never
    copies the other slots' KV caches. (Serial-admit path only; the bucketed
    scheduler prefills straight into the batch state and never merges.)
    """

    def walk(dst, src, path):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k], f"{path}/{k}") for k in dst}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=axis).astype(dst.dtype))

    return walk(batch_state, one_state, "")


_merge_jit = None


def _merge_slot(batch_state, one_state, slot):
    """Jitted merge, donation decided lazily (first call, not import time —
    importing this module must not initialize the JAX platform)."""
    global _merge_jit
    if _merge_jit is None:
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _merge_jit = jax.jit(_merge_slot_impl, donate_argnums=donate)
    return _merge_jit(batch_state, one_state, slot)


def _reset_rows_impl(state, mask):
    """Clear the per-row decode state for rows in `mask` (new admissions).

    Ring-cache position leaves reset to -1 (nothing valid), everything else
    (KV, recurrent states, absolute pos) to zero — one fused dispatch no
    matter how many rows reset, so a burst of admits costs one round-trip.
    """

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        shape = [1] * node.ndim
        shape[axis] = node.shape[axis]
        reset = -1 if (path.endswith("/pos") and path != "/pos") else 0
        return jnp.where(mask.reshape(shape),
                         jnp.asarray(reset, node.dtype), node)

    return walk(state, "")


def _decode_loop(params, state, tokens, temps, active, key, *,
                 cfg, n_steps, eos_id):
    """K fused decode steps with on-device per-slot sampling.

    Args:
      tokens: (B,) int32 last token per slot.
      temps:  (B,) f32 per-slot temperature (0 → greedy for that slot).
      active: (B,) bool — decoding slots; inactive slots (free, mid-prefill,
        or EOS-frozen) repeat their token and their state is left untouched.
    Returns:
      (new_state, toks) with toks (n_steps, B) — the sampled token per step.
    """

    def body(carry, _):
        state, tok, active, key = carry
        logits, state = decode_step(params, cfg, state, tok, active)
        key, sub = jax.random.split(key)
        nxt = sample_tokens(logits, sub, temps)
        nxt = jnp.where(active, nxt, tok)  # frozen slots repeat (host drops)
        if eos_id is not None:
            active = jnp.logical_and(active, nxt != eos_id)
        return (state, nxt, active, key), nxt

    # Full unroll: the scan body is op-overhead-bound at decode shapes, and
    # unrolling lets XLA fuse across steps (measured ~40% per-token on CPU).
    (state, _, _, _), toks = jax.lax.scan(
        body, (state, tokens, active, key), None, length=n_steps,
        unroll=min(n_steps, 16))
    return state, toks


class ServingEngine:
    """Bucketed/chunked-prefill scheduler (see module docstring)."""

    def __init__(self, params, model_cfg, engine_cfg: EngineConfig):
        self.params = params
        self.cfg = model_cfg
        self.ecfg = engine_cfg
        self.key = jax.random.PRNGKey(engine_cfg.seed)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_slots
        self.state = init_decode_state(model_cfg, engine_cfg.max_slots,
                                       engine_cfg.capacity)
        self.last_tokens = np.zeros((engine_cfg.max_slots,), np.int32)
        pre = engine_cfg.preunpack_decode
        if pre is None:
            pre = resolve_backend(matmul_backend()) == "grouped"
        # serve-side params: prefill and decode both read these, so the
        # unpack is paid once per engine, not once per dispatch
        self._serve_params = _preunpack_params(params) if pre else params
        self._loop_cache: Dict[int, Any] = {}
        self._prefill_cache: Dict[int, Any] = {}
        self._reset_jit = None
        # per-slot prompt progress: clipped prompt + tokens already consumed
        self._prompts: List[Optional[List[int]]] = [None] * engine_cfg.max_slots
        self._cursor: List[int] = [0] * engine_cfg.max_slots
        self._admit_finished: List[Request] = []
        self._slot_arrays = None  # (temps, active) cache; None → slots dirty
        self.steps = 0           # decode steps dispatched (tokens per slot)
        self.prefill_steps = 0   # prefill_chunk dispatches
        self.admits = 0

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError("empty prompt")
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain; returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    def warmup(self):
        """Precompile every dispatch the engine can ever need.

        Feasible *because* the dispatch set is bounded: prefill buckets are
        the powers of two up to prefill_chunk and decode chunks the powers
        of two up to decode_chunk — a dozen programs, not one per prompt
        length. Every warm call is a semantic no-op on the live state
        (lengths=0 rows / active=False rows / empty reset mask), so warmup
        can run at any point in the engine's life.
        """
        self._warm_prefill()
        nb = len(self.slots)
        chunks = {min(self.ecfg.decode_chunk, n)
                  for n in self._bucket_lengths(self.ecfg.decode_chunk)}
        chunks.add(min(self.ecfg.decode_chunk,
                       self.ecfg.decode_chunk_prefilling))
        idle = jnp.zeros((nb,), bool)
        for n in sorted(chunks):
            self.key, sub = jax.random.split(self.key)
            self.state, _ = self._loop_fn(n)(
                self._serve_params, self.state,
                jnp.asarray(self.last_tokens),
                jnp.zeros((nb,), jnp.float32), idle, sub)
        self._reset_rows(np.zeros((nb,), bool))

    def _warm_prefill(self):
        nb = len(self.slots)
        for length in self._bucket_lengths(self.ecfg.prefill_chunk):
            _, self.state = self._prefill_fn(length)(
                self._serve_params, self.state,
                jnp.zeros((nb, length), jnp.int32),
                jnp.zeros((nb,), jnp.int32))

    @staticmethod
    def _bucket_lengths(top: int) -> List[int]:
        out = [1]
        while out[-1] < _pow2ceil(top):
            out.append(out[-1] * 2)
        return out

    def compile_stats(self) -> Dict[str, Any]:
        """Jit-cache occupancy — the compile-bound story, made observable.

        The bucketed scheduler's prefill entries are power-of-two chunk
        lengths ≤ prefill_chunk, so ``n_prefill_compiles`` is bounded by
        ``prefill_bucket_bound`` = log2(next_pow2(prefill_chunk)) + 1; the
        decode entries are power-of-two chunk lengths ≤ decode_chunk. The
        serial-admit baseline instead caches one prefill entry per distinct
        prompt length (up to `capacity` of them).
        """
        return {
            "prefill_bucket_lengths": sorted(self._prefill_cache),
            "n_prefill_compiles": len(self._prefill_cache),
            "prefill_bucket_bound":
                _pow2ceil(self.ecfg.prefill_chunk).bit_length(),
            "decode_chunk_lengths": sorted(self._loop_cache),
            "n_decode_compiles": len(self._loop_cache),
            "admits": self.admits,
            "prefill_steps": self.prefill_steps,
        }

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """Admit into all free slots, advance prefill one chunk, decode one
        chunk.

        The decode chunk length adapts to the largest remaining token budget
        among decoding slots, rounded up to a power of two (compile count
        stays O(log K)) — a fleet that only needs 3 more tokens never pays
        for a 16-step dispatch.
        """
        self._admit()
        done_now = self._admit_finished
        self._admit_finished = []
        done_now = done_now + self._prefill_step()
        dec = [i for i in range(len(self.slots)) if self._decoding(i)]
        if not dec:
            return done_now
        remaining = max(self.slots[i].max_new_tokens
                        - len(self.slots[i].output) for i in dec)
        chunk = self.ecfg.decode_chunk
        if any(self._prefilling(i) for i in range(len(self.slots))):
            chunk = min(chunk, self.ecfg.decode_chunk_prefilling)
        n_steps = min(chunk, _pow2ceil(remaining))
        self.key, sub = jax.random.split(self.key)
        if self._slot_arrays is None:  # rebuilt only when slots changed
            self._slot_arrays = (
                jnp.asarray([self.slots[i].temperature
                             if self._decoding(i) else 0.0
                             for i in range(len(self.slots))], jnp.float32),
                jnp.asarray([self._decoding(i)
                             for i in range(len(self.slots))]))
        temps, active = self._slot_arrays
        self.state, toks = self._loop_fn(n_steps)(
            self._serve_params, self.state, jnp.asarray(self.last_tokens),
            temps, active, sub)
        self.steps += n_steps
        return done_now + self._collect(np.asarray(toks))

    # ------------------------------------------------------------- internals
    def _prefilling(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] < len(self._prompts[slot]))

    def _decoding(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] >= len(self._prompts[slot]))

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        self._prompts[slot] = None
        self._cursor[slot] = 0
        self._slot_arrays = None

    def _loop_fn(self, n_steps: int):
        if n_steps not in self._loop_cache:
            # Donating the decode state lets XLA update the KV caches in
            # place; CPU has no donation support and would warn per dispatch.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._loop_cache[n_steps] = jax.jit(
                functools.partial(_decode_loop, cfg=self.cfg,
                                  n_steps=n_steps,
                                  eos_id=self.ecfg.eos_id),
                donate_argnums=donate)
        return self._loop_cache[n_steps]

    def _prefill_fn(self, length: int):
        """One jit per power-of-two chunk bucket (O(log prefill_chunk))."""
        if length not in self._prefill_cache:
            cfg = self.cfg
            donate = (1,) if jax.default_backend() != "cpu" else ()

            def impl(params, state, tokens, lengths):
                return prefill_chunk(params, cfg, state, {"tokens": tokens},
                                     lengths)

            self._prefill_cache[length] = jax.jit(impl, donate_argnums=donate)
        return self._prefill_cache[length]

    def _reset_rows(self, mask: np.ndarray):
        if self._reset_jit is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._reset_jit = jax.jit(_reset_rows_impl, donate_argnums=donate)
        self.state = self._reset_jit(self.state, jnp.asarray(mask))

    def _admit(self):
        """Drain the wait queue into *all* free slots in one go."""
        fresh = []
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.slots[slot] = req
            self._prompts[slot] = list(req.prompt[-self.ecfg.capacity:])
            self._cursor[slot] = 0
            fresh.append(slot)
            self.admits += 1
        if fresh:
            mask = np.zeros((len(self.slots),), bool)
            mask[fresh] = True
            self._reset_rows(mask)
            self._slot_arrays = None

    def _prefill_step(self) -> List[Request]:
        """Advance every mid-prompt slot by one bucketed chunk.

        All prefilling rows share one fixed-(B, L) dispatch: L is the
        power-of-two bucket of the longest remaining need this step (capped
        at prefill_chunk); rows with shorter remainders right-pad, rows not
        prefilling ride along with length 0 (no-op). Rows whose prompt
        completes sample their first token here and join the decode fleet
        in the same engine step.
        """
        pf = [i for i in range(len(self.slots)) if self._prefilling(i)]
        if not pf:
            return []
        nb = len(self.slots)
        need = max(min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk) for i in pf)
        length = _pow2ceil(need)
        tokens = np.zeros((nb, length), np.int32)
        lengths = np.zeros((nb,), np.int32)
        for i in pf:
            # never consume more than prefill_chunk per step, even when the
            # pow2 bucket rounds past it (non-pow2 prefill_chunk configs)
            take = min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk)
            tokens[i, :take] = self._prompts[i][
                self._cursor[i]:self._cursor[i] + take]
            lengths[i] = take
        logits, self.state = self._prefill_fn(length)(
            self._serve_params, self.state, jnp.asarray(tokens),
            jnp.asarray(lengths))
        self.prefill_steps += 1
        finishers = [i for i in pf
                     if self._cursor[i] + int(lengths[i])
                     >= len(self._prompts[i])]
        for i in pf:
            self._cursor[i] += int(lengths[i])
        if not finishers:
            return []
        # the prompt's last logits yield the first generated token; one
        # vectorized sample covers every finishing row (per-row temperature)
        self.key, sub = jax.random.split(self.key)
        fin = set(finishers)
        temps = jnp.asarray([self.slots[i].temperature if i in fin else 0.0
                             for i in range(nb)], jnp.float32)
        toks = np.asarray(sample_tokens(logits, sub, temps))
        now = time.perf_counter()
        finished: List[Request] = []
        for i in finishers:
            req = self.slots[i]
            tok = int(toks[i])
            req.output.append(tok)
            req.t_first = req.t_first or now
            # the prefill-sampled token may already terminate the request
            hit_eos = (self.ecfg.eos_id is not None
                       and tok == self.ecfg.eos_id)
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                self._free_slot(i)
            else:
                self.last_tokens[i] = tok
                self._slot_arrays = None
        return finished

    def _collect(self, toks: np.ndarray) -> List[Request]:
        """Fold a (K, B) chunk of tokens into the per-slot requests.

        A slot stops at its first EOS or at its token budget; anything the
        device generated past that point within the chunk is discarded (the
        slot's state is reset by the next admission). Slots still mid-prefill
        took no decode step — their repeated tokens are skipped entirely.
        """
        finished = []
        now = time.perf_counter()
        for slot, req in enumerate(self.slots):
            if req is None or not self._decoding(slot):
                continue
            for k in range(toks.shape[0]):
                tok = int(toks[k, slot])
                req.output.append(tok)
                req.t_first = req.t_first or now
                self.last_tokens[slot] = tok
                hit_eos = (self.ecfg.eos_id is not None
                           and tok == self.ecfg.eos_id)
                if hit_eos or len(req.output) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    self._free_slot(slot)
                    break
        return finished


class SerialAdmitEngine(ServingEngine):
    """The PR-1 admission path, kept as the measured baseline: each arriving
    request is prefilled *alone* through a jit cached per distinct prompt
    length (up to `capacity` compilations) and merged into its slot — the
    whole decode fleet stalls while the queue's prompts are consumed one by
    one. Decode itself is the same fused loop as `ServingEngine`.
    """

    def _warm_prefill(self):
        # Best effort only: compiles the power-of-two prompt lengths, but
        # this engine's jit cache is keyed by *exact* prompt length — any
        # other arriving length still compiles at admission time, which is
        # exactly the TTFT pathology the bucketed scheduler removes.
        for length in self._bucket_lengths(self.ecfg.capacity):
            if length > self.ecfg.capacity:
                break
            self._prefill_len_fn(length)(
                self._serve_params, jnp.zeros((1, length), jnp.int32))

    def _merge(self, batch_state, one_state, slot):
        # hook: the decode benchmark's seed baseline overrides this with the
        # eager leaf-by-leaf merge it measures against
        return _merge_slot(batch_state, one_state, slot)

    def _prefill_len_fn(self, length: int):
        # one jit per distinct prompt length; prompts are clipped to
        # `capacity` on admit, so the cache is bounded by capacity entries
        if length not in self._prefill_cache:
            cfg, cap = self.cfg, self.ecfg.capacity

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, {"tokens": tokens}, capacity=cap)

            self._prefill_cache[length] = fn
        return self._prefill_cache[length]

    def _admit(self):
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.admits += 1
            prompt = req.prompt[-self.ecfg.capacity:]
            fn = self._prefill_len_fn(len(prompt))
            logits, one_state = fn(self._serve_params,
                                   jnp.asarray([prompt], jnp.int32))
            self.state = self._merge(self.state, one_state, slot)
            self.prefill_steps += 1
            self.key, sub = jax.random.split(self.key)
            tok = int(np.asarray(
                sample_token(logits, sub, temperature=req.temperature))[0])
            req.output.append(tok)
            req.t_first = req.t_first or time.perf_counter()
            # the prefill-sampled token may already terminate the request
            hit_eos = (self.ecfg.eos_id is not None
                       and tok == self.ecfg.eos_id)
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self._admit_finished.append(req)
                continue
            self.last_tokens[slot] = tok
            self.slots[slot] = req
            # mark the whole prompt consumed → base class sees a decoding row
            self._prompts[slot] = list(prompt)
            self._cursor[slot] = len(prompt)
            self._slot_arrays = None
