"""Continuous-batching serving engine behind the v1 request API: bucketed
batched prefill, chunked prefill interleaved with a fused multi-step decode
loop, per-request RNG, streaming handles, cancellation.

Request lifecycle (Serving API v1 — see ``repro.serving.api``):

  * ``submit(prompt, SamplingParams(...)) -> RequestHandle`` enqueues; the
    handle exposes ``tokens()`` (a generator that drives ``step()`` on
    demand and yields each token in the engine step that produced it),
    ``result()`` (block until finished), ``cancel()`` (frees the slot
    immediately, mid-prefill or mid-decode), plus ``t_submit/t_first/
    t_done`` and a ``truncated`` flag when the prompt was clipped to
    ``capacity``;
  * ``step()`` advances the whole fleet one engine step (admission +
    prefill chunk + decode chunk) and returns the handles that finished;
  * ``run()`` drives until drained (the batch-caller style; the pre-v1
    ``Request`` record shim is gone after its one PR of grace).

Scheduling (unchanged from PR 2): the batch has ``max_slots`` fixed slots →
one jit'd decode loop for the whole fleet; **bucketed admission** drains the
wait queue into all free slots per step and advances every mid-prompt row by
one power-of-two prefill-chunk bucket in a single fixed-shape dispatch
(prefill compile cache O(log prefill_chunk)); **chunked prefill** interleaves
long prompts with (shortened) decode chunks; finished or cancelled slots free
immediately and refill next step.

Per-request RNG (the v1 determinism contract): each slot carries its
request's ``SamplingParams.seed``; the i-th generated token is drawn with
``fold_in(PRNGKey(seed), i)`` *on device inside the decode scan* (and for
i = 0 by the prefill finisher / serial admitter). No draw touches
engine-global state, so a request's output is a pure function of (params,
prompt, SamplingParams) — invariant to fleet composition, scheduler
(`ServingEngine` vs `SerialAdmitEngine`), and chunk boundaries. Stop-token
ids (``SamplingParams.stop`` ∪ ``EngineConfig.eos_id``) freeze the row
on device and truncate the host-side stream at the first hit, wherever in a
chunk (or in the prefill-finisher sample) it lands.

Paged KV: ``EngineConfig.kv_layout="paged"`` virtualizes every slot's KV
ring into ``page_size``-token physical pages drawn from one shared,
refcounted pool (``repro.serving.paging``), with copy-on-write prefix
sharing keyed by *exact* prompt-prefix token tuples — cache-hit pages are
adopted read-only and their tokens skip prefill entirely. Admission
reserves each request's worst-case page budget up front (including COW
fork targets for wrap-bound requests), so a resident request can never
run out of pages; the v1.2 contract section in ``repro.serving`` states
the determinism guarantee.

Works identically for dense and PTQTP-quantized params (`dense` dispatches
on the kernel leaf type), which is the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import unpack_trits
from repro.core.quantize_model import QuantizedKernel
from repro.kernels.ternary_matmul.ops import resolve_backend
from repro.models import (decode_step, init_decode_state, prefill,
                          prefill_chunk)
from repro.models.common import matmul_backend
from repro.runtime import clock as rtclock
from repro.runtime.monitor import HealthSnapshot
from repro.serving.api import (FINISH_CANCELLED, FINISH_ERROR, FINISH_LENGTH,
                               FINISH_REJECTED, FINISH_STOP, FINISH_TIMEOUT,
                               RequestHandle, SamplingParams, make_handle)
from repro.serving.observability import TRACK_ENGINE, Observability
from repro.serving.paging import PageAllocator
from repro.serving.sampling import request_keys, sample_tokens_per_request

__all__ = ["EngineConfig", "ServingEngine", "SerialAdmitEngine",
           "SamplingParams", "RequestHandle", "EngineFault", "EngineCrash"]


class EngineCrash(RuntimeError):
    """The engine itself died — not a containable per-dispatch fault.

    Unlike :class:`EngineFault`, which ``_contain`` absorbs (retire the
    attributed slot, quarantine, keep stepping), an ``EngineCrash``
    deliberately escapes ``step()``: device state after a crash cannot be
    trusted, so whoever drives the engine (the ``EngineDriver``'s
    ``_fatal`` path) must tear it down and — under an
    ``EngineSupervisor`` — rebuild and replay. ``uid`` blames one request
    when the crasher is known; the engine fills ``suspects`` with the
    uids participating in the dispatch that died (just the blamed uid
    when it was resident), which is what the supervisor's replay
    blacklist keys on."""

    def __init__(self, msg: str, uid: Optional[int] = None):
        super().__init__(msg)
        self.uid = uid
        self.suspects: Tuple[int, ...] = ()


class EngineFault(RuntimeError):
    """A device-dispatch failure attributed (when possible) to one slot.

    Raised by fault injectors and used internally as the containment
    envelope for real dispatch exceptions. ``slot`` is the offending batch
    row, or None when the failure cannot be attributed — in that case every
    request participating in the dispatch is retired (the containment unit
    is the dispatch, never the engine)."""

    def __init__(self, msg: str, slot: Optional[int] = None):
        super().__init__(msg)
        self.slot = slot


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide knobs. Per-request generation behavior (budget,
    temperature, top-k/top-p, seed, stop ids) lives in ``SamplingParams``;
    what remains here is fleet shape and scheduling.

    ``eos_id`` is the engine-wide stop token (tokenizer property, honored
    for every request in addition to its ``SamplingParams.stop``).
    ``attn_backend`` overrides the model's ring-cache attention backend
    (``repro.kernels.chunk_attention``: auto | pallas | stream |
    materialized) for every dispatch this engine compiles — the serving-
    level knob the launcher's ``--attn-backend`` flag sets.
    """

    max_slots: int = 4
    capacity: int = 256          # KV-cache length per slot
    eos_id: Optional[int] = None
    attn_backend: Optional[str] = None
    decode_chunk: int = 8        # tokens per jitted decode dispatch (K)
    prefill_chunk: int = 64      # max prompt tokens consumed per slot per step
    # ---- admission control (None → unbounded, the pre-containment behavior)
    # max_queue caps how many requests may *wait* for a slot; a submit that
    # would exceed it is shed ("reject": the handle comes back already
    # finished with reason "rejected") or blocks ("block": submit drives
    # step() until space frees) — overload degrades to fast rejections or
    # bounded blocking instead of unbounded queue growth.
    max_queue: Optional[int] = None
    # max_resident_tokens caps the committed token footprint (clipped prompt
    # + max_new_tokens budget) summed over queued + resident requests.
    max_resident_tokens: Optional[int] = None
    admission_policy: str = "reject"   # "reject" | "block"
    # how many engine steps a suspect slot sits out before it is row-reset
    # and returned to the admission pool (observable cool-down; None →
    # never automatically, only an explicit engine.rehabilitate())
    quarantine_steps: Optional[int] = 2
    # decode chunk cap while any slot is mid-prefill: a long prompt reaches
    # its first token in ~L/prefill_chunk short engine steps instead of
    # waiting a full decode chunk between each of its prefill chunks
    # (TTFT-vs-TPOT balance, the chunked-prefill token-budget idea)
    decode_chunk_prefilling: int = 2
    # Pre-unpack trit-planes for the decode loop (None → auto: only when the
    # grouped XLA backend serves the quantized matmuls; the Pallas TPU kernel
    # unpacks in-kernel, where streaming packed planes IS the win). Trades
    # 4x plane bytes (int8 trits vs 2-bit fields, still 2x under fp16) for
    # not re-unpacking every weight at every decode step.
    preunpack_decode: Optional[bool] = None
    # ---- paged KV cache ("paged" virtualizes every slot's ring into
    # page_size-token physical pages drawn from one shared pool; "ring" is
    # the contiguous per-slot layout, kept as the baseline and the
    # bit-identity oracle)
    kv_layout: str = "ring"            # "ring" | "paged"
    page_size: int = 16                # tokens per physical page
    # pool size in pages (None → max_slots · capacity/page_size: exactly the
    # ring footprint, so paging alone never reduces admissible load — set it
    # lower to overcommit against prefix sharing)
    max_pages: Optional[int] = None
    prefix_cache: bool = True          # COW prefix reuse across requests

    def __post_init__(self):
        assert self.max_slots >= 1 and self.capacity >= 1
        assert self.decode_chunk >= 1, "decode_chunk=0 would never emit"
        assert self.prefill_chunk >= 1, "prefill_chunk=0 would never admit"
        assert self.decode_chunk_prefilling >= 1
        assert self.admission_policy in ("reject", "block"), \
            self.admission_policy
        assert self.max_queue is None or self.max_queue >= 1
        assert self.max_resident_tokens is None \
            or self.max_resident_tokens >= 1
        assert self.quarantine_steps is None or self.quarantine_steps >= 0
        assert self.kv_layout in ("ring", "paged"), self.kv_layout
        if self.kv_layout == "paged":
            assert self.page_size >= 1
            assert self.capacity % self.page_size == 0, \
                (f"capacity {self.capacity} must be a whole number of "
                 f"pages (page_size {self.page_size})")
            # max_pages below one slot's worth is allowed: requests whose
            # worst case can't fit the pool shed at submit; shorter ones
            # still serve (deliberate overcommit against prefix sharing)
            assert self.max_pages is None or self.max_pages >= 1


def _pow2ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _preunpack_params(params):
    """Replace packed QuantizedKernel planes with raw int8 trit-planes.

    The unpack is exact and the grouped einsum consumes either form with the
    identical contraction order, so decode outputs are bit-identical — the
    unpack work just moves from every decode step to engine init.
    """

    def unpack(leaf):
        if isinstance(leaf, QuantizedKernel):
            return dataclasses.replace(
                leaf, t1p=unpack_trits(leaf.t1p), t2p=unpack_trits(leaf.t2p))
        return leaf

    return jax.tree.map(unpack, params,
                        is_leaf=lambda x: isinstance(x, QuantizedKernel))


def _merge_slot_impl(batch_state, one_state, slot):
    """Write a batch=1 decode state into slot `slot` of the batch state.

    Jitted (slot is a traced scalar): one dispatch per admit instead of one
    per state leaf — the leaf-by-leaf eager version dominated admit latency.
    The batch state is donated on accelerators so the one-slot write never
    copies the other slots' KV caches. (Serial-admit path only; the bucketed
    scheduler prefills straight into the batch state and never merges.)
    """

    def walk(dst, src, path):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k], f"{path}/{k}") for k in dst}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=axis).astype(dst.dtype))

    return walk(batch_state, one_state, "")


_merge_jit = None


def _merge_slot(batch_state, one_state, slot):
    """Jitted merge, donation decided lazily (first call, not import time —
    importing this module must not initialize the JAX platform)."""
    global _merge_jit
    if _merge_jit is None:
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _merge_jit = jax.jit(_merge_slot_impl, donate_argnums=donate)
    return _merge_jit(batch_state, one_state, slot)


def _reset_rows_impl(state, mask, pos0):
    """Clear the per-row decode state for rows in `mask` (new admissions).

    Ring-cache position leaves reset to -1 (nothing valid), everything else
    (KV, recurrent states, page tables) to zero, and the absolute position
    to ``pos0`` (nonzero when a paged admission skips prefix-cached prompt
    pages — the row resumes mid-prompt) — one fused dispatch no matter how
    many rows reset, so a burst of admits costs one round-trip.

    Paged pool leaves (``pages_*``) have no batch axis — they are shared
    physical storage, owned by the host-side :class:`PageAllocator` — so
    they pass through untouched; the engine's page maintenance op clears
    freshly allocated pages instead.
    """

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if path.rsplit("/", 1)[-1].startswith("pages_"):
            return node
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        shape = [1] * node.ndim
        shape[axis] = node.shape[axis]
        if path == "/pos":
            return jnp.where(mask, pos0.astype(node.dtype), node)
        reset = -1 if path.endswith("/pos") else 0
        return jnp.where(mask.reshape(shape),
                         jnp.asarray(reset, node.dtype), node)

    return walk(state, "")


def _page_maint_impl(state, src, dst, clear, tables):
    """One fused dispatch for all device-side page bookkeeping of a step:
    COW copies (``pool[dst] = pool[src]`` on every ``pages_*`` leaf, every
    layer), invalidation of freshly allocated pages (``pages_pos[clear] =
    -1`` — a recycled page's stale positions would otherwise satisfy the
    gather mask), and the authoritative host page-table push. Index args
    are power-of-two padded with 0 by the caller: page 0 is the reserved
    null page, so ``copy 0→0`` and ``clear 0`` are identities.
    """

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        name = path.rsplit("/", 1)[-1]
        if name == "table":
            t = tables.astype(node.dtype)
            return jnp.broadcast_to(t[None], node.shape) if node.ndim == 3 \
                else t
        if not name.startswith("pages_"):
            return node
        axis = 1 if "/blocks/" in path else 0  # stacked pools: (L, P, ...)
        idx = [slice(None)] * node.ndim
        idx[axis] = dst
        node = node.at[tuple(idx)].set(jnp.take(node, src, axis=axis))
        if name == "pages_pos":
            idx[axis] = clear
            node = node.at[tuple(idx)].set(-1)
        return node

    return walk(state, "")


def _decode_loop(params, state, tokens, temps, active, seeds, gen_idx,
                 top_k, top_p, stops, poison, *, cfg, n_steps, use_mask,
                 use_poison=False):
    """K fused decode steps with on-device per-request sampling.

    Args:
      tokens:  (B,) int32 last token per slot.
      temps:   (B,) f32 per-slot temperature (0 → greedy for that row).
      active:  (B,) bool — decoding slots; inactive slots (free, mid-prefill,
        or stop-frozen) repeat their token and their state is left untouched.
      seeds:   (B,) uint32 per-request RNG seed (``SamplingParams.seed``).
      gen_idx: (B,) int32 tokens already generated per request — the i-th
        token draws ``fold_in(PRNGKey(seed), i)``, so resuming a request at
        any chunk boundary continues the identical stream.
      top_k:   (B,) int32, 0 disables per row (traced iff ``use_mask``).
      top_p:   (B,) f32, 1.0 disables per row (traced iff ``use_mask``).
      stops:   (B, W) int32 stop-token ids, -1-padded (W static; a hit
        freezes the row exactly like the pre-v1 EOS check).
      poison:  (B,) int32 fault-injection gen-index per row, -1 = never
        (traced iff ``use_poison``, i.e. only for engines built with a
        fault injector — the production loop compiles it out). When row b's
        gen counter equals ``poison[b]`` its logits are overwritten with
        NaN *on device*, exercising the real non-finite containment path.
    Returns:
      (new_state, (toks, bad)): toks (n_steps, B) — the sampled token per
      step; bad (n_steps, B) bool — True where the row's logits for that
      step were non-finite (the host retires such rows with reason
      ``"error"`` and discards the garbage token). The reduction is a
      per-row ``isfinite`` all — numerics of surviving rows are untouched,
      so adding the health output preserves bit-identity.
    """

    def body(carry, _):
        state, tok, active, gen = carry
        logits, state = decode_step(params, cfg, state, tok, active)
        if use_poison:
            logits = jnp.where((gen == poison)[:, None] & active[:, None],
                               jnp.nan, logits)
        bad = jnp.logical_and(
            active, jnp.logical_not(jnp.all(jnp.isfinite(logits), axis=-1)))
        keys = request_keys(seeds, gen)
        nxt = sample_tokens_per_request(
            logits, keys, temps,
            top_k=top_k if use_mask else None,
            top_p=top_p if use_mask else None)
        nxt = jnp.where(active, nxt, tok)  # frozen slots repeat (host drops)
        gen = gen + active.astype(gen.dtype)
        hit = jnp.any(nxt[:, None] == stops, axis=-1)
        # a poisoned/non-finite row freezes too: its state is garbage from
        # here on and the host is about to retire it anyway
        active = jnp.logical_and(active,
                                 jnp.logical_not(jnp.logical_or(hit, bad)))
        return (state, nxt, active, gen), (nxt, bad)

    # Full unroll: the scan body is op-overhead-bound at decode shapes, and
    # unrolling lets XLA fuse across steps (measured ~40% per-token on CPU).
    (state, _, _, _), (toks, bad) = jax.lax.scan(
        body, (state, tokens, active, gen_idx), None, length=n_steps,
        unroll=min(n_steps, 16))
    return state, (toks, bad)


class ServingEngine:
    """Bucketed/chunked-prefill scheduler behind the v1 handle API (see
    module docstring).

    ``injector`` (optional) is a fault-injection hook implementing the
    :class:`repro.serving.faults.FaultInjector` protocol: it may substitute
    the engine's clock (deterministic deadline tests), raise from a chosen
    dispatch, and poison chosen rows' logits with NaN on device. Production
    engines pass None and compile the poison input out entirely.

    ``observability`` (optional) is a :class:`repro.serving.observability.
    Observability` bundle; the engine always carries one (constructing a
    registry-only default when unconfigured), adopts it onto its own clock,
    and registers the frozen serving metric set against its bookkeeping
    counters. Pass ``Observability(trace=True)`` to also record the
    lifecycle/phase trace. All instrumentation is host-side around (never
    inside) the compiled dispatches: tokens are bit-identical with tracing
    on, off, or unconfigured, and no new compile-cache axis exists.
    """

    def __init__(self, params, model_cfg, engine_cfg: EngineConfig, *,
                 injector=None, observability: Optional[Observability] = None):
        self.params = params
        if engine_cfg.attn_backend is not None:
            model_cfg = dataclasses.replace(
                model_cfg, attn_backend=engine_cfg.attn_backend)
        self.cfg = model_cfg
        self.ecfg = engine_cfg
        self.queue: deque[RequestHandle] = deque()
        self.slots: List[Optional[RequestHandle]] = [None] * engine_cfg.max_slots
        # ---- paged KV layout (see _plan_pages for the admission story)
        self.paged = engine_cfg.kv_layout == "paged"
        kv_spec = None
        if self.paged:
            ps = engine_cfg.page_size
            self._per_slot = engine_cfg.capacity // ps
            total = engine_cfg.max_pages
            if total is None:
                total = engine_cfg.max_slots * self._per_slot
            kinds = (tuple(model_cfg.prefix_pattern)
                     + tuple(model_cfg.block_pattern)
                     + tuple(model_cfg.remainder_pattern))
            # prefix reuse splices cached KV pages under a later request —
            # sound only when attention is the *only* stateful mixer (a
            # recurrent rwkv/rglru state summarizes every prior token and
            # cannot skip the shared prefix), so it auto-disables otherwise
            attn_only = all(k != "rwkv" and not k.startswith("rglru")
                            for k in kinds)
            self._prefix_reuse = engine_cfg.prefix_cache and attn_only
            self.alloc = PageAllocator(total, ps,
                                       prefix_cache=self._prefix_reuse)
            # host-authoritative logical→physical page map per slot; pushed
            # to the device "table" leaves by _page_maintenance
            self._tables = np.zeros((engine_cfg.max_slots, self._per_slot),
                                    np.int32)
            self._tables_dirty = False
            self._registered = [0] * engine_cfg.max_slots
            self._cacheable = [False] * engine_cfg.max_slots
            # COW fork targets pre-reserved at admission (so a wrap-time
            # fork can never fail mid-request)
            self._reserve: List[List[int]] = \
                [[] for _ in range(engine_cfg.max_slots)]
            self._maint_jit = None
            kv_spec = {"page_size": ps, "max_pages": total}
        else:
            self.alloc = None
            self._prefix_reuse = False
        self.state = init_decode_state(model_cfg, engine_cfg.max_slots,
                                       engine_cfg.capacity, kv_spec=kv_spec)
        self.last_tokens = np.zeros((engine_cfg.max_slots,), np.int32)
        pre = engine_cfg.preunpack_decode
        if pre is None:
            pre = resolve_backend(matmul_backend()) == "grouped"
        # serve-side params: prefill and decode both read these, so the
        # unpack is paid once per engine, not once per dispatch
        self._serve_params = _preunpack_params(params) if pre else params
        self.preunpack_decode = pre
        self._loop_cache: Dict[Tuple[int, bool, int, bool], Any] = {}
        self._prefill_cache: Dict[int, Any] = {}
        self._reset_jit = None
        # per-slot prompt progress: clipped prompt + tokens already consumed
        self._prompts: List[Optional[List[int]]] = [None] * engine_cfg.max_slots
        self._cursor: List[int] = [0] * engine_cfg.max_slots
        self._admit_finished: List[RequestHandle] = []
        self._slot_arrays = None  # fleet array cache; None → slots dirty
        self._next_uid = 0
        self.steps = 0           # decode steps dispatched (tokens per slot)
        self.prefill_steps = 0   # prefill_chunk dispatches
        self.admits = 0
        # ---- fault containment / admission control state
        self._injector = injector
        clock = getattr(injector, "clock", None) if injector else None
        self._clock = clock if clock is not None else rtclock.MONOTONIC
        # suspect slots → engine step at which they may auto-rehabilitate
        self.quarantined: Dict[int, int] = {}
        self.engine_steps = 0    # step() calls (injector schedule index)
        self._dispatch_counts = {"prefill": 0, "decode": 0}
        self.completed = 0       # finished stop/length
        self.cancelled = 0
        self.sheds = 0           # rejected at submit
        self.timeouts = 0        # retired by the deadline sweep
        self.errors = 0          # retired by fault containment
        # ---- observability (registry always on; tracing only when asked)
        self.submitted = 0           # submit() calls accepted
        self.tokens_generated = 0    # tokens delivered to outputs
        self.prefill_tokens = 0      # prompt tokens consumed by prefill
        self.obs = observability if observability is not None \
            else Observability()
        # the engine's clock (a VirtualClock under an injector) owns every
        # timestamp, including the bundle's spans and histogram observations
        self.obs.clock = self._clock
        self.obs.bind_engine(self)

    # ------------------------------------------------------------------ API
    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               uid: Optional[int] = None) -> RequestHandle:
        """Enqueue a request; returns its :class:`RequestHandle`.

        ``prompt`` is a token-id list; ``params`` is its
        ``SamplingParams`` (default greedy).

        Admission control: when ``EngineConfig.max_queue`` or
        ``max_resident_tokens`` is set and accepting this request would
        exceed it, the request is **shed** — under policy ``"reject"`` the
        handle returns already finished with reason ``"rejected"`` (a fast,
        bounded failure the caller can retry elsewhere); under ``"block"``
        submit drives ``step()`` until the fleet drains enough to accept.
        """
        if uid is None:
            uid, self._next_uid = self._next_uid, self._next_uid + 1
        h = make_handle(self, prompt, params, uid)
        self._next_uid = max(self._next_uid, h.uid + 1)  # explicit uids must
        # not collide with auto-assigned ones
        h.t_submit = self._clock()  # the engine clock owns all timestamps
        self.submitted += 1
        stop = frozenset(h.params.stop)
        if self.ecfg.eos_id is not None:
            stop |= {self.ecfg.eos_id}
        h._stop_ids = stop
        # the truncation that _admit will apply, surfaced at submit time
        h.truncated = len(h.prompt) > self.ecfg.capacity
        self.obs.request_submitted(h)
        never_fits = (self.ecfg.max_resident_tokens is not None
                      and self._committed_tokens(h)
                      > self.ecfg.max_resident_tokens)
        if self.paged and self._worst_pages(h) > self.alloc.n_pages:
            # an empty pool could not hold its worst case: shed now rather
            # than let the queue head wait for pages that can never free
            h.error = (f"page budget ({self._worst_pages(h)} worst-case "
                       f"pages > pool of {self.alloc.n_pages})")
            self._finish(h, FINISH_REJECTED, self._clock())
            return h
        if not self._admissible(h):
            if self.ecfg.admission_policy == "reject" or never_fits:
                # never_fits: blocking would spin forever — an empty engine
                # still could not hold it, so shed regardless of policy
                h.error = self._overload_reason(h)
                self._finish(h, FINISH_REJECTED, self._clock())
                return h
            while not self._admissible(h):  # "block": bounded latency is
                if not self.queue and all(s is None for s in self.slots):
                    # fully drained and still over cap: blocking could never
                    # succeed (e.g. every slot quarantined), so shed instead
                    h.error = self._overload_reason(h)
                    self._finish(h, FINISH_REJECTED, self._clock())
                    return h
                self.step()                 # traded for progress-coupled wait
        self.queue.append(h)
        return h

    def _committed_tokens(self, h: RequestHandle) -> int:
        """Token footprint a request commits the engine to: its clipped
        prompt plus its full generation budget."""
        return min(len(h.prompt), self.ecfg.capacity) + h.params.max_new_tokens

    def resident_tokens(self) -> int:
        """Committed tokens across queued + resident requests (the load
        number ``max_resident_tokens`` caps)."""
        live = list(self.queue) + [s for s in self.slots if s is not None]
        return sum(self._committed_tokens(h) for h in live)

    @property
    def clock(self):
        """The engine's injectable clock (``repro.runtime.clock`` duck type;
        a ``VirtualClock`` under a fault injector). Frontend layers stamp
        their timestamps through this so every layer shares one time base."""
        return self._clock

    def free_admissible_slots(self) -> int:
        """Slots a new admission could take right now (free and not
        quarantined) — what the frontend scheduler meters offers against."""
        return sum(1 for i, s in enumerate(self.slots)
                   if s is None and i not in self.quarantined)

    def _admissible(self, h: RequestHandle) -> bool:
        if self.ecfg.max_queue is not None \
                and len(self.queue) >= self.ecfg.max_queue:
            return False
        if self.ecfg.max_resident_tokens is not None \
                and self.resident_tokens() + self._committed_tokens(h) \
                > self.ecfg.max_resident_tokens:
            return False
        return True

    def _overload_reason(self, h: RequestHandle) -> str:
        if self.ecfg.max_queue is not None \
                and len(self.queue) >= self.ecfg.max_queue:
            return (f"queue full ({len(self.queue)}/{self.ecfg.max_queue} "
                    "waiting)")
        return (f"resident-token cap ({self.resident_tokens()} committed + "
                f"{self._committed_tokens(h)} requested > "
                f"{self.ecfg.max_resident_tokens})")

    # -------------------------------------------------- paged KV internals
    def _worst_pages(self, h: RequestHandle) -> int:
        """Worst-case physical pages a request can ever hold at once: its
        committed tokens in pages, clipped to the slot's logical ring (a
        wrapping request reuses its own pages). This is exactly what
        admission reserves — shared prefix pages reduce *fresh* demand but
        wrap-bound requests pre-reserve matching COW fork targets, so the
        pool draw is this number regardless of cache luck."""
        ps = self.ecfg.page_size
        return min(-(-self._committed_tokens(h) // ps), self._per_slot)

    def _plan_pages(self, h: RequestHandle):
        """Reserve the whole worst-case page budget for ``h`` up front, or
        return None if the pool can't cover it yet (the queue head then
        waits — FIFO, nothing jumps it).

        Returns (prompt, shared, fresh, reserve, cacheable):
          shared   — prefix-cache pages adopted read-only (logical pages
                     0..len(shared)-1; their tokens skip prefill entirely);
          fresh    — private pages for the rest of the logical ring;
          reserve  — unmapped COW fork targets, one per shared page, taken
                     only when generation will wrap the ring (every shared
                     page is then eventually overwritten and must fork —
                     reserving at admission makes the fork infallible);
          cacheable — whether this row's own prompt pages may be published
                     (truncated prompts never: their page keys would claim
                     tokens the row didn't see; wrap-bound rows never:
                     their prompt pages get overwritten by generation).

        The skipped-prefix length is trimmed to a multiple of
        ``prefill_chunk`` so a warm run replays the cold run's exact
        prefill dispatch sequence from the skip point — chunk boundaries,
        and therefore logits, stay deterministic under cache hits.
        """
        ps, cap = self.ecfg.page_size, self.ecfg.capacity
        prompt = list(h.prompt[-cap:])
        plen = len(prompt)
        will_wrap = plen + h.params.max_new_tokens > cap
        n_req = self._worst_pages(h)
        shared: List[int] = []
        n_keys = 0
        if self._prefix_reuse and not h.truncated:
            # page j is lookup-able iff fully prompt-filled; at least one
            # token always prefills (the finisher samples from the last
            # prompt position's logits)
            n_keys = (plen - 1) // ps
            shared = self.alloc.cache_lookup(
                [tuple(prompt[:(j + 1) * ps]) for j in range(n_keys)])
            chunk = self.ecfg.prefill_chunk
            while shared and (len(shared) * ps) % chunk:
                self.alloc.release(shared.pop())  # determinism trim
        need = n_req - len(shared) + (len(shared) if will_wrap else 0)
        if self.alloc.available() < need:
            for pid in shared:
                self.alloc.release(pid)
            return None
        fresh = self.alloc.alloc(need)
        reserve = fresh[n_req - len(shared):]
        fresh = fresh[:n_req - len(shared)]
        self.alloc.hits += len(shared)
        self.alloc.misses += 1 if n_keys > len(shared) else 0
        cacheable = (self._prefix_reuse and not h.truncated
                     and not will_wrap)
        return prompt, shared, fresh, reserve, cacheable

    def _page_maintenance(self, copies=(), clear=()):
        """Apply COW copies + fresh-page invalidation on device and push
        the host page tables (one fused jitted dispatch; index operands are
        power-of-two padded with the null page so compile count stays
        O(log pool))."""
        def pad(ids):
            out = list(ids)
            out += [0] * (_pow2ceil(max(len(out), 1)) - len(out))
            return jnp.asarray(out, jnp.int32)

        if self._maint_jit is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._maint_jit = jax.jit(_page_maint_impl,
                                      donate_argnums=donate)
        with self.obs.span("page_maint",
                           args={"copies": len(copies), "clear": len(clear)}):
            self.state = self._maint_jit(
                self.state, pad([s for s, _ in copies]),
                pad([d for _, d in copies]), pad(clear),
                jnp.asarray(self._tables))
        self._tables_dirty = False

    def _fork_writes(self, spans):
        """Copy-on-write, before the dispatch that writes: for each
        upcoming write span (slot, first position, token count), any
        touched logical page whose physical page is shared (ref > 1 — held
        by the prefix cache and/or another slot) forks to this row's
        pre-reserved target; readers keep the original bit-for-bit.
        Spans are worst case (a row may freeze mid-chunk): a wasted fork
        costs one page copy, never correctness."""
        ps = self.ecfg.page_size
        copies = []
        for slot, start, n in spans:
            if n <= 0:
                continue
            for p in range(start // ps, (start + n - 1) // ps + 1):
                j = p % self._per_slot
                pid = int(self._tables[slot, j])
                if pid == 0 or self.alloc.ref[pid] <= 1:
                    continue
                new = self._reserve[slot].pop()
                self._tables[slot, j] = new
                self._tables_dirty = True
                copies.append((pid, new))
                self.alloc.release(pid)
                self.alloc.forks += 1
        if copies:
            self._page_maintenance(copies=copies)

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a request (``RequestHandle.cancel`` delegates here).

        Queued → removed before it ever admits; resident → its slot frees
        *immediately*, mid-prefill or mid-decode, and the next admission
        reuses it (the admission row-reset clears whatever the cancelled
        request left in the KV cache, so neighbors never see it). Already
        finished → no-op, returns False.
        """
        if handle.done:
            return False
        try:
            self.queue.remove(handle)
        except ValueError:
            slot = next((i for i, h in enumerate(self.slots) if h is handle),
                        None)
            if slot is None:
                return False  # not ours
            self._free_slot(slot)
        self._finish(handle, FINISH_CANCELLED, self._clock())
        return True

    def run(self, max_steps: int = 10_000) -> List[RequestHandle]:
        """Drive until queue + slots drain; returns the finished handles.
        Cancelled requests are not returned."""
        finished: List[RequestHandle] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    def warmup(self):
        """Precompile every dispatch the engine can ever need.

        Feasible *because* the dispatch set is bounded: prefill buckets are
        the powers of two up to prefill_chunk and decode chunks the powers
        of two up to decode_chunk, each in a masked (top-k/top-p fleet) and
        unmasked sampling variant — a few dozen programs, not one per
        prompt length. (The only lazily compiled stragglers are stop-set
        width buckets > 1, for fleets using multi-token ``stop`` sets.)
        Every warm call is a semantic no-op on the live state (lengths=0
        rows / active=False rows / empty reset mask), so warmup can run at
        any point in the engine's life.
        """
        self._warm_prefill()
        nb = len(self.slots)
        chunks = {min(self.ecfg.decode_chunk, n)
                  for n in self._bucket_lengths(self.ecfg.decode_chunk)}
        chunks.add(min(self.ecfg.decode_chunk,
                       self.ecfg.decode_chunk_prefilling))
        idle = jnp.zeros((nb,), bool)
        z32 = jnp.zeros((nb,), jnp.int32)
        use_poison = self._injector is not None
        for n in sorted(chunks):
            for masked in (False, True):
                self.state, _ = self._loop_fn(n, masked, 1, use_poison)(
                    self._serve_params, self.state,
                    jnp.asarray(self.last_tokens),
                    jnp.zeros((nb,), jnp.float32), idle,
                    jnp.zeros((nb,), jnp.uint32), z32, z32,
                    jnp.ones((nb,), jnp.float32),
                    jnp.full((nb, 1), -1, jnp.int32),
                    jnp.full((nb,), -1, jnp.int32))
        self._reset_rows(np.zeros((nb,), bool))

    def _warm_prefill(self):
        nb = len(self.slots)
        for length in self._bucket_lengths(self.ecfg.prefill_chunk):
            _, self.state = self._prefill_fn(length)(
                self._serve_params, self.state,
                jnp.zeros((nb, length), jnp.int32),
                jnp.zeros((nb,), jnp.int32))

    @staticmethod
    def _bucket_lengths(top: int) -> List[int]:
        out = [1]
        while out[-1] < _pow2ceil(top):
            out.append(out[-1] * 2)
        return out

    def compile_stats(self) -> Dict[str, Any]:
        """Jit-cache occupancy — the compile-bound story, made observable.

        The bucketed scheduler's prefill entries are power-of-two chunk
        lengths ≤ prefill_chunk, so ``n_prefill_compiles`` is bounded by
        ``prefill_bucket_bound`` = log2(next_pow2(prefill_chunk)) + 1; the
        decode entries are (power-of-two chunk length ≤ decode_chunk,
        masked-sampling?, stop-width bucket, poison-injection?) quadruples
        — the last axis only ever True under a fault injector, so the
        production cache stays the PR-5 triple set. The serial-admit
        baseline instead caches one prefill entry per distinct prompt
        length (up to `capacity` of them).
        """
        return {
            "prefill_bucket_lengths": sorted(self._prefill_cache),
            "n_prefill_compiles": len(self._prefill_cache),
            "prefill_bucket_bound":
                _pow2ceil(self.ecfg.prefill_chunk).bit_length(),
            "decode_chunk_lengths": sorted({k[0] for k in self._loop_cache}),
            "n_decode_compiles": len(self._loop_cache),
            "admits": self.admits,
            "prefill_steps": self.prefill_steps,
        }

    def memory_stats(self) -> Dict[str, Any]:
        """Resident serving-state byte accounting (the boot-breakdown /
        attention-memory-bench numbers, computed not estimated).

        ``preunpack_decode`` trades plane bytes for per-step unpack work:
        the resident planes are raw int8 trits (1 byte/trit) instead of the
        packed 2-bit fields (0.25 byte/trit), so ``resident_plane_bytes``
        is 4x ``packed_plane_bytes`` while it is on — and a bench that only
        counted the packed artifact would understate resident state by
        exactly that ratio. ``decode_state_bytes`` is the live batch state
        (KV rings + recurrent states + positions) at this engine's
        (max_slots, capacity).
        """
        def plane_bytes(tree) -> int:
            return sum(
                int(leaf.t1p.nbytes) + int(leaf.t2p.nbytes)
                for leaf in jax.tree.leaves(
                    tree, is_leaf=lambda x: isinstance(x, QuantizedKernel))
                if isinstance(leaf, QuantizedKernel))

        packed = plane_bytes(self.params)
        resident = plane_bytes(self._serve_params)
        param_bytes = sum(int(x.nbytes)
                          for x in jax.tree.leaves(self._serve_params))
        state_bytes = sum(int(x.nbytes) for x in jax.tree.leaves(self.state))
        out = {
            "preunpack_decode": self.preunpack_decode,
            "packed_plane_bytes": packed,
            "resident_plane_bytes": resident,
            "preunpack_ratio": (resident / packed) if packed else 1.0,
            "param_bytes": param_bytes,
            "decode_state_bytes": state_bytes,
            "resident_total_bytes": param_bytes + state_bytes,
            "kv_layout": self.ecfg.kv_layout,
        }
        out.update(self._kv_bytes())
        return out

    def _kv_bytes(self) -> Dict[str, Any]:
        """KV-cache byte accounting by leaf name. Under the ring layout the
        whole allocation is resident per slot; under paging only *used*
        pages hold live KV — ``kv_resident_bytes`` is what a request
        actually costs, the number the paged-KV bench turns into
        requests/GB."""
        pool_bytes = table_bytes = kv_bytes = 0
        n_phys = 1

        def walk(node, path):
            nonlocal pool_bytes, table_bytes, kv_bytes, n_phys
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{path}/{k}")
                return
            name = path.rsplit("/", 1)[-1]
            if name.startswith("pages_"):
                pool_bytes += int(node.nbytes)
                n_phys = node.shape[1 if "/blocks/" in path else 0]
            elif name == "table":
                table_bytes += int(node.nbytes)
            elif name in ("k", "v", "k_scale", "v_scale") \
                    or (name == "pos" and path != "/pos"):
                kv_bytes += int(node.nbytes)

        walk(self.state, "")
        if not self.paged:
            return {"kv_pool_bytes": kv_bytes, "kv_resident_bytes": kv_bytes}
        per_page = pool_bytes // n_phys  # one physical page, all layers
        return {"kv_pool_bytes": pool_bytes + table_bytes,
                "kv_page_bytes": per_page,
                # used pages + the always-resident null page + the tables
                "kv_resident_bytes":
                    per_page * (self.alloc.used_pages() + 1) + table_bytes}

    # ----------------------------------------------------------------- step
    def step(self) -> List[RequestHandle]:
        """Sweep deadlines, admit into all free slots, advance prefill one
        chunk, decode one chunk; returns the requests that finished this
        step (including ones retired by the sweep or fault containment).

        The decode chunk length adapts to the largest remaining token budget
        among decoding slots, rounded up to a power of two (compile count
        stays O(log K)) — a fleet that only needs 3 more tokens never pays
        for a 16-step dispatch.
        """
        obs = self.obs
        t_step0, tok0, churn0 = self._step_begin()
        self.engine_steps += 1
        if self._injector is not None:
            self._injector.on_step(self)
        with obs.span("sweep"):
            done_now = self._sweep_deadlines()
            self._auto_rehabilitate()
        with obs.span("admit"):
            self._admit()
        done_now += self._admit_finished
        self._admit_finished = []
        done_now = done_now + self._prefill_step()
        dec = [i for i in range(len(self.slots)) if self._decoding(i)]
        if not dec:
            self._step_end(t_step0, tok0, churn0)
            return done_now
        remaining = max(self.slots[i].params.max_new_tokens
                        - len(self.slots[i].output) for i in dec)
        chunk = self.ecfg.decode_chunk
        if any(self._prefilling(i) for i in range(len(self.slots))):
            chunk = min(chunk, self.ecfg.decode_chunk_prefilling)
        n_steps = min(chunk, _pow2ceil(remaining))
        if self.paged:
            # decode writes positions pos..pos+n_steps-1 (worst case); a
            # wrapping row is about to overwrite its oldest pages, which
            # may be cache-shared prefix — fork them first (COW)
            self._fork_writes(
                [(i, len(self._prompts[i]) + len(self.slots[i].output) - 1,
                  n_steps) for i in dec])
            if self._tables_dirty:
                self._page_maintenance()
        (temps, active, seeds, top_k, top_p, stops), use_mask, stop_w = \
            self._fleet_arrays()
        # tokens generated so far per row: the on-device draw for a row's
        # i-th token always uses fold_in(PRNGKey(seed), i), independent of
        # where the chunk boundaries fell
        gen0 = jnp.asarray([len(self.slots[i].output) if self._decoding(i)
                            else 0 for i in range(len(self.slots))], jnp.int32)
        use_poison = self._injector is not None
        poison = self._poison_array(gen0, n_steps) if use_poison \
            else jnp.full((len(self.slots),), -1, jnp.int32)
        try:
            self._guard_dispatch("decode", dec)
            with obs.span("decode_dispatch",
                          args={"n_steps": n_steps, "rows": len(dec)}):
                self.state, (toks, bad) = self._loop_fn(
                    n_steps, use_mask, stop_w, use_poison)(
                    self._serve_params, self.state,
                    jnp.asarray(self.last_tokens),
                    temps, active, seeds, gen0, top_k, top_p, stops, poison)
        except EngineCrash as exc:  # engine death escapes containment
            self._attribute_crash(exc, dec)
            raise
        except Exception as exc:  # containment unit: this dispatch only
            done_now = done_now + self._contain("decode", dec, exc)
            self._step_end(t_step0, tok0, churn0)
            return done_now
        self.steps += n_steps
        with obs.span("decode_sync"):
            toks_np, bad_np = np.asarray(toks), np.asarray(bad)
        with obs.span("collect"):
            done_now = done_now + self._collect(toks_np, bad_np)
        self._step_end(t_step0, tok0, churn0)
        return done_now

    def _step_begin(self) -> Tuple[float, int, int]:
        churn = (self.alloc.allocs + self.alloc.releases) if self.paged else 0
        return self._clock(), self.tokens_generated, churn

    def _step_end(self, t0: float, tok0: int, churn0: int):
        """Per-step observations (always on — host-side arithmetic only):
        step duration, tokens delivered this step, page churn this step,
        plus the enclosing "step" trace span when tracing."""
        obs = self.obs
        now = self._clock()
        obs.h_step.observe(now - t0)
        obs.h_tokens_step.observe(self.tokens_generated - tok0)
        if self.paged:
            obs.h_page_churn.observe(
                self.alloc.allocs + self.alloc.releases - churn0)
        if obs.trace is not None:
            obs.trace.complete("step", TRACK_ENGINE, t0, now, cat="engine",
                               args={"engine_step": self.engine_steps})

    # ------------------------------------------------- deadlines / containment
    def _expired(self, h: RequestHandle, now: float) -> Optional[str]:
        p = h.params
        if p.deadline_s is not None and now - h.t_submit > p.deadline_s:
            return f"deadline_s={p.deadline_s} exceeded"
        if p.ttft_deadline_s is not None and not h.t_first \
                and now - h.t_submit > p.ttft_deadline_s:
            return f"ttft_deadline_s={p.ttft_deadline_s} exceeded"
        return None

    def _sweep_deadlines(self) -> List[RequestHandle]:
        """Retire every queued or resident request past its deadline with
        frozen reason ``"timeout"``. Freed slots are reusable at this very
        step's admission; neighbors are bit-unperturbed (the same guarantee
        cancellation gives — retirement only ever *removes* a row)."""
        now = self._clock()
        out: List[RequestHandle] = []
        for h in list(self.queue):
            why = self._expired(h, now)
            if why is not None:
                self.queue.remove(h)
                h.error = why
                self._finish(h, FINISH_TIMEOUT, now)
                out.append(h)
        for slot, h in enumerate(self.slots):
            if h is None:
                continue
            why = self._expired(h, now)
            if why is not None:
                self._free_slot(slot)
                h.error = why
                self._finish(h, FINISH_TIMEOUT, now)
                out.append(h)
        return out

    def _poison_array(self, gen0, n_steps: int):
        """(B,) int32 gen-index at which to NaN each row's logits, -1 =
        never (asked of the injector per decode dispatch)."""
        nb = len(self.slots)
        poison = np.full((nb,), -1, np.int32)
        g = np.asarray(gen0)
        for i in range(nb):
            if not self._decoding(i):
                continue
            k = self._injector.poison_index(self.slots[i].uid, int(g[i]),
                                            n_steps)
            if k is not None:
                poison[i] = k
        return jnp.asarray(poison)

    def _guard_dispatch(self, kind: str, slots: List[int]):
        """Count the dispatch and let the injector veto it (raising
        :class:`EngineFault`) — injected faults fire *before* the device
        call so the batch state is never half-written."""
        idx = self._dispatch_counts[kind]
        self._dispatch_counts[kind] = idx + 1
        if self._injector is not None:
            self._injector.before_dispatch(self, kind, idx, slots)

    def _attribute_crash(self, exc: "EngineCrash", slots: List[int]) -> None:
        """Stamp an escaping :class:`EngineCrash` with its suspects: the
        blamed uid when it is resident in the dying dispatch, else every
        participating row — the supervisor retires/blacklists from this."""
        if exc.suspects:
            return
        uids = [self.slots[i].uid for i in slots if self.slots[i] is not None]
        if exc.uid is not None and exc.uid in uids:
            exc.suspects = (exc.uid,)
        else:
            exc.suspects = tuple(uids)

    def _contain(self, kind: str, slots: List[int],
                 exc: Exception) -> List[RequestHandle]:
        """Quarantine a failed dispatch to the offending request/slot.

        An :class:`EngineFault` carrying a slot retires exactly that
        request; an unattributed exception retires every request that
        participated in the dispatch (the honest containment unit — their
        rows' states cannot be trusted). Either way the slot(s) are marked
        suspect and leave the admission pool until :meth:`rehabilitate`,
        and the engine keeps stepping: the dispatch that failed was never
        applied, so surviving rows retry it untouched next step.
        """
        hit = getattr(exc, "slot", None)
        bad_slots = [hit] if hit is not None and hit in slots else list(slots)
        now = self._clock()
        out: List[RequestHandle] = []
        for slot in bad_slots:
            h = self.slots[slot]
            if h is None:
                continue
            self._free_slot(slot)
            self._quarantine(slot)
            h.error = f"{kind} dispatch failed: {exc!r}"
            self._finish(h, FINISH_ERROR, now)
            out.append(h)
        return out

    def _quarantine(self, slot: int):
        cool = self.ecfg.quarantine_steps
        until = (self.engine_steps + cool) if cool is not None else -1
        self.quarantined[slot] = until

    def _restore(self, slots: List[int]):
        mask = np.zeros((len(self.slots),), bool)
        mask[slots] = True
        self._reset_rows(mask)
        for s in slots:
            self.quarantined.pop(s, None)
        self._slot_arrays = None

    def _auto_rehabilitate(self):
        """Return suspect slots whose cool-down elapsed to the pool (after
        a row reset). ``quarantine_steps=None`` disables — only an explicit
        :meth:`rehabilitate` restores them."""
        if self.ecfg.quarantine_steps is None:
            return
        due = [s for s, until in self.quarantined.items()
               if self.engine_steps >= until]
        if due:
            self._restore(due)

    def rehabilitate(self) -> List[int]:
        """Row-reset every quarantined slot and return it to the admission
        pool immediately; returns the slots restored. (The operator
        override of the ``quarantine_steps`` cool-down.)"""
        back = sorted(self.quarantined)
        if back:
            self._restore(back)
        return back

    def health(self) -> HealthSnapshot:
        """Current engine health (see :class:`repro.runtime.monitor.
        HealthSnapshot`); cheap — every field is a read of the same
        registry counters/gauges the observability bundle exports, so a
        snapshot and a metrics scrape can never disagree."""
        reg = self.obs.registry
        pages = {}
        if self.paged:
            pages = dict(
                pages_free=reg.value("serving_pages_free"),
                pages_used=reg.value("serving_pages_used"),
                pages_shared=reg.value("serving_pages_shared"),
                prefix_hits=reg.value("serving_prefix_hits_total"),
                prefix_misses=reg.value("serving_prefix_misses_total"),
                prefix_evictions=reg.value("serving_prefix_evictions_total"))
        return HealthSnapshot(
            t=self._clock(), steps=self.steps,
            queue_depth=reg.value("serving_queue_depth"),
            resident=reg.value("serving_resident_slots"),
            free_slots=reg.value("serving_free_slots"),
            quarantined_slots=tuple(sorted(self.quarantined)),
            resident_tokens=reg.value("serving_resident_tokens"),
            completed=reg.value("serving_requests_completed_total"),
            cancelled=reg.value("serving_requests_cancelled_total"),
            sheds=reg.value("serving_requests_shed_total"),
            timeouts=reg.value("serving_requests_timeout_total"),
            errors=reg.value("serving_requests_error_total"),
            **pages)

    # ------------------------------------------------------------- internals
    def _prefilling(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] < len(self._prompts[slot]))

    def _decoding(self, slot: int) -> bool:
        return (self.slots[slot] is not None
                and self._cursor[slot] >= len(self._prompts[slot]))

    def _free_slot(self, slot: int):
        if self.paged and self.slots[slot] is not None:
            # retirement — every retirement path (finish, cancel, timeout,
            # error containment) funnels through here, so pages always
            # return: table refs drop (cache-held pages survive at ref 1,
            # evictable; private pages free instantly), unused COW
            # reserves free, and the device table row goes stale-but-
            # harmless (lengths-0/inactive rows are fully masked) until
            # the next maintenance push
            for pid in self._tables[slot]:
                if pid:
                    self.alloc.release(int(pid))
            for pid in self._reserve[slot]:
                self.alloc.release(pid)
            self._reserve[slot] = []
            self._tables[slot, :] = 0
            self._registered[slot] = 0
            self._cacheable[slot] = False
            self._tables_dirty = True
        self.slots[slot] = None
        self._prompts[slot] = None
        self._cursor[slot] = 0
        self._slot_arrays = None

    def _mark_first(self, h: RequestHandle, now: float):
        if not h.t_first:
            h.t_first = now
            self.obs.request_first_token(h)

    def _finish(self, h: RequestHandle, reason: str, now: float):
        h.finish_reason = reason
        h.t_done = now
        if reason in (FINISH_STOP, FINISH_LENGTH):
            self.completed += 1
        elif reason == FINISH_CANCELLED:
            self.cancelled += 1
        elif reason == FINISH_TIMEOUT:
            self.timeouts += 1
        elif reason == FINISH_REJECTED:
            self.sheds += 1
        elif reason == FINISH_ERROR:
            self.errors += 1
        # every retirement path funnels through here — the single place
        # the lifecycle spans and completion histograms are emitted
        self.obs.request_retired(h, h._slot)

    def _fleet_arrays(self):
        """Per-slot device arrays for the decode dispatch, cached until the
        fleet changes: (temps, active, seeds, top_k, top_p, stops) plus the
        static (use_mask, stop_width) pair that keys the loop variant."""
        if self._slot_arrays is None:
            nb = len(self.slots)
            temps = np.zeros((nb,), np.float32)
            seeds = np.zeros((nb,), np.uint32)
            top_k = np.zeros((nb,), np.int32)
            top_p = np.ones((nb,), np.float32)
            stop_sets: List[List[int]] = [[] for _ in range(nb)]
            use_mask = False
            for i in range(nb):
                if not self._decoding(i):
                    continue
                p = self.slots[i].params
                temps[i] = p.temperature
                seeds[i] = p.seed & 0xFFFFFFFF
                top_k[i] = p.top_k
                top_p[i] = p.top_p
                stop_sets[i] = sorted(self.slots[i]._stop_ids)
                use_mask |= p.needs_mask
            stop_w = _pow2ceil(max(1, max(len(s) for s in stop_sets)))
            stops = np.full((nb, stop_w), -1, np.int32)
            for i, s in enumerate(stop_sets):
                stops[i, :len(s)] = s
            active = np.asarray([self._decoding(i) for i in range(nb)])
            self._slot_arrays = (
                tuple(jnp.asarray(a) for a in
                      (temps, active, seeds, top_k, top_p, stops)),
                use_mask, stop_w)
        return self._slot_arrays

    def _loop_fn(self, n_steps: int, use_mask: bool, stop_w: int,
                 use_poison: bool = False):
        key = (n_steps, use_mask, stop_w, use_poison)
        if key not in self._loop_cache:
            # Donating the decode state lets XLA update the KV caches in
            # place; CPU has no donation support and would warn per dispatch.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._loop_cache[key] = jax.jit(
                functools.partial(_decode_loop, cfg=self.cfg,
                                  n_steps=n_steps, use_mask=use_mask,
                                  use_poison=use_poison),
                donate_argnums=donate)
        return self._loop_cache[key]

    def _prefill_fn(self, length: int):
        """One jit per power-of-two chunk bucket (O(log prefill_chunk))."""
        if length not in self._prefill_cache:
            cfg = self.cfg
            donate = (1,) if jax.default_backend() != "cpu" else ()

            def impl(params, state, tokens, lengths):
                return prefill_chunk(params, cfg, state, {"tokens": tokens},
                                     lengths)

            self._prefill_cache[length] = jax.jit(impl, donate_argnums=donate)
        return self._prefill_cache[length]

    def _reset_rows(self, mask: np.ndarray, pos0=None):
        if self._reset_jit is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._reset_jit = jax.jit(_reset_rows_impl, donate_argnums=donate)
        if pos0 is None:
            pos0 = np.zeros((len(self.slots),), np.int32)
        self.state = self._reset_jit(self.state, jnp.asarray(mask),
                                     jnp.asarray(pos0))

    def _admit(self):
        """Drain the wait queue into *all* free, non-quarantined slots in
        one go. Under the paged layout a slot admits only when the queue
        head's worst-case page budget is reservable right now; otherwise
        the head waits (strict FIFO — a shorter request behind it never
        jumps the line) until retirements return pages to the pool."""
        fresh_rows = []
        pos0 = np.zeros((len(self.slots),), np.int32)
        clear: List[int] = []
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue \
                    or slot in self.quarantined:
                continue
            page_args = None
            if self.paged:
                plan = self._plan_pages(self.queue[0])
                if plan is None:
                    break  # head waits for pages; FIFO holds
                prompt, shared, fresh, reserve, cacheable = plan
                h = self.queue.popleft()
                self.slots[slot] = h
                self._prompts[slot] = prompt
                skip = len(shared) * self.ecfg.page_size
                self._cursor[slot] = skip   # cache-hit tokens never prefill
                pos0[slot] = skip
                ids = shared + fresh
                self._tables[slot, :] = 0
                self._tables[slot, :len(ids)] = ids
                self._tables_dirty = True
                self._registered[slot] = len(shared)
                self._cacheable[slot] = cacheable
                self._reserve[slot] = reserve
                clear.extend(fresh)
                page_args = {"pages_shared": len(shared),
                             "pages_fresh": len(fresh),
                             "pages_reserved": len(reserve)}
            else:
                h = self.queue.popleft()
                self.slots[slot] = h
                self._prompts[slot] = list(h.prompt[-self.ecfg.capacity:])
                self._cursor[slot] = 0
            h.t_admit = self._clock()
            h._slot = slot
            self.obs.request_admitted(h, slot, pages=page_args)
            fresh_rows.append(slot)
            self.admits += 1
        if fresh_rows:
            mask = np.zeros((len(self.slots),), bool)
            mask[fresh_rows] = True
            self._reset_rows(mask, pos0)
            if self.paged:
                self._page_maintenance(clear=clear)
            self._slot_arrays = None

    def _sample_first(self, logits, rows: List[int]) -> np.ndarray:
        """Token 0 for every row in ``rows`` (whose prompt just completed),
        drawn from each request's own stream — ``fold_in(PRNGKey(seed), 0)``
        — with its top-k/top-p support; other rows ride along as greedy and
        are ignored by the caller."""
        nb = logits.shape[0]
        rs = set(rows)
        p = {i: self.slots[i].params for i in rows}
        temps = jnp.asarray([p[i].temperature if i in rs else 0.0
                             for i in range(nb)], jnp.float32)
        seeds = jnp.asarray([p[i].seed & 0xFFFFFFFF if i in rs else 0
                             for i in range(nb)], jnp.uint32)
        keys = request_keys(seeds, jnp.zeros((nb,), jnp.int32))
        tk = tp = None
        if any(p[i].needs_mask for i in rows):
            tk = jnp.asarray([p[i].top_k if i in rs else 0
                              for i in range(nb)], jnp.int32)
            tp = jnp.asarray([p[i].top_p if i in rs else 1.0
                              for i in range(nb)], jnp.float32)
        return np.asarray(sample_tokens_per_request(
            logits, keys, temps, top_k=tk, top_p=tp))

    def _prefill_step(self) -> List[RequestHandle]:
        """Advance every mid-prompt slot by one bucketed chunk.

        All prefilling rows share one fixed-(B, L) dispatch: L is the
        power-of-two bucket of the longest remaining need this step (capped
        at prefill_chunk); rows with shorter remainders right-pad, rows not
        prefilling ride along with length 0 (no-op). Rows whose prompt
        completes sample their first token here — so a streamed first token
        lands in the same engine step that finishes its prefill — and join
        the decode fleet the same step.
        """
        pf = [i for i in range(len(self.slots)) if self._prefilling(i)]
        if not pf:
            return []
        nb = len(self.slots)
        need = max(min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk) for i in pf)
        length = _pow2ceil(need)
        tokens = np.zeros((nb, length), np.int32)
        lengths = np.zeros((nb,), np.int32)
        for i in pf:
            # never consume more than prefill_chunk per step, even when the
            # pow2 bucket rounds past it (non-pow2 prefill_chunk configs)
            take = min(len(self._prompts[i]) - self._cursor[i],
                       self.ecfg.prefill_chunk)
            tokens[i, :take] = self._prompts[i][
                self._cursor[i]:self._cursor[i] + take]
            lengths[i] = take
        if self.paged:
            # prefill only ever writes this row's private unregistered
            # pages (skip starts past the shared prefix and registration
            # trails the cursor), so these are no-ops — kept as the single
            # COW choke point guarding *every* write dispatch
            self._fork_writes([(i, self._cursor[i], int(lengths[i]))
                               for i in pf])
            if self._tables_dirty:
                self._page_maintenance()
        obs = self.obs
        t_pf0 = self._clock()
        try:
            self._guard_dispatch("prefill", pf)
            with obs.span("prefill_dispatch",
                          args={"bucket": length, "rows": len(pf)}):
                logits, self.state = self._prefill_fn(length)(
                    self._serve_params, self.state, jnp.asarray(tokens),
                    jnp.asarray(lengths))
        except EngineCrash as exc:  # engine death escapes containment
            self._attribute_crash(exc, pf)
            raise
        except Exception as exc:  # cursors untouched: survivors retry as-is
            return self._contain("prefill", pf, exc)
        t_pf1 = self._clock()
        obs.h_prefill_chunk.observe(t_pf1 - t_pf0)
        self.prefill_steps += 1
        self.prefill_tokens += int(lengths.sum())
        finishers = [i for i in pf
                     if self._cursor[i] + int(lengths[i])
                     >= len(self._prompts[i])]
        for i in pf:
            self._cursor[i] += int(lengths[i])
            obs.prefill_chunk(self.slots[i], i, t_pf0, t_pf1,
                              int(lengths[i]), self._cursor[i])
        if not finishers:
            return []
        if self._injector is not None:
            # token 0's logits can be poisoned too (gen index 0 lives in the
            # prefill finisher, not the decode loop); row-local, so
            # co-batched rows keep their exact logits
            for i in finishers:
                if self._injector.poison_index(self.slots[i].uid, 0, 1) == 0:
                    logits = logits.at[i].set(jnp.nan)
        # non-finite logits are contained *before* sampling: the offending
        # row retires with "error", finite rows sample from untouched logits
        with obs.span("prefill_sync"):
            row_ok = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        if self.paged:
            # registration rides the finisher sync that happens anyway — a
            # per-chunk publish would cost a blocking device round-trip on
            # every prefill step
            self._register_pages(finishers, row_ok)
        now = self._clock()
        finished: List[RequestHandle] = []
        bad_rows = [i for i in finishers if not row_ok[i]]
        for i in bad_rows:
            h = self.slots[i]
            self._free_slot(i)
            self._quarantine(i)
            h.error = "non-finite logits at prefill completion"
            self._finish(h, FINISH_ERROR, now)
            finished.append(h)
        finishers = [i for i in finishers if row_ok[i]]
        if not finishers:
            return finished
        # the prompt's last logits yield the first generated token; one
        # vectorized sample covers every finishing row
        with obs.span("sample_collect", args={"rows": len(finishers)}):
            toks = self._sample_first(logits, finishers)
            for i in finishers:
                h = self.slots[i]
                tok = int(toks[i])
                h.output.append(tok)
                self.tokens_generated += 1
                self._mark_first(h, now)
                # the prefill-sampled token may already terminate the
                # request — on eos_id *or* any SamplingParams.stop id
                if tok in h._stop_ids:
                    self._finish(h, FINISH_STOP, now)
                elif len(h.output) >= h.params.max_new_tokens:
                    self._finish(h, FINISH_LENGTH, now)
                else:
                    self.last_tokens[i] = tok
                    self._slot_arrays = None
                    continue
                finished.append(h)
                self._free_slot(i)
        return finished

    def _register_pages(self, finishers: List[int], row_ok):
        """Publish a finished prompt's fully-filled pages to the prefix
        cache, at prefill completion (the step that already syncs logits
        for the first token — containment granularity, PR 6). A row whose
        completion logits are non-finite never publishes — its KV pages
        can't be trusted and must never splice into other requests.
        """
        ps = self.ecfg.page_size
        for i in finishers:
            if not self._cacheable[i]:
                continue
            if not row_ok[i]:
                self._cacheable[i] = False
                continue
            prompt = self._prompts[i]
            upto = min(self._cursor[i], len(prompt)) // ps
            for j in range(self._registered[i], upto):
                self.alloc.cache_insert(tuple(prompt[:(j + 1) * ps]),
                                        int(self._tables[i, j]))
            self._registered[i] = upto

    def _collect(self, toks: np.ndarray,
                 bad: Optional[np.ndarray] = None) -> List[RequestHandle]:
        """Fold a (K, B) chunk of tokens into the per-slot requests.

        A slot stops at its first stop-token hit (any id in the request's
        ``stop`` set ∪ ``eos_id``) or at its token budget; anything the
        device generated past that point within the chunk is discarded (the
        slot's state is reset by the next admission). Slots still mid-prefill
        took no decode step — their repeated tokens are skipped entirely.

        ``bad`` (K, B) flags steps whose logits were non-finite for that
        row: the garbage token is *not* appended — the request retires with
        frozen reason ``"error"`` and the slot is quarantined, before the
        poisoned value can reach the stream.
        """
        finished = []
        now = self._clock()
        for slot, h in enumerate(self.slots):
            if h is None or not self._decoding(slot):
                continue
            for k in range(toks.shape[0]):
                if bad is not None and bad[k, slot]:
                    self._free_slot(slot)
                    self._quarantine(slot)
                    h.error = (f"non-finite logits at generated token "
                               f"{len(h.output)}")
                    self._finish(h, FINISH_ERROR, now)
                    finished.append(h)
                    break
                tok = int(toks[k, slot])
                h.output.append(tok)
                self.tokens_generated += 1
                self._mark_first(h, now)
                self.last_tokens[slot] = tok
                if tok in h._stop_ids:
                    self._finish(h, FINISH_STOP, now)
                elif len(h.output) >= h.params.max_new_tokens:
                    self._finish(h, FINISH_LENGTH, now)
                else:
                    continue
                finished.append(h)
                self._free_slot(slot)
                break
        return finished


class SerialAdmitEngine(ServingEngine):
    """The PR-1 admission path, kept as the measured baseline: each arriving
    request is prefilled *alone* through a jit cached per distinct prompt
    length (up to `capacity` compilations) and merged into its slot — the
    whole decode fleet stalls while the queue's prompts are consumed one by
    one. Decode (and the v1 handle/cancellation/per-request-RNG surface) is
    identical to `ServingEngine`, so a request's output is bit-identical
    across the two schedulers.
    """

    def __init__(self, params, model_cfg, engine_cfg: EngineConfig, *,
                 injector=None, observability: Optional[Observability] = None):
        if engine_cfg.kv_layout != "ring":
            raise ValueError(
                "SerialAdmitEngine prefills through prefill() into a "
                "private ring state and merges it by slot — the paged "
                "layout is a bucketed-scheduler feature; use "
                "kv_layout='ring' here")
        super().__init__(params, model_cfg, engine_cfg, injector=injector,
                         observability=observability)

    def _warm_prefill(self):
        # Best effort only: compiles the power-of-two prompt lengths, but
        # this engine's jit cache is keyed by *exact* prompt length — any
        # other arriving length still compiles at admission time, which is
        # exactly the TTFT pathology the bucketed scheduler removes.
        for length in self._bucket_lengths(self.ecfg.capacity):
            if length > self.ecfg.capacity:
                break
            self._prefill_len_fn(length)(
                self._serve_params, jnp.zeros((1, length), jnp.int32))

    def _merge(self, batch_state, one_state, slot):
        # hook: the decode benchmark's seed baseline overrides this with the
        # eager leaf-by-leaf merge it measures against
        return _merge_slot(batch_state, one_state, slot)

    @staticmethod
    def _sample_first_row(logits, keys, p: SamplingParams):
        """Token 0 for one batch-1 logits row — row-wise sampling is
        batch-size-invariant, so this matches the bucketed engine's fleet
        dispatch bit for bit."""
        tk = jnp.asarray([p.top_k], jnp.int32) if p.needs_mask else None
        tp = jnp.asarray([p.top_p], jnp.float32) if p.needs_mask else None
        return np.asarray(sample_tokens_per_request(
            logits, keys, jnp.asarray([p.temperature], jnp.float32),
            top_k=tk, top_p=tp))[0]

    def _prefill_len_fn(self, length: int):
        # one jit per distinct prompt length; prompts are clipped to
        # `capacity` on admit, so the cache is bounded by capacity entries
        if length not in self._prefill_cache:
            cfg, cap = self.cfg, self.ecfg.capacity

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, {"tokens": tokens}, capacity=cap)

            self._prefill_cache[length] = fn
        return self._prefill_cache[length]

    def _admit(self):
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue \
                    or slot in self.quarantined:
                continue
            h = self.queue.popleft()
            self.admits += 1
            prompt = h.prompt[-self.ecfg.capacity:]
            self.slots[slot] = h          # resident before the dispatch so
            self._prompts[slot] = list(prompt)  # containment can attribute
            self._cursor[slot] = 0        # not decoding until token 0 lands
            h.t_admit = self._clock()
            h._slot = slot
            self.obs.request_admitted(h, slot)
            fn = self._prefill_len_fn(len(prompt))
            t_pf0 = self._clock()
            try:
                self._guard_dispatch("prefill", [slot])
                with self.obs.span("prefill_dispatch",
                                   args={"bucket": len(prompt), "rows": 1}):
                    logits, one_state = fn(self._serve_params,
                                           jnp.asarray([prompt], jnp.int32))
            except EngineCrash as exc:  # engine death escapes containment
                self._attribute_crash(exc, [slot])
                raise
            except Exception as exc:  # serial admission: batch-1 containment
                self._admit_finished.extend(
                    self._contain("prefill", [slot], exc))
                continue
            self.state = self._merge(self.state, one_state, slot)
            self.prefill_steps += 1
            self.prefill_tokens += len(prompt)
            self.obs.h_prefill_chunk.observe(self._clock() - t_pf0)
            self.obs.prefill_chunk(h, slot, t_pf0, self._clock(),
                                   len(prompt), len(prompt))
            p = h.params
            if self._injector is not None \
                    and self._injector.poison_index(h.uid, 0, 1) == 0:
                logits = logits.at[0].set(jnp.nan)
            with self.obs.span("prefill_sync"):
                row_ok = bool(np.asarray(jnp.all(jnp.isfinite(logits[0]))))
            if not row_ok:
                self._free_slot(slot)
                self._quarantine(slot)
                h.error = "non-finite logits at prefill completion"
                self._finish(h, FINISH_ERROR, self._clock())
                self._admit_finished.append(h)
                continue
            # token 0 from the request's own stream (serial prefill logits
            # are batch-1: sample that one row directly)
            keys = request_keys(jnp.asarray([p.seed & 0xFFFFFFFF],
                                            jnp.uint32),
                                jnp.zeros((1,), jnp.int32))
            with self.obs.span("sample_collect", args={"rows": 1}):
                tok = int(self._sample_first_row(logits, keys, p))
            now = self._clock()
            h.output.append(tok)
            self.tokens_generated += 1
            self._mark_first(h, now)
            # the prefill-sampled token may already terminate the request
            if tok in h._stop_ids:
                self._finish(h, FINISH_STOP, now)
            elif len(h.output) >= h.params.max_new_tokens:
                self._finish(h, FINISH_LENGTH, now)
            else:
                self.last_tokens[slot] = tok
                # mark the prompt consumed → base class sees a decoding row
                self._cursor[slot] = len(prompt)
                self._slot_arrays = None
                continue
            self._admit_finished.append(h)
            self._free_slot(slot)
