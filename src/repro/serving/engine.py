"""Continuous-batching serving engine with a fused multi-step decode loop.

Slot-based continuous batching (vLLM-style, adapted to fixed-shape JAX):

  * the decode batch has `max_slots` fixed slots → one jit'd decode loop
    for the whole fleet of in-flight requests (no recompilation as requests
    come and go);
  * an arriving request is prefilled alone (one cached jit per prompt
    length, bounded by `capacity`) and its state is *merged* into a free
    slot;
  * finished slots (EOS / max_tokens) are freed immediately and refilled from
    the wait queue on the next step — decode never stalls on stragglers.

Decode fast path (the paper's 4.63× end-to-end claim only materializes if the
serving loop keeps the accelerator busy):

  * ``decode_chunk`` tokens are generated per host round-trip by a single
    jitted ``lax.scan`` that fuses decode_step + on-device sampling — one
    dispatch and one host sync per K tokens instead of per token;
  * the decode state is donated to the loop (``donate_argnums``), so XLA
    writes KV-cache updates in place instead of copying the caches each step;
  * temperature and EOS handling are vectorized per slot *on device*: each
    slot samples with its own temperature (greedy where 0), and a slot that
    emits EOS is frozen for the rest of the chunk (its token repeats; the
    host discards everything after the EOS when collecting).

Works identically for dense and PTQTP-quantized params (`dense` dispatches on
the kernel leaf type), which is the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import unpack_trits
from repro.core.quantize_model import QuantizedKernel
from repro.kernels.ternary_matmul.ops import resolve_backend
from repro.models import decode_step, init_decode_state, prefill
from repro.models.common import matmul_backend
from repro.serving.sampling import sample_token, sample_tokens


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    capacity: int = 256          # KV-cache length per slot
    eos_id: Optional[int] = None
    seed: int = 0
    decode_chunk: int = 8        # tokens per jitted decode dispatch (K)
    # Pre-unpack trit-planes for the decode loop (None → auto: only when the
    # grouped XLA backend serves the quantized matmuls; the Pallas TPU kernel
    # unpacks in-kernel, where streaming packed planes IS the win). Trades
    # 4x plane bytes (int8 trits vs 2-bit fields, still 2x under fp16) for
    # not re-unpacking every weight at every decode step.
    preunpack_decode: Optional[bool] = None

    def __post_init__(self):
        assert self.max_slots >= 1 and self.capacity >= 1
        assert self.decode_chunk >= 1, "decode_chunk=0 would never emit"


def _preunpack_params(params):
    """Replace packed QuantizedKernel planes with raw int8 trit-planes.

    The unpack is exact and the grouped einsum consumes either form with the
    identical contraction order, so decode outputs are bit-identical — the
    unpack work just moves from every decode step to engine init.
    """

    def unpack(leaf):
        if isinstance(leaf, QuantizedKernel):
            return dataclasses.replace(
                leaf, t1p=unpack_trits(leaf.t1p), t2p=unpack_trits(leaf.t2p))
        return leaf

    return jax.tree.map(unpack, params,
                        is_leaf=lambda x: isinstance(x, QuantizedKernel))


def _merge_slot_impl(batch_state, one_state, slot):
    """Write a batch=1 decode state into slot `slot` of the batch state.

    Jitted (slot is a traced scalar): one dispatch per admit instead of one
    per state leaf — the leaf-by-leaf eager version dominated admit latency.
    The batch state is donated on accelerators so the one-slot write never
    copies the other slots' KV caches.
    """

    def walk(dst, src, path):
        if isinstance(dst, dict):
            return {k: walk(dst[k], src[k], f"{path}/{k}") for k in dst}
        axis = 1 if "/blocks/" in path else 0  # stacked caches: (L, B, ...)
        idx = [slice(None)] * dst.ndim
        idx[axis] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=axis).astype(dst.dtype))

    return walk(batch_state, one_state, "")


_merge_jit = None


def _merge_slot(batch_state, one_state, slot):
    """Jitted merge, donation decided lazily (first call, not import time —
    importing this module must not initialize the JAX platform)."""
    global _merge_jit
    if _merge_jit is None:
        donate = (0,) if jax.default_backend() != "cpu" else ()
        _merge_jit = jax.jit(_merge_slot_impl, donate_argnums=donate)
    return _merge_jit(batch_state, one_state, slot)


def _decode_loop(params, state, tokens, temps, active, key, *,
                 cfg, n_steps, eos_id):
    """K fused decode steps with on-device per-slot sampling.

    Args:
      tokens: (B,) int32 last token per slot.
      temps:  (B,) f32 per-slot temperature (0 → greedy for that slot).
      active: (B,) bool — occupied slots; inactive slots repeat their token.
    Returns:
      (new_state, toks) with toks (n_steps, B) — the sampled token per step.
    """

    def body(carry, _):
        state, tok, active, key = carry
        logits, state = decode_step(params, cfg, state, tok)
        key, sub = jax.random.split(key)
        nxt = sample_tokens(logits, sub, temps)
        nxt = jnp.where(active, nxt, tok)  # frozen slots repeat (host drops)
        if eos_id is not None:
            active = jnp.logical_and(active, nxt != eos_id)
        return (state, nxt, active, key), nxt

    # Full unroll: the scan body is op-overhead-bound at decode shapes, and
    # unrolling lets XLA fuse across steps (measured ~40% per-token on CPU).
    (state, _, _, _), toks = jax.lax.scan(
        body, (state, tokens, active, key), None, length=n_steps,
        unroll=min(n_steps, 16))
    return state, toks


class ServingEngine:
    def __init__(self, params, model_cfg, engine_cfg: EngineConfig):
        self.params = params
        self.cfg = model_cfg
        self.ecfg = engine_cfg
        self.key = jax.random.PRNGKey(engine_cfg.seed)
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_slots
        self.state = init_decode_state(model_cfg, engine_cfg.max_slots,
                                       engine_cfg.capacity)
        self.last_tokens = np.zeros((engine_cfg.max_slots,), np.int32)
        pre = engine_cfg.preunpack_decode
        if pre is None:
            pre = resolve_backend(matmul_backend()) == "grouped"
        # serve-side params: prefill and decode both read these, so the
        # unpack is paid once per engine, not once per dispatch
        self._serve_params = _preunpack_params(params) if pre else params
        self._loop_cache: Dict[int, Any] = {}
        self._prefill_cache: Dict[int, Any] = {}
        self._admit_finished: List[Request] = []
        self._slot_arrays = None  # (temps, active) cache; None → slots dirty
        self.steps = 0

    # ------------------------------------------------------------------ API
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain; returns finished requests."""
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            finished.extend(self.step())
        return finished

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """Admit waiting requests, then decode one chunk of up to K tokens.

        The chunk length adapts to the largest remaining token budget among
        active slots, rounded up to a power of two (compile count stays
        O(log K)) — a fleet that only needs 3 more tokens never pays for a
        16-step dispatch.
        """
        self._admit()
        done_now = self._admit_finished
        self._admit_finished = []
        if all(s is None for s in self.slots):
            return done_now
        remaining = max(s.max_new_tokens - len(s.output)
                        for s in self.slots if s is not None)
        n_steps = min(self.ecfg.decode_chunk,
                      1 << max(remaining - 1, 0).bit_length())
        self.key, sub = jax.random.split(self.key)
        if self._slot_arrays is None:  # rebuilt only when slots changed
            self._slot_arrays = (
                jnp.asarray([s.temperature if s else 0.0
                             for s in self.slots], jnp.float32),
                jnp.asarray([s is not None for s in self.slots]))
        temps, active = self._slot_arrays
        self.state, toks = self._loop_fn(n_steps)(
            self._serve_params, self.state, jnp.asarray(self.last_tokens),
            temps, active, sub)
        self.steps += n_steps
        return done_now + self._collect(np.asarray(toks))

    # ------------------------------------------------------------- internals
    def _merge(self, batch_state, one_state, slot):
        return _merge_slot(batch_state, one_state, slot)

    def _loop_fn(self, n_steps: int):
        if n_steps not in self._loop_cache:
            # Donating the decode state lets XLA update the KV caches in
            # place; CPU has no donation support and would warn per dispatch.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._loop_cache[n_steps] = jax.jit(
                functools.partial(_decode_loop, cfg=self.cfg,
                                  n_steps=n_steps,
                                  eos_id=self.ecfg.eos_id),
                donate_argnums=donate)
        return self._loop_cache[n_steps]

    def _prefill_fn(self, length: int):
        # one jit per distinct prompt length; prompts are clipped to
        # `capacity` on admit, so the cache is bounded by capacity entries
        if length not in self._prefill_cache:
            cfg, cap = self.cfg, self.ecfg.capacity

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, {"tokens": tokens}, capacity=cap)

            self._prefill_cache[length] = fn
        return self._prefill_cache[length]

    def _admit(self):
        for slot in range(len(self.slots)):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = req.prompt[-self.ecfg.capacity:]
            fn = self._prefill_fn(len(prompt))
            logits, one_state = fn(self._serve_params,
                                   jnp.asarray([prompt], jnp.int32))
            self.state = self._merge(self.state, one_state, slot)
            self.key, sub = jax.random.split(self.key)
            tok = int(np.asarray(
                sample_token(logits, sub, temperature=req.temperature))[0])
            req.output.append(tok)
            # the prefill-sampled token may already terminate the request
            hit_eos = (self.ecfg.eos_id is not None
                       and tok == self.ecfg.eos_id)
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self._admit_finished.append(req)
                continue
            self.last_tokens[slot] = tok
            self.slots[slot] = req
            self._slot_arrays = None

    def _collect(self, toks: np.ndarray) -> List[Request]:
        """Fold a (K, B) chunk of tokens into the per-slot requests.

        A slot stops at its first EOS or at its token budget; anything the
        device generated past that point within the chunk is discarded (the
        slot's cache is overwritten by the next prefill merge).
        """
        finished = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for k in range(toks.shape[0]):
                tok = int(toks[k, slot])
                req.output.append(tok)
                self.last_tokens[slot] = tok
                hit_eos = (self.ecfg.eos_id is not None
                           and tok == self.ecfg.eos_id)
                if hit_eos or len(req.output) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    self.slots[slot] = None
                    self._slot_arrays = None
                    break
        return finished
