"""Thread-safe engine driver: one thread owns the device.

``ServingEngine`` is deliberately single-threaded — ``step()`` mutates
slot state, the page pool, and the compile caches with no locking, and
the v1 ``RequestHandle`` drives ``step()`` from whatever thread consumes
it. That cooperative style stays the in-process baseline; this module
adds the concurrent one:

* :class:`EngineDriver` runs a single daemon thread that is the **only**
  caller of any engine method after ``start()``. Clients talk to the
  driver through thread-safe ``submit`` / ``cancel`` / ``call`` and
  consume per-request queues; a condition variable wakes the driver on
  new work and parks it (no spinning) when the fleet is idle.
* :class:`DriverHandle` mirrors the v1 handle surface (``tokens()``,
  ``result()``, ``cancel()``, the timing fields) but never touches the
  engine: ``tokens()`` reads the handle's own event queue fed by the
  driver at the end of each step — same-step delivery, stream TTFT is
  engine TTFT — and ``result()`` waits on an event instead of stepping.
  ``subscribe(fn)`` replays history then attaches a callback (the HTTP
  layer bridges it onto an asyncio loop).

Admission order is delegated to a :class:`~repro.serving.frontend.
fairness.FairScheduler`: accepted requests wait in per-tenant DRR queues
and the driver offers the engine at most ``free_admissible_slots()``
requests per step, so the engine's strict-FIFO internal queue stays
shallow and the DRR decision is the effective admission order. Engine-
level admission control (v1.1 caps, v1.2 page budgets) still applies to
every offer; an engine shed propagates to the client unchanged
(finish_reason ``"rejected"``).

Determinism is unaffected: tokens are a pure function of (params,
prompt, ``SamplingParams``), so outputs through the driver are
bit-identical to cooperative ``engine.submit`` — regardless of thread
interleaving, which only changes co-batching.

Drain and shutdown: ``drain()`` stops intake (new submits and anything
still waiting in the fair queue shed with ``"rejected"`` — the client
retries another replica) and lets everything already offered to the
engine finish or deadline out; ``close()`` cancels whatever is left and
joins the thread.

Engine death: an exception escaping ``engine.step()`` hits ``_fatal``.
Standalone, every in-flight handle retires ``"error"`` with the crash
detail attached (clients can tell engine death from a contained
per-request fault) and the driver closes. Under an
:class:`~repro.serving.frontend.supervisor.EngineSupervisor`
(``on_fatal`` set), handles are left alive for :meth:`EngineDriver.reap`
/ :meth:`EngineDriver.adopt` migration onto a rebuilt engine: replay
regenerates from token 0 and the ``_delivered`` cursor dedups the
already-streamed prefix.

Every timestamp routes through the engine's injectable clock
(``engine.clock`` — a ``VirtualClock`` under a fault injector), keeping
the static wall-clock guard and the trace-reconciliation guarantee
intact across the frontend.
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import threading
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional

from repro.serving.api import (FINISH_CANCELLED, FINISH_REJECTED,
                               FINISH_TIMEOUT, FINISH_ERROR, RequestResult,
                               SamplingParams)
from repro.serving.frontend.fairness import FairScheduler

_DONE = "done"
_TOKEN = "token"


class DriverHandle:
    """Client-side view of one request submitted through the driver.

    Mirrors the v1 ``RequestHandle`` reading surface (``uid``,
    ``prompt``, ``params``, ``output``, ``finish_reason``, ``error``,
    ``truncated``, timing fields, ``done``, ``tokens()``, ``result()``,
    ``cancel()``) but is passive: consuming it never drives the engine.
    ``tokens()`` has single-consumer semantics (one queue per handle);
    any number of ``subscribe`` callbacks may observe in parallel.
    """

    def __init__(self, uid: int, prompt: List[int], params: SamplingParams):
        self.uid = uid
        self.prompt = prompt
        self.params = params
        self.tenant = params.tenant
        self.output: List[int] = []
        self.finish_reason: Optional[str] = None
        self.error: Optional[str] = None
        self.truncated = False
        self.t_submit = 0.0
        self.t_admit = 0.0
        self.t_first = 0.0
        self.t_done = 0.0
        self._driver: Optional["EngineDriver"] = None
        self._inner = None              # engine RequestHandle, driver-only
        self._state = "new"             # new -> queued -> engine -> done
        self._delivered = 0             # engine tokens already mirrored
        self._replayed = False          # re-queued after an engine crash
        self._drr_cost: Optional[int] = None
        self._elock = threading.Lock()
        self._events: List[tuple] = []
        self._watchers: List[Callable[[tuple], None]] = []
        self._q: _queue.Queue = _queue.Queue()
        self._done_evt = threading.Event()
        self._result: Optional[RequestResult] = None

    # ------------------------------------------------------------- consume
    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    def tokens(self) -> Iterator[int]:
        """Yield each generated token as the driver step that produced it
        completes. Returns when the request retires (check
        ``finish_reason`` / ``result()`` afterwards)."""
        while True:
            ev = self._q.get()
            if ev[0] == _TOKEN:
                yield ev[2]
            else:
                return

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Block until the request retires; returns the immutable record.
        Raises ``TimeoutError`` if ``timeout`` (seconds) elapses first."""
        if not self._done_evt.wait(timeout):
            raise TimeoutError(f"request {self.uid} not done "
                               f"after {timeout}s")
        assert self._result is not None
        return self._result

    def cancel(self) -> bool:
        """Thread-safe cancel; False if the request already finished."""
        assert self._driver is not None
        return self._driver.cancel(self)

    def subscribe(self, fn: Callable[[tuple], None]) -> None:
        """Attach an event callback, first replaying history — so a
        subscriber can never miss a token to the race between submit and
        attach. Events are ``("token", index, token_id)`` then exactly one
        ``("done", RequestResult)``. Callbacks run on the driver thread:
        return quickly and do not call back into the driver (except
        ``cancel``, which is re-entrant)."""
        with self._elock:
            history = list(self._events)
            self._watchers.append(fn)
        for ev in history:
            fn(ev)

    # -------------------------------------------------------- driver-side
    def _emit(self, ev: tuple) -> None:
        with self._elock:
            self._events.append(ev)
            watchers = list(self._watchers)
        self._q.put(ev)
        for w in watchers:
            try:
                w(ev)
            except Exception:
                pass  # a broken subscriber must not take down the driver


class _CallBox:
    __slots__ = ("fn", "evt", "value", "exc")

    def __init__(self, fn):
        self.fn = fn
        self.evt = threading.Event()
        self.value = None
        self.exc: Optional[BaseException] = None


class EngineDriver:
    """Single-threaded owner of a ``ServingEngine`` with a thread-safe
    frontend surface.

    Threading rules (the v1.4 contract):

    * After ``start()``, **no other thread may call any engine method**
      — use ``submit`` / ``cancel`` / ``call`` instead. ``call(fn)``
      runs ``fn(engine)`` on the driver thread between steps (how the
      HTTP layer snapshots ``health()`` and scrapes the registry without
      racing the step loop).
    * Any number of threads may submit/cancel/consume concurrently; a
      handle's ``tokens()`` iterator is single-consumer.
    * The driver parks on its condition variable when there is no
      waiting, queued, or resident work — an idle server burns no CPU —
      and wakes on submit/cancel/call/drain.
    """

    def __init__(self, engine, *, fairness: Optional[FairScheduler] = None,
                 name: str = "engine-driver"):
        self._eng = engine
        self._clock = engine.clock
        self._fair = fairness if fairness is not None else FairScheduler()
        cap = engine.ecfg.capacity
        self._fair.bind_cost(
            lambda h: min(len(h.prompt), cap) + h.params.max_new_tokens)
        lock = threading.RLock()
        self._cond = threading.Condition(lock)
        self._cancels: deque = deque()
        self._calls: deque = deque()
        self._live: Dict[int, DriverHandle] = {}
        self._results: List[RequestResult] = []
        self._draining = False
        self._closed = False
        self._drained_evt = threading.Event()
        self._next_uid = engine._next_uid
        # supervision surface (EngineSupervisor): on_fatal routes engine
        # death to the supervisor instead of fanning "error" out to every
        # client; generation tags which rebuild this driver belongs to
        self.on_fatal: Optional[Callable[[BaseException], None]] = None
        self.fatal_exc: Optional[BaseException] = None
        self.generation = 0
        self._abandoned = False   # reaped: the loop must exit touching nothing
        self._step_t0: Optional[float] = None  # engine-clock stamp of the
        #                                        in-flight step (watchdog read)
        self.submitted = 0
        self.sheds = 0      # frontend sheds (caps, drain) — engine sheds
        #                     are counted by the engine itself
        self.cancelled = 0  # cancelled before reaching the engine
        self.timeouts = 0   # deadlined before reaching the engine
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._started = False

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "EngineDriver":
        if self._started:
            return self
        self._started = True
        reg = self._eng.obs.registry
        if "serving_frontend_shed_total" not in reg:
            reg.counter("serving_frontend_shed_total",
                        poll=lambda: self.sheds,
                        help="requests shed by the frontend "
                             "(fair-queue caps, drain)")
            reg.gauge("serving_frontend_queue_depth",
                      poll=lambda: len(self._fair),
                      help="requests waiting in the frontend fair queue")
        self._thread.start()
        return self

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Stop intake and wait for the engine to empty. New submits and
        requests still waiting in the fair queue shed with ``"rejected"``;
        work already offered to the engine finishes (or deadlines out)
        normally. Returns True once fully drained."""
        with self._cond:
            if not self._draining:
                self._draining = True
                for h in self._fair.drain():
                    self._shed_locked(h, "server draining")
            self._cond.notify_all()
        return self._drained_evt.wait(timeout)

    def close(self, timeout: float = 30.0) -> None:
        """Cancel everything still in flight and join the driver thread."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._started:
            self._thread.join(timeout)

    @property
    def engine(self):
        """The owned engine. Only for pre-``start()`` wiring and
        post-``close()`` inspection — never call engine methods while the
        driver is running (use :meth:`call`)."""
        return self._eng

    # ------------------------------------------------------------- clients
    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               tenant: Optional[str] = None) -> DriverHandle:
        """Thread-safe submit. Invalid inputs raise synchronously
        (``TypeError`` / ``ValueError`` — the HTTP layer's 400s);
        admission decisions come back through the handle
        (``finish_reason "rejected"`` for sheds)."""
        if params is None:
            params = SamplingParams()
        if tenant is not None:
            params = dataclasses.replace(params, tenant=tenant)
        if isinstance(prompt, (str, bytes)):
            raise TypeError("prompt must be a sequence of token ids, not "
                            "text — tokenize first")
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        h = DriverHandle(self._alloc_uid(), prompt, params)
        h._driver = self
        h.t_submit = self._clock()
        h.truncated = len(prompt) > self._eng.ecfg.capacity
        with self._cond:
            self.submitted += 1
            if self._closed:
                self._shed_locked(h, "driver closed")
                return h
            if self._draining:
                self._shed_locked(h, "server draining")
                return h
            cap = self._fair.tenant_max_resident_tokens
            if cap is not None and self._fair.cost(h) > cap:
                self._shed_locked(
                    h, f"request needs {self._fair.cost(h)} committed "
                       f"tokens > per-tenant cap {cap} (can never fit)")
                return h
            why = self._fair.push(h)
            if why is not None:
                self._shed_locked(h, why)
                return h
            h._state = "queued"
            self._cond.notify_all()
        return h

    def cancel(self, h: DriverHandle) -> bool:
        with self._cond:
            if h._state == "done":
                return False
            if h._state == "queued" and self._fair.remove(h):
                self.cancelled += 1
                self._finish_locked(h, RequestResult(
                    uid=h.uid, tokens=(), finish_reason=FINISH_CANCELLED,
                    truncated=h.truncated, t_submit=h.t_submit, t_first=0.0,
                    t_done=self._clock(),
                    error="cancelled before admission"))
                return True
            self._cancels.append(h)
            self._cond.notify_all()
            return True

    def call(self, fn: Callable[[Any], Any], timeout: float = 30.0) -> Any:
        """Run ``fn(engine)`` on the driver thread between steps and
        return its value — the one sanctioned way to read engine state
        (health, metrics, compile stats) while the driver runs."""
        if threading.current_thread() is self._thread:
            return fn(self._eng)  # re-entrant (e.g. from a subscriber)
        box = _CallBox(fn)
        with self._cond:
            if self._closed and not self._thread.is_alive():
                raise RuntimeError("driver closed")
            self._calls.append(box)
            self._cond.notify_all()
        if not box.evt.wait(timeout):
            raise TimeoutError("driver call timed out")
        if box.exc is not None:
            raise box.exc
        return box.value

    def results(self) -> List[RequestResult]:
        """Completion records of every request that retired through this
        driver, in retirement order (the drain-table source)."""
        with self._cond:
            return list(self._results)

    def stats(self) -> Dict[str, int]:
        with self._cond:
            return {
                "submitted": self.submitted,
                "frontend_sheds": self.sheds,
                "frontend_cancelled": self.cancelled,
                "frontend_timeouts": self.timeouts,
                "pending": len(self._fair),
                "live": len(self._live),
                "retired": len(self._results),
            }

    # ------------------------------------------------------- driver thread
    def _alloc_uid(self) -> int:
        with self._cond:
            uid, self._next_uid = self._next_uid, self._next_uid + 1
            return uid

    def _shed_locked(self, h: DriverHandle, why: str) -> None:
        self.sheds += 1
        self._finish_locked(h, RequestResult(
            uid=h.uid, tokens=(), finish_reason=FINISH_REJECTED,
            truncated=h.truncated, t_submit=h.t_submit, t_first=0.0,
            t_done=self._clock(), error=why))

    def _finish_locked(self, h: DriverHandle, res: RequestResult) -> None:
        if h._replayed:
            # a replayed request's record keeps its original submit/admit/
            # first-token stamps — the client experienced one request, not
            # one per engine generation
            res = dataclasses.replace(
                res, t_submit=h.t_submit or res.t_submit,
                t_admit=h.t_admit or res.t_admit,
                t_first=h.t_first or res.t_first)
        h.finish_reason = res.finish_reason
        h.error = res.error
        h.t_admit, h.t_first, h.t_done = res.t_admit, res.t_first, res.t_done
        h._state = "done"
        h._result = res
        self._results.append(res)
        h._emit((_DONE, res))
        h._done_evt.set()

    def _service_calls_locked(self) -> None:
        while self._calls:
            box = self._calls.popleft()
            try:
                box.value = box.fn(self._eng)
            except BaseException as e:  # noqa: BLE001 — relayed to caller
                box.exc = e
            box.evt.set()

    def _apply_cancels_locked(self) -> None:
        while self._cancels:
            h = self._cancels.popleft()
            if h._state == "engine" and not h._inner.done:
                self._eng.cancel(h._inner)
            elif h._state == "queued" and self._fair.remove(h):
                self.cancelled += 1
                self._finish_locked(h, RequestResult(
                    uid=h.uid, tokens=(), finish_reason=FINISH_CANCELLED,
                    truncated=h.truncated, t_submit=h.t_submit, t_first=0.0,
                    t_done=self._clock(),
                    error="cancelled before admission"))

    def _sweep_frontend_locked(self) -> None:
        """Deadline requests still waiting in the fair queue (the engine
        only sweeps what it has been offered)."""
        now = self._clock()
        expired = []
        for h in self._fair.pending():
            d, td = h.params.deadline_s, h.params.ttft_deadline_s
            over = min(x for x in (d, td) if x is not None) \
                if (d is not None or td is not None) else None
            if over is not None and now - h.t_submit >= over:
                expired.append(h)
        for h in expired:
            self._fair.remove(h)
            self.timeouts += 1
            self._finish_locked(h, RequestResult(
                uid=h.uid, tokens=(), finish_reason=FINISH_TIMEOUT,
                truncated=h.truncated, t_submit=h.t_submit, t_first=0.0,
                t_done=now, error="deadline expired in frontend queue"))

    def _offer_locked(self) -> int:
        """Hand the engine up to (free admissible slots − already queued)
        requests in DRR order; engine-level sheds propagate unchanged."""
        eng = self._eng
        offered = 0
        while True:
            room = eng.free_admissible_slots() - len(eng.queue)
            if room <= 0:
                break
            h = self._fair.pop()
            if h is None:
                break
            inner = eng.submit(h.prompt, h.params, uid=h.uid)
            h._inner = inner
            h.truncated = inner.truncated
            if inner.done:  # engine-level shed (caps, page budget)
                self._fair.retire(h)
                self._finish_locked(h, inner.result())
            else:
                h._state = "engine"
                self._live[h.uid] = h
            offered += 1
        return offered

    def _shutdown_locked(self) -> None:
        for h in self._fair.drain():
            self._shed_locked(h, "driver closed")
        for h in list(self._live.values()):
            if not h._inner.done:
                self._eng.cancel(h._inner)

    def _pump(self) -> None:
        """Mirror new engine tokens into handle queues and retire finished
        requests — the per-step fan-out that makes delivery same-step."""
        retired = []
        for h in list(self._live.values()):
            inner = h._inner
            out = inner.output
            while h._delivered < len(out):
                tok = out[h._delivered]
                h.output.append(tok)
                h._delivered += 1
                if not h.t_first:
                    h.t_first = inner.t_first
                    h.t_admit = inner.t_admit
                h._emit((_TOKEN, h._delivered - 1, tok))
            if inner.done:
                retired.append(h)
        if not retired:
            return
        with self._cond:
            for h in retired:
                self._live.pop(h.uid, None)
                self._fair.retire(h)
                self._finish_locked(h, h._inner.result())
            self._cond.notify_all()  # wake a drain() waiter's re-check path

    def _fatal(self, exc: BaseException) -> None:
        """Engine-level failure (not a contained per-request fault).

        Standalone: retire everything with ``"error"`` carrying the crash
        detail (exception type + message), so no client hangs and each
        can tell engine death from a per-request fault. Supervised
        (``on_fatal`` set): leave the non-retired handles untouched — the
        supervisor harvests them with :meth:`reap` and replays them on a
        rebuilt engine — and just hand the exception over."""
        why = self._crash_detail(exc)
        cb = self.on_fatal
        with self._cond:
            self.fatal_exc = exc
            self._closed = True
            self._abandoned = True
            self._fail_calls_locked(why)
            if cb is None:
                now = self._clock()
                for h in list(self._live.values()):
                    self._live.pop(h.uid, None)
                    self._fair.retire(h)
                    self._finish_locked(h, RequestResult(
                        uid=h.uid, tokens=tuple(h.output),
                        finish_reason=FINISH_ERROR, truncated=h.truncated,
                        t_submit=h.t_submit, t_first=h.t_first, t_done=now,
                        t_admit=h.t_admit, error=why))
                for h in self._fair.drain():
                    self._shed_locked(h, why)
            self._drained_evt.set()
            self._cond.notify_all()
        if cb is not None:
            try:
                cb(exc)
            except Exception:  # a broken supervisor must not mask the crash
                pass

    def _crash_detail(self, exc: Optional[BaseException]) -> str:
        if exc is None:
            return f"engine died (generation {self.generation})"
        return (f"engine died (generation {self.generation}): "
                f"{type(exc).__name__}: {exc}")

    def _fail_calls_locked(self, why: str) -> None:
        while self._calls:
            box = self._calls.popleft()
            box.exc = RuntimeError(why)
            box.evt.set()

    def step_age(self) -> Optional[float]:
        """Engine-clock seconds the in-flight ``engine.step()`` has been
        running, or None between steps — the watchdog's only read."""
        t0 = self._step_t0
        return None if t0 is None else self._clock() - t0

    def reap(self, exc: Optional[BaseException] = None):
        """Supervisor-side harvest after engine death (crash or hang).

        Marks the driver closed and abandoned (a still-running loop exits
        without touching handles), fails pending ``call()`` waiters, and
        returns ``(suspects, survivors)``: the uids blamed for the death
        (from ``exc.suspects`` / ``exc.uid``, else every engine-resident
        uid — the hung-step case) and every non-retired handle, engine
        residents first then the fair queue, each in uid order. Safe from
        any thread: the driver thread is either dead (crash) or stuck
        inside ``engine.step()`` (hang), and never holds the condition
        across a step."""
        exc = exc if exc is not None else self.fatal_exc
        with self._cond:
            self.fatal_exc = self.fatal_exc or exc
            self._closed = True
            self._abandoned = True
            self._fail_calls_locked(self._crash_detail(exc))
            suspects = tuple(getattr(exc, "suspects", ()) or ())
            if not suspects and getattr(exc, "uid", None) is not None:
                suspects = (exc.uid,)
            if not suspects:
                suspects = tuple(h.uid for h in self._eng.slots
                                 if h is not None)
            live = sorted(self._live.values(), key=lambda h: h.uid)
            self._live.clear()
            queued = sorted(self._fair.drain(), key=lambda h: h.uid)
            self._drained_evt.set()
            self._cond.notify_all()
        return suspects, live + queued

    def adopt(self, h: DriverHandle) -> bool:
        """Re-queue a handle that lived on a previous (crashed) driver.

        The handle keeps its uid, delivered-token count, event history,
        and subscribers; the rebuilt engine regenerates its stream from
        token 0 (the determinism contract) and ``_pump``'s
        ``_delivered``-cursor skips the already-mirrored prefix — clients
        see no duplicate and no gap. Returns False when the handle
        already finished (nothing to replay)."""
        with self._cond:
            if h.done:
                return False
            h._driver = self
            h._inner = None
            h._replayed = True
            self._next_uid = max(self._next_uid, h.uid + 1)
            if self._closed or self._draining:
                self._shed_locked(h, "driver closed" if self._closed
                                  else "server draining")
                return True
            why = self._fair.push(h)
            if why is not None:
                self._shed_locked(h, why)
                return True
            h._state = "queued"
            self._cond.notify_all()
        return True

    def _loop(self) -> None:
        eng = self._eng
        while True:
            with self._cond:
                if self._abandoned:
                    return  # reaped by a supervisor — handles migrated
                self._service_calls_locked()
                if self._closed:
                    self._shutdown_locked()
                self._apply_cancels_locked()
                self._sweep_frontend_locked()
                if not self._closed:
                    self._offer_locked()
                busy = bool(eng.queue) \
                    or any(s is not None for s in eng.slots)
                # pending work behind quarantined slots: step anyway so the
                # quarantine countdown (engine_steps) can advance
                stalled = (len(self._fair) > 0 and not busy
                           and bool(eng.quarantined))
                if self._draining and not busy and not self._live \
                        and not len(self._fair):
                    self._drained_evt.set()
                if self._closed and not busy:
                    self._pump()
                    self._drained_evt.set()
                    return
                if not busy and not stalled:
                    # a cancel can retire an inner handle without a step;
                    # mirror it before parking or its client hangs
                    self._pump()
                    self._cond.wait(0.5)
                    continue
            self._step_t0 = self._clock()
            try:
                eng.step()
            except Exception as e:
                self._step_t0 = None
                self._fatal(e)
                return
            self._step_t0 = None
            if self._abandoned:
                # the watchdog reaped us mid-step (hung-step recovery that
                # eventually woke up): the handles now live on a newer
                # generation — mirroring anything would double-deliver
                return
            self._pump()
