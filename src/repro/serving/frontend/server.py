"""Stdlib-asyncio HTTP frontend over :class:`EngineDriver`.

No new runtime dependencies: ``asyncio.start_server`` plus a hand-rolled
HTTP/1.1 request parser (one request per connection, ``Connection:
close`` — SSE holds the connection for the response anyway). Endpoints:

``POST /v1/completions``
    JSON body: ``{"prompt": [token ids], "stream": bool, "tenant": str,
    ...SamplingParams fields...}``. With ``"stream": true`` the response
    is ``text/event-stream``: one ``data:`` event per generated token in
    the engine step that produced it (the driver's per-request queues
    bridged onto the asyncio loop with ``call_soon_threadsafe``), a
    terminal event carrying the ``RequestResult`` summary, then
    ``data: [DONE]``. Without streaming, one JSON body at completion.
    A client that disconnects mid-stream cancels its request (freeing
    the slot without perturbing co-batched neighbors, the v1 guarantee).
``GET /healthz``
    ``engine.health()`` (a ``HealthSnapshot``) as JSON, snapshotted on
    the driver thread so it can never race a step.
``GET /metrics``
    The registry's Prometheus text exposition (v1.3 frozen schema plus
    the frontend additions).

Status mapping (the v1.4 contract): terminal outcomes that occur before
any byte of the body is sent map to HTTP codes — ``"rejected"`` → 429
with ``Retry-After``, ``"timeout"`` → 504, ``"error"`` → 500; malformed
bodies/params → 400; a supervised driver in degraded mode (crash-loop
circuit breaker open) → 503 with ``Retry-After``. Every
``/v1/completions`` response carries
``X-Request-Id: <uid>`` — the id the trace recorder annotates spans
with, so an operator can go from an HTTP error straight to the request's
lifecycle spans. Once streaming has started, late outcomes are reported
in the terminal SSE event instead (HTTP has already committed a 200).

``ThreadedHttpServer`` wraps the server in a daemon thread with its own
event loop — what tests, benches, and the example use to serve and
consume from one process.
"""

from __future__ import annotations

import asyncio
import dataclasses
import functools
import json
import threading
from typing import Any, Dict, Optional, Tuple

from repro.serving.api import (FINISH_ERROR, FINISH_REJECTED, FINISH_TIMEOUT,
                               SamplingParams)
from repro.serving.frontend.driver import DriverHandle, EngineDriver
from repro.serving.frontend.supervisor import DegradedError

#: terminal finish_reason → HTTP status, when known before the body starts
STATUS_BY_REASON = {
    FINISH_REJECTED: 429,
    FINISH_TIMEOUT: 504,
    FINISH_ERROR: 500,
}

_REASONS = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 413: "Payload Too Large",
    429: "Too Many Requests", 500: "Internal Server Error",
    503: "Service Unavailable", 504: "Gateway Timeout",
}

#: request-body keys forwarded into SamplingParams
_PARAM_KEYS = ("max_new_tokens", "temperature", "top_k", "top_p", "seed",
               "stop", "deadline_s", "ttft_deadline_s", "tenant")


class _BadRequest(Exception):
    pass


def _parse_body(body: Dict[str, Any]) -> Tuple[list, SamplingParams, bool]:
    if not isinstance(body, dict):
        raise _BadRequest("body must be a JSON object")
    if "prompt" not in body:
        raise _BadRequest("missing 'prompt' (a list of token ids)")
    prompt = body["prompt"]
    if not isinstance(prompt, list) \
            or not all(isinstance(t, int) for t in prompt):
        raise _BadRequest("'prompt' must be a list of token ids — this "
                          "endpoint is pre-tokenized")
    stream = body.get("stream", False)
    if not isinstance(stream, bool):
        raise _BadRequest("'stream' must be a boolean")
    fields = {}
    for k in body:
        if k in ("prompt", "stream"):
            continue
        if k not in _PARAM_KEYS:
            raise _BadRequest(f"unknown field {k!r} (expected one of "
                              f"{sorted(_PARAM_KEYS)})")
        fields[k] = body[k]
    if "stop" in fields:
        stop = fields["stop"]
        if not isinstance(stop, list) \
                or not all(isinstance(t, int) for t in stop):
            raise _BadRequest("'stop' must be a list of token ids")
        fields["stop"] = frozenset(stop)
    try:
        params = SamplingParams(**fields)
    except (TypeError, ValueError) as e:
        raise _BadRequest(str(e)) from e
    return prompt, params, stream


def _result_json(res) -> Dict[str, Any]:
    return {
        "id": res.uid,
        "tokens": list(res.tokens),
        "finish_reason": res.finish_reason,
        "truncated": res.truncated,
        "ttft_s": res.ttft,
        "queue_wait_s": res.queue_wait,
        "error": res.error,
    }


class HttpServer:
    """The asyncio server; all engine access goes through ``driver``."""

    def __init__(self, driver: EngineDriver, host: str = "127.0.0.1",
                 port: int = 0, *, max_body: int = 1 << 22):
        self.driver = driver
        self.host = host
        self.port = port
        self.max_body = max_body
        self._server: Optional[asyncio.AbstractServer] = None
        self._conn_tasks: set = set()

    # ----------------------------------------------------------- lifecycle
    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]

    async def stop(self) -> None:
        """Stop listening and wait for in-flight connections to finish
        (their requests keep running in the engine; only intake stops)."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if self._conn_tasks:
            await asyncio.gather(*list(self._conn_tasks),
                                 return_exceptions=True)

    # ----------------------------------------------------------- plumbing
    async def _driver_call(self, fn):
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            None, functools.partial(self.driver.call, fn))

    @staticmethod
    async def _write_response(writer, status: int, body: bytes,
                              ctype: str = "application/json",
                              extra: Optional[Dict[str, str]] = None):
        head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
                f"Content-Type: {ctype}",
                f"Content-Length: {len(body)}",
                "Connection: close"]
        for k, v in (extra or {}).items():
            head.append(f"{k}: {v}")
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
        await writer.drain()

    @staticmethod
    async def _write_json(writer, status: int, obj: Any,
                          extra: Optional[Dict[str, str]] = None):
        await HttpServer._write_response(
            writer, status, (json.dumps(obj) + "\n").encode(), extra=extra)

    async def _read_request(self, reader):
        """Parse one HTTP/1.1 request; returns (method, path, headers,
        body) or raises ``_BadRequest`` / ``asyncio.IncompleteReadError``."""
        line = await reader.readline()
        if not line:
            raise asyncio.IncompleteReadError(b"", None)
        try:
            method, path, _version = line.decode("latin-1").split(None, 2)
        except ValueError:
            raise _BadRequest("malformed request line") from None
        headers: Dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            if b":" in raw:
                k, v = raw.decode("latin-1").split(":", 1)
                headers[k.strip().lower()] = v.strip()
        length = int(headers.get("content-length", "0") or "0")
        if length > self.max_body:
            raise _BadRequest(f"body too large ({length} > {self.max_body})")
        body = await reader.readexactly(length) if length else b""
        return method, path.split("?", 1)[0], headers, body

    # ------------------------------------------------------------ handlers
    async def _handle_conn(self, reader, writer):
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        try:
            try:
                method, path, headers, body = await self._read_request(reader)
            except (asyncio.IncompleteReadError, ConnectionError):
                return
            except _BadRequest as e:
                await self._write_json(writer, 400, {"error": str(e)})
                return
            if path == "/healthz":
                await self._handle_healthz(writer, method)
            elif path == "/metrics":
                await self._handle_metrics(writer, method)
            elif path == "/v1/completions":
                await self._handle_completions(reader, writer, method, body)
            else:
                await self._write_json(writer, 404,
                                       {"error": f"no route {path!r}"})
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # client went away mid-response
        finally:
            self._conn_tasks.discard(task)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _handle_healthz(self, writer, method):
        if method != "GET":
            await self._write_json(writer, 405, {"error": "GET only"})
            return
        # a supervised driver surfaces its recovery state (generation,
        # restarts, degraded, blacklist) alongside the engine snapshot
        status_fn = getattr(self.driver, "supervisor_status", None)
        sup = status_fn() if status_fn is not None else None
        try:
            snap = await self._driver_call(lambda eng: eng.health())
        except RuntimeError as e:  # engine mid-rebuild / permanently dead
            payload = {"ok": False, "error": str(e)}
            if sup is not None:
                payload["supervisor"] = sup
            await self._write_json(writer, 503, payload)
            return
        payload = dataclasses.asdict(snap)
        payload["ok"] = True
        if sup is not None:
            payload["supervisor"] = sup
        await self._write_json(writer, 200, payload)

    async def _handle_metrics(self, writer, method):
        if method != "GET":
            await self._write_json(writer, 405, {"error": "GET only"})
            return
        text = await self._driver_call(
            lambda eng: eng.obs.registry.render_prometheus())
        await self._write_response(
            writer, 200, text.encode(),
            ctype="text/plain; version=0.0.4; charset=utf-8")

    async def _handle_completions(self, reader, writer, method, body):
        if method != "POST":
            await self._write_json(writer, 405, {"error": "POST only"})
            return
        try:
            payload = json.loads(body.decode("utf-8") or "null")
            prompt, params, stream = _parse_body(payload)
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            await self._write_json(writer, 400,
                                   {"error": f"invalid JSON: {e}"})
            return
        except _BadRequest as e:
            await self._write_json(writer, 400, {"error": str(e)})
            return
        loop = asyncio.get_running_loop()
        try:
            # executor hop: a supervised submit may park briefly while the
            # engine rebuilds — never block the event loop on it
            handle = await loop.run_in_executor(
                None, functools.partial(self.driver.submit, prompt, params))
        except (TypeError, ValueError) as e:
            await self._write_json(writer, 400, {"error": str(e)})
            return
        except DegradedError as e:  # breaker open: shed with Retry-After
            await self._write_json(
                writer, 503, {"error": str(e), "degraded": True},
                extra={"Retry-After": str(max(int(e.retry_after), 1))})
            return

        events: asyncio.Queue = asyncio.Queue()
        handle.subscribe(
            lambda ev: loop.call_soon_threadsafe(events.put_nowait, ev))
        # EOF on the request socket = the client hung up: cancel the
        # request so its slot frees without touching co-batched neighbors
        gone = asyncio.ensure_future(self._watch_disconnect(reader))
        try:
            if stream:
                await self._stream_response(writer, handle, events, gone)
            else:
                await self._unary_response(writer, handle, events, gone)
        finally:
            gone.cancel()
            if not handle.done:
                # any early exit with the request still running — reader
                # EOF, a write to a closed socket, a handler error — means
                # the client is gone: free the slot
                handle.cancel()

    @staticmethod
    async def _watch_disconnect(reader):
        try:
            while True:
                chunk = await reader.read(4096)
                if not chunk:
                    return
        except (ConnectionError, asyncio.CancelledError):
            return

    async def _next_event(self, events: asyncio.Queue, gone: asyncio.Task,
                          handle: DriverHandle):
        """Next handle event, or ``None`` if the client disconnected
        first (in which case the request has been cancelled)."""
        getter = asyncio.ensure_future(events.get())
        done, _pending = await asyncio.wait(
            {getter, gone}, return_when=asyncio.FIRST_COMPLETED)
        if gone in done:  # disconnect wins even if a token is also ready
            getter.cancel()
            handle.cancel()
            return None
        return getter.result()

    def _error_headers(self, res) -> Dict[str, str]:
        extra = {"X-Request-Id": str(res.uid)}
        if res.finish_reason == FINISH_REJECTED:
            extra["Retry-After"] = "1"
        return extra

    async def _unary_response(self, writer, handle, events, gone):
        while True:
            ev = await self._next_event(events, gone, handle)
            if ev is None:
                return  # disconnected; nothing left to write to
            if ev[0] == "done":
                res = ev[1]
                status = STATUS_BY_REASON.get(res.finish_reason, 200) \
                    if not res.tokens else 200
                await self._write_json(writer, status, _result_json(res),
                                       extra=self._error_headers(res))
                return

    async def _stream_response(self, writer, handle, events, gone):
        # hold the status line until the first event: a request that
        # retires with rejected/timeout/error before producing anything
        # still gets a real HTTP error code instead of an empty 200 stream
        first = await self._next_event(events, gone, handle)
        if first is None:
            return
        if first[0] == "done" and not first[1].tokens:
            res = first[1]
            status = STATUS_BY_REASON.get(res.finish_reason, 200)
            await self._write_json(writer, status, _result_json(res),
                                   extra=self._error_headers(res))
            return
        head = ["HTTP/1.1 200 OK",
                "Content-Type: text/event-stream",
                "Cache-Control: no-store",
                f"X-Request-Id: {handle.uid}",
                "Connection: close"]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode())
        ev = first
        while True:
            if ev[0] == "token":
                line = {"id": handle.uid, "index": ev[1], "token": ev[2]}
            else:
                line = _result_json(ev[1])
            writer.write(f"data: {json.dumps(line)}\n\n".encode())
            await writer.drain()
            if ev[0] == "done":
                writer.write(b"data: [DONE]\n\n")
                await writer.drain()
                return
            ev = await self._next_event(events, gone, handle)
            if ev is None:
                return  # disconnected mid-stream; request cancelled


class ThreadedHttpServer:
    """Run an :class:`HttpServer` on a daemon thread with a private event
    loop — the in-process deployment shape (tests/benches/examples):

    >>> driver = EngineDriver(engine).start()
    >>> srv = ThreadedHttpServer(driver).start()
    >>> ...  # requests against http://{srv.host}:{srv.port}
    >>> srv.stop(); driver.drain(); driver.close()
    """

    def __init__(self, driver: EngineDriver, host: str = "127.0.0.1",
                 port: int = 0):
        self.server = HttpServer(driver, host, port)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="http-frontend")
        self._ready = threading.Event()
        self._startup_exc: Optional[BaseException] = None

    @property
    def host(self):
        return self.server.host

    @property
    def port(self):
        return self.server.port

    def _run(self):
        asyncio.set_event_loop(self._loop)
        try:
            self._loop.run_until_complete(self.server.start())
        except BaseException as e:  # port in use, bad host, ...
            self._startup_exc = e
            self._ready.set()
            return
        self._ready.set()
        self._loop.run_forever()
        # drain in-flight connections before the loop is torn down
        self._loop.run_until_complete(self.server.stop())

    def start(self, timeout: float = 10.0) -> "ThreadedHttpServer":
        self._thread.start()
        if not self._ready.wait(timeout):
            raise TimeoutError("HTTP server failed to start")
        if self._startup_exc is not None:
            raise self._startup_exc
        return self

    def stop(self, timeout: float = 10.0) -> None:
        if not self._thread.is_alive():
            return
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout)
        if not self._thread.is_alive() and not self._loop.is_closed():
            self._loop.close()
