"""Per-tenant fair admission: deficit-weighted round-robin (DRR).

The engine's own wait queue stays strict FIFO — that ordering is part of
the v1.2 page-budget admission rule. Fairness therefore lives one layer
up: the :class:`EngineDriver` holds accepted-but-not-yet-offered requests
in a :class:`FairScheduler` and only hands the engine as many as it has
free slots, so the DRR decision *is* the admission order.

DRR (Shreedhar & Varghese): each tenant owns a FIFO queue and a deficit
counter in "committed tokens" (clipped prompt + generation budget — the
same unit the v1.1 ``max_resident_tokens`` cap meters). Tenants sit on a
round-robin ring; when the ring reaches a tenant, its deficit grows by
``quantum * weight`` and it may release requests while the deficit covers
the head request's cost. A tenant that empties its queue loses its
deficit (no banking idle credit), so a flooding tenant can never starve a
trickling one: per ring rotation every backlogged tenant moves
O(quantum * weight) tokens regardless of how deep any other queue is.

Two caps compose with the engine's own admission budgets:

* ``max_pending`` — bound on requests waiting in the frontend across all
  tenants; past it, ``push`` refuses and the driver sheds the request
  with finish_reason ``"rejected"`` (HTTP 429).
* ``tenant_max_resident_tokens`` — per-tenant bound on committed tokens
  *inside the engine* (offered and not yet retired). A tenant at its cap
  is skipped without replenishing its deficit (blocked turns must not
  bank credit) until retirements free room.

Thread safety: this class is plain data guarded by the driver's lock —
every method is called with the :class:`EngineDriver` condition held.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Any, Callable, Dict, Iterator, List, Optional


class _TenantQueue:
    __slots__ = ("name", "q", "deficit", "inflight_tokens")

    def __init__(self, name: str):
        self.name = name
        self.q: deque = deque()
        self.deficit = 0.0
        self.inflight_tokens = 0  # committed tokens offered, not retired


class FairScheduler:
    """Deficit-weighted round-robin over per-tenant FIFO queues.

    Args:
      quantum: deficit replenished per ring visit, in committed tokens.
        Smaller → finer interleaving (more alternation between tenants);
        larger → longer per-tenant runs. Must be >= 1.
      weights: tenant → relative share (default 1.0 each). A tenant with
        weight 2 replenishes twice the deficit per rotation, i.e. twice
        the admission bandwidth under contention.
      max_pending: cap on waiting requests across all tenants (None = no
        cap); ``push`` returns a shed reason past it.
      tenant_max_resident_tokens: per-tenant cap on committed tokens
        concurrently inside the engine (None = no cap).
      cost: request → committed-token cost. The driver binds this to the
        engine's ``min(len(prompt), capacity) + max_new_tokens`` rule;
        the default uses the unclipped prompt length.
    """

    def __init__(self, *, quantum: int = 256,
                 weights: Optional[Dict[str, float]] = None,
                 max_pending: Optional[int] = None,
                 tenant_max_resident_tokens: Optional[int] = None,
                 cost: Optional[Callable[[Any], int]] = None):
        if quantum < 1:
            raise ValueError("quantum must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (None disables)")
        if tenant_max_resident_tokens is not None \
                and tenant_max_resident_tokens < 1:
            raise ValueError("tenant_max_resident_tokens must be >= 1 "
                             "(None disables)")
        self.quantum = quantum
        self.weights = dict(weights or {})
        self.max_pending = max_pending
        self.tenant_max_resident_tokens = tenant_max_resident_tokens
        self._cost = cost
        # ring order = insertion order of tenants with live state; tenants
        # are dropped once both queue and inflight are empty
        self._tenants: "OrderedDict[str, _TenantQueue]" = OrderedDict()
        self._ring: deque = deque()      # tenant names, rotation order
        self._pending = 0
        # True when the front tenant's turn has not replenished yet; a
        # turn spans pop() calls and ends when the ring rotates
        self._turn_fresh = True

    # ------------------------------------------------------------- plumbing
    def bind_cost(self, cost: Callable[[Any], int]) -> None:
        """Install the engine-derived cost rule (driver start-time hook);
        an explicitly constructed ``cost=`` wins."""
        if self._cost is None:
            self._cost = cost

    def _tenant_of(self, h: Any) -> str:
        return getattr(h.params, "tenant", "") or ""

    def _weight(self, tenant: str) -> float:
        w = float(self.weights.get(tenant, 1.0))
        return w if w > 0 else 1.0

    def _get(self, tenant: str) -> _TenantQueue:
        tq = self._tenants.get(tenant)
        if tq is None:
            tq = self._tenants[tenant] = _TenantQueue(tenant)
            self._ring.append(tenant)
        return tq

    def _rotate(self) -> None:
        """End the front tenant's turn: advance the ring; the next front
        tenant starts a fresh turn (entitled to one replenish)."""
        self._ring.rotate(-1)
        self._turn_fresh = True

    def _gc(self, tq: _TenantQueue) -> None:
        """Drop a tenant with no queued and no inflight work (its deficit
        must not survive idleness — that would bank credit)."""
        if not tq.q and tq.inflight_tokens <= 0:
            self._tenants.pop(tq.name, None)
            if self._ring and self._ring[0] == tq.name:
                self._turn_fresh = True  # front changes: fresh turn
            try:
                self._ring.remove(tq.name)
            except ValueError:
                pass

    def cost(self, h: Any) -> int:
        if self._cost is not None:
            return int(self._cost(h))
        return len(h.prompt) + h.params.max_new_tokens

    # ------------------------------------------------------------- mutation
    def push(self, h: Any) -> Optional[str]:
        """Queue a request under its tenant. Returns ``None`` when
        accepted, or a human-readable shed reason (the driver turns it
        into finish_reason ``"rejected"``)."""
        if self.max_pending is not None and self._pending >= self.max_pending:
            return (f"frontend queue full ({self._pending}/"
                    f"{self.max_pending} pending)")
        self._get(self._tenant_of(h)).q.append(h)
        self._pending += 1
        return None

    def pop(self) -> Optional[Any]:
        """Release the next request under DRR order, or ``None`` when no
        tenant can be served right now (empty, or every backlogged tenant
        is at its resident-token cap).

        Charges the request's cost to the tenant's deficit and inflight
        account; the driver must call :meth:`retire` when the request
        leaves the engine.
        """
        # one replenish per tenant per *ring visit* (a visit may span many
        # pop() calls while the deficit lasts; it ends — and the ring
        # rotates — the moment the deficit stops covering the head), so
        # the scan terminates: after a full ring pass either someone's
        # deficit covered their head request or nobody is servable
        for _ in range(len(self._ring)):
            name = self._ring[0]
            tq = self._tenants[name]
            if not tq.q:
                tq.deficit = 0.0
                self._rotate()
                self._gc(tq)
                continue
            head_cost = self.cost(tq.q[0])
            cap = self.tenant_max_resident_tokens
            if cap is not None and tq.inflight_tokens + head_cost > cap:
                # blocked on its own cap: skip WITHOUT replenishing, so a
                # capped tenant cannot bank an unbounded deficit
                self._rotate()
                continue
            if tq.deficit < head_cost:
                if self._turn_fresh:
                    tq.deficit += self.quantum * self._weight(name)
                    self._turn_fresh = False
                if tq.deficit < head_cost:
                    self._rotate()
                    continue
            h = tq.q.popleft()
            tq.deficit -= head_cost
            if not tq.q:
                tq.deficit = 0.0  # no banking credit while idle
            tq.inflight_tokens += head_cost
            h._drr_cost = head_cost  # retire() refunds exactly this
            self._pending -= 1
            return h
        return None

    def retire(self, h: Any) -> None:
        """Refund a previously popped request's inflight tokens (called at
        engine retirement on every finish path)."""
        cost = getattr(h, "_drr_cost", None)
        if cost is None:
            return
        h._drr_cost = None
        tq = self._tenants.get(self._tenant_of(h))
        if tq is None:
            return
        tq.inflight_tokens = max(tq.inflight_tokens - cost, 0)
        self._gc(tq)

    def remove(self, h: Any) -> bool:
        """Withdraw a still-queued request (cancel before offer)."""
        tq = self._tenants.get(self._tenant_of(h))
        if tq is None:
            return False
        try:
            tq.q.remove(h)
        except ValueError:
            return False
        self._pending -= 1
        self._gc(tq)
        return True

    def drain(self) -> List[Any]:
        """Remove and return every waiting request (driver shutdown path);
        inflight accounting is untouched."""
        out: List[Any] = []
        for tq in list(self._tenants.values()):
            out.extend(tq.q)
            tq.q.clear()
            tq.deficit = 0.0
            self._gc(tq)
        self._pending = 0
        return out

    # ---------------------------------------------------------------- reads
    def __len__(self) -> int:
        return self._pending

    def pending(self) -> Iterator[Any]:
        """Iterate waiting requests across tenants (deadline sweeps)."""
        for tq in self._tenants.values():
            yield from tq.q

    def pending_by_tenant(self) -> Dict[str, int]:
        return {name: len(tq.q) for name, tq in self._tenants.items()
                if tq.q}

    def inflight_by_tenant(self) -> Dict[str, int]:
        return {name: tq.inflight_tokens
                for name, tq in self._tenants.items()
                if tq.inflight_tokens}
