"""``repro.serving.frontend`` — the concurrent serving surface (v1.4).

Three layers over the single-threaded engine:

* :mod:`~repro.serving.frontend.driver` — :class:`EngineDriver`, the one
  thread that owns the device; thread-safe submit/cancel/stream/call.
* :mod:`~repro.serving.frontend.fairness` — :class:`FairScheduler`,
  deficit-weighted round-robin admission across per-tenant queues.
* :mod:`~repro.serving.frontend.server` — :class:`HttpServer` /
  :class:`ThreadedHttpServer`, the stdlib-asyncio HTTP + SSE endpoint.

See the v1.4 section of the ``repro.serving`` package docstring for the
frozen contract (threading rules, tenant field, HTTP status mapping).
"""

from repro.serving.frontend.driver import DriverHandle, EngineDriver
from repro.serving.frontend.fairness import FairScheduler
from repro.serving.frontend.server import (STATUS_BY_REASON, HttpServer,
                                           ThreadedHttpServer)

__all__ = [
    "EngineDriver", "DriverHandle", "FairScheduler",
    "HttpServer", "ThreadedHttpServer", "STATUS_BY_REASON",
]
