"""``repro.serving.frontend`` — the concurrent serving surface (v1.5).

Four layers over the single-threaded engine:

* :mod:`~repro.serving.frontend.driver` — :class:`EngineDriver`, the one
  thread that owns the device; thread-safe submit/cancel/stream/call.
* :mod:`~repro.serving.frontend.fairness` — :class:`FairScheduler`,
  deficit-weighted round-robin admission across per-tenant queues.
* :mod:`~repro.serving.frontend.server` — :class:`HttpServer` /
  :class:`ThreadedHttpServer`, the stdlib-asyncio HTTP + SSE endpoint.
* :mod:`~repro.serving.frontend.supervisor` — :class:`EngineSupervisor`,
  crash-restart supervision: engine-death detection (driver fatal path +
  hung-step watchdog), rebuild from the engine factory with a new
  generation id, deterministic replay of in-flight requests, suspect
  blacklisting, and a crash-loop circuit breaker
  (:class:`DegradedError` → HTTP 503).

See the v1.4/v1.5 sections of the ``repro.serving`` package docstring
for the frozen contract (threading rules, tenant field, HTTP status
mapping, recovery semantics).
"""

from repro.serving.frontend.driver import DriverHandle, EngineDriver
from repro.serving.frontend.fairness import FairScheduler
from repro.serving.frontend.server import (STATUS_BY_REASON, HttpServer,
                                           ThreadedHttpServer)
from repro.serving.frontend.supervisor import (DegradedError,
                                               EngineSupervisor, StepTimeout)

__all__ = [
    "EngineDriver", "DriverHandle", "FairScheduler",
    "HttpServer", "ThreadedHttpServer", "STATUS_BY_REASON",
    "EngineSupervisor", "DegradedError", "StepTimeout",
]
