"""Supervised engine recovery: crash-restart, deterministic replay,
hung-step watchdog.

PR 6 contained per-request faults and PR 9's driver fanned ``"error"``
out to every client when the engine itself died. This module closes the
gap: an :class:`EngineSupervisor` owns an engine *factory* (rebuild from
the memmap artifact or in-process quantization), wraps the
:class:`~repro.serving.frontend.driver.EngineDriver` lifecycle, and
turns engine death into a recovery instead of a fleet-wide error:

* **Detection** — a crashed ``engine.step()`` reaches the driver's
  ``_fatal`` path, which (under supervision) hands the exception to the
  supervisor instead of retiring handles; a *hung* step is caught by the
  watchdog, which polls ``EngineDriver.step_age()`` (read off the
  injectable engine clock — no raw wall time) against
  ``watchdog_step_timeout_s``.
* **Recovery** — the dead driver is :meth:`~EngineDriver.reap`-ed (its
  non-retired handles harvested), the factory builds a fresh engine with
  a new **generation id**, and every survivor is
  :meth:`~EngineDriver.adopt`-ed into the new driver. Replayed rows
  regenerate from token 0 under the determinism contract (output is a
  pure function of (params, prompt, SamplingParams)) while the handle's
  ``_delivered`` cursor dedups the already-streamed prefix — an SSE
  client sees its stream continue with no duplicate and no gap.
* **Blame** — the request mid-dispatch at the crash is the suspect: a
  single-attributed suspect is retired ``"error"`` immediately and
  blacklisted from replay; an ambiguous multi-suspect crash replays
  everyone but counts strikes, and ``blacklist_after`` strikes condemn
  the repeat offender. A poison request therefore cannot crash-loop the
  fleet: every crash shrinks the suspect set.
* **Circuit breaker** — exponential backoff between restarts;
  ``max_restarts`` crashes inside ``crash_window_s`` opens the breaker
  (**degraded mode**): new submits raise :class:`DegradedError` (the
  HTTP layer maps it to 503 + Retry-After) while replayable work keeps
  finishing. A crash-free window closes the breaker.

The supervisor duck-types the driver's client surface (``submit`` /
``cancel`` / ``call`` / ``results`` / ``stats`` / ``drain`` / ``close``)
so ``ThreadedHttpServer(supervisor)`` and ``serve.py --supervise`` work
unchanged; ``supervisor_status()`` feeds ``/healthz``.

Timing discipline: decisions (watchdog age, crash windows, MTTR spans)
read injectable clocks — the driver's engine clock for step age, the
supervisor's own ``clock`` (default ``repro.runtime.clock.MONOTONIC``,
a ``VirtualClock`` in tests) for everything else. Real-time *sleeping*
(poll interval, backoff) uses interruptible ``threading.Event.wait``.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.runtime import clock as rtclock
from repro.serving.api import FINISH_ERROR, RequestResult, SamplingParams
from repro.serving.frontend.driver import DriverHandle, EngineDriver
from repro.serving.frontend.fairness import FairScheduler
from repro.serving.observability import TRACK_ENGINE

__all__ = ["EngineSupervisor", "DegradedError", "StepTimeout"]


class DegradedError(RuntimeError):
    """Raised by :meth:`EngineSupervisor.submit` while the crash-loop
    circuit breaker is open (or the engine is permanently dead): the
    caller should retry after ``retry_after`` seconds. The HTTP layer
    maps this to ``503`` with a ``Retry-After`` header."""

    def __init__(self, msg: str, retry_after: float = 1.0):
        super().__init__(msg)
        self.retry_after = retry_after


class StepTimeout(RuntimeError):
    """Synthesized by the watchdog for a hung ``engine.step()`` — plays
    the role of the crash exception for the recovery path. Carries no
    suspect attribution: ``reap`` blames every engine-resident row."""


class EngineSupervisor:
    """Crash-restart supervisor around an :class:`EngineDriver`.

    ``factory`` is a zero-arg callable returning a **fresh** engine
    (fresh ``Observability`` — the registry and ``bind_engine`` are
    single-bind) each call; ``engine`` optionally seeds generation 0
    with a pre-built engine (e.g. the one ``serve.py`` boot-traced).

    Thread model: client threads call the driver-shaped surface; one
    daemon monitor thread handles crash notifications, runs the
    watchdog, performs recoveries, and ages the breaker. The current
    driver swaps atomically under ``_lock``; ``_gen_ready`` is cleared
    for the duration of a rebuild so clients briefly park instead of
    racing a dead driver.
    """

    def __init__(self, factory: Callable[[], Any], *,
                 engine: Any = None,
                 fairness_factory: Optional[Callable[[], FairScheduler]]
                 = None,
                 max_restarts: int = 3,
                 restart_backoff_s: float = 0.05,
                 backoff_factor: float = 2.0,
                 crash_window_s: float = 30.0,
                 watchdog_step_timeout_s: Optional[float] = None,
                 watchdog_poll_s: float = 0.02,
                 blacklist_after: int = 2,
                 retry_after_s: float = 1.0,
                 resume_timeout_s: float = 30.0,
                 clock: Optional[Callable[[], float]] = None,
                 name: str = "engine-supervisor"):
        self._factory = factory
        self._initial_engine = engine
        self._fairness_factory = fairness_factory or FairScheduler
        self.max_restarts = max_restarts
        self.restart_backoff_s = restart_backoff_s
        self.backoff_factor = backoff_factor
        self.crash_window_s = crash_window_s
        self.watchdog_step_timeout_s = watchdog_step_timeout_s
        self.watchdog_poll_s = watchdog_poll_s
        self.blacklist_after = blacklist_after
        self.retry_after_s = retry_after_s
        self.resume_timeout_s = resume_timeout_s
        self._clock = clock if clock is not None else rtclock.MONOTONIC
        self._lock = threading.RLock()
        self._driver: Optional[EngineDriver] = None
        self.generation = 0
        self.restarts = 0
        self.replayed = 0           # requests adopted onto rebuilt engines
        self.degraded = False
        self.dead = False           # factory failed: no more recoveries
        self.blacklist: set = set()
        self.crash_counts: Dict[int, int] = {}   # uid -> suspect strikes
        self.crash_times: List[float] = []
        self.recoveries: List[Dict[str, Any]] = []
        self.last_crash: Optional[str] = None
        self._recovery_durations: List[float] = []
        self._prior_results: List[RequestResult] = []
        self._prior_stats = {"submitted": 0, "frontend_sheds": 0,
                             "frontend_cancelled": 0, "frontend_timeouts": 0}
        self._crash_q: deque = deque()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._gen_ready = threading.Event()
        self._thread = threading.Thread(target=self._monitor, name=name,
                                        daemon=True)
        self._started = False

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "EngineSupervisor":
        if self._started:
            return self
        self._started = True
        eng = self._initial_engine if self._initial_engine is not None \
            else self._factory()
        self._initial_engine = None
        self._bind(eng)
        self._thread.start()
        return self

    def drain(self, timeout: Optional[float] = None) -> bool:
        with self._lock:
            drv = self._driver
        return drv.drain(timeout) if drv is not None else True

    def close(self, timeout: float = 30.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._started and self._thread.is_alive():
            self._thread.join(timeout)
        with self._lock:
            drv = self._driver
        if drv is not None:
            drv.close(timeout)
        self._gen_ready.set()  # unpark any submit/call waiter to fail fast

    @property
    def engine(self):
        """The current generation's engine (drain-report / test surface;
        the same only-between-steps rules as ``EngineDriver.engine``)."""
        with self._lock:
            drv = self._driver
        return drv.engine if drv is not None else None

    @property
    def driver(self) -> Optional[EngineDriver]:
        with self._lock:
            return self._driver

    # ------------------------------------------------------------- clients
    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               tenant: Optional[str] = None) -> DriverHandle:
        """Driver-shaped submit. Degraded/dead → :class:`DegradedError`
        (the 503 path); during a rebuild the call parks briefly on
        ``_gen_ready`` and retries once if it raced the crash."""
        h = None
        for _ in range(2):
            with self._lock:
                if self.degraded or self.dead:
                    raise DegradedError(
                        "engine permanently failed" if self.dead else
                        "service degraded: engine is crash-looping, "
                        "shedding new work while replay finishes",
                        retry_after=self.retry_after_s)
            if not self._gen_ready.wait(self.resume_timeout_s):
                raise DegradedError("engine rebuilding",
                                    retry_after=self.retry_after_s)
            with self._lock:
                drv = self._driver
            h = drv.submit(prompt, params, tenant=tenant)
            # "driver closed" here means we raced the crash: the next
            # generation will accept — retry once against it
            if not (h.done and h.error == "driver closed"):
                return h
        return h

    def cancel(self, h: DriverHandle) -> bool:
        drv = h._driver
        return drv.cancel(h) if drv is not None else False

    def call(self, fn: Callable[[Any], Any], timeout: float = 30.0) -> Any:
        """Run ``fn(engine)`` on the current generation's driver thread
        (retrying once across a racing crash)."""
        last: Optional[BaseException] = None
        for _ in range(2):
            if not self._gen_ready.wait(timeout):
                raise RuntimeError("engine rebuilding")
            with self._lock:
                drv = self._driver
            if drv is None:
                raise RuntimeError("supervisor closed")
            try:
                return drv.call(fn, timeout)
            except RuntimeError as e:  # driver died under us — retry once
                last = e
        raise RuntimeError(f"engine unavailable across restart: {last}")

    def results(self) -> List[RequestResult]:
        with self._lock:
            drv = self._driver
            out = list(self._prior_results)
        if drv is not None:
            out.extend(drv.results())
        return out

    def stats(self) -> Dict[str, int]:
        with self._lock:
            drv = self._driver
            prior = dict(self._prior_stats)
            retired_prior = len(self._prior_results)
        cur = drv.stats() if drv is not None else {
            "submitted": 0, "frontend_sheds": 0, "frontend_cancelled": 0,
            "frontend_timeouts": 0, "pending": 0, "live": 0, "retired": 0}
        for k in prior:
            cur[k] += prior[k]
        cur["retired"] += retired_prior
        cur["generation"] = self.generation
        cur["restarts"] = self.restarts
        cur["replayed"] = self.replayed
        return cur

    def supervisor_status(self) -> Dict[str, Any]:
        """Flat JSON-able snapshot for ``/healthz`` and the stats line."""
        with self._lock:
            return {
                "generation": self.generation,
                "restarts": self.restarts,
                "degraded": self.degraded,
                "dead": self.dead,
                "replayed": self.replayed,
                "blacklisted": sorted(self.blacklist),
                "last_crash": self.last_crash,
                "recoveries": len(self.recoveries),
                "watchdog_step_timeout_s": self.watchdog_step_timeout_s,
            }

    # ----------------------------------------------------- monitor thread
    def _on_fatal(self, exc: BaseException) -> None:
        """Driver-thread callback: hand the crash to the monitor."""
        with self._lock:
            self._crash_q.append(exc)
        self._wake.set()

    def _monitor(self) -> None:
        while not self._stop.is_set():
            fired = self._wake.wait(self.watchdog_poll_s)
            if self._stop.is_set():
                return
            if fired:
                self._wake.clear()
            exc: Optional[BaseException] = None
            with self._lock:
                if self._crash_q:
                    exc = self._crash_q.popleft()
            if exc is None:
                exc = self._check_watchdog()
            if exc is not None:
                self._recover(exc)
            else:
                self._maybe_close_breaker()

    def _check_watchdog(self) -> Optional[BaseException]:
        timeout = self.watchdog_step_timeout_s
        if timeout is None:
            return None
        with self._lock:
            drv = self._driver
        if drv is None or drv.fatal_exc is not None:
            return None
        age = drv.step_age()
        if age is not None and age > timeout:
            return StepTimeout(
                f"engine step exceeded watchdog_step_timeout_s={timeout} "
                f"(running {age:.3f}s on the engine clock)")
        return None

    def _maybe_close_breaker(self) -> None:
        with self._lock:
            if not self.degraded or self.dead:
                return
            now = self._clock()
            if not self.crash_times \
                    or now - self.crash_times[-1] > self.crash_window_s:
                self.degraded = False

    # ------------------------------------------------------------ recovery
    def _recover(self, exc: BaseException) -> None:
        t_detect = self._clock()
        self._gen_ready.clear()
        with self._lock:
            old = self._driver
        if old is None:
            return
        suspects, survivors = old.reap(exc)
        self.last_crash = f"{type(exc).__name__}: {exc}"
        kept = self._condemn(old, exc, suspects, survivors, t_detect)
        with self._lock:
            self._prior_results.extend(old.results())
            self._prior_stats["submitted"] += old.submitted
            self._prior_stats["frontend_sheds"] += old.sheds
            self._prior_stats["frontend_cancelled"] += old.cancelled
            self._prior_stats["frontend_timeouts"] += old.timeouts
            self.crash_times.append(t_detect)
            recent = [t for t in self.crash_times
                      if t_detect - t <= self.crash_window_s]
            if len(recent) >= self.max_restarts:
                self.degraded = True
        old.close(timeout=0.1)  # dead or wedged in step(): don't block
        backoff = self.restart_backoff_s * (
            self.backoff_factor ** max(len(recent) - 1, 0))
        if self._stop.wait(backoff):
            self._retire_all(old, kept, exc)
            return
        try:
            eng = self._factory()
        except Exception as e:  # rebuild itself failed: terminal
            with self._lock:
                self.dead = True
            self._retire_all(old, kept, e)
            self._gen_ready.set()
            return
        with self._lock:
            self.generation += 1
            self.restarts += 1
        rec: Dict[str, Any] = {
            "generation": self.generation, "t_detect": t_detect,
            "suspects": list(suspects), "replayed": len(kept),
            "exc": self.last_crash, "t_first_replayed_token": None}
        drv = self._bind(eng)
        for h in kept:
            self._watch_first_replay(h, rec)
            drv.adopt(h)
            with self._lock:
                self.replayed += 1
        t_restored = self._clock()
        rec["t_restored"] = t_restored
        rec["duration_s"] = t_restored - t_detect
        with self._lock:
            self.recoveries.append(rec)
            self._recovery_durations.append(rec["duration_s"])
        reg = eng.obs.registry
        if "serving_recovery_seconds" in reg:
            reg.get_histogram("serving_recovery_seconds").observe(
                rec["duration_s"])
        if eng.obs.trace is not None:
            eng.obs.trace.complete(
                "recovery", TRACK_ENGINE, t_detect, t_restored,
                cat="supervisor",
                args={"generation": self.generation,
                      "replayed": len(kept), "suspects": list(suspects)})
        self._gen_ready.set()

    def _condemn(self, old: EngineDriver, exc: BaseException,
                 suspects: Tuple[int, ...], survivors: List[DriverHandle],
                 now: float) -> List[DriverHandle]:
        """Strike every suspect; blacklist an unambiguous one immediately
        and any uid reaching ``blacklist_after`` strikes. Returns the
        survivors still eligible for replay (blacklisted ones retire
        ``"error"`` exactly once, on the *old* driver so their record
        lands before the generation swap)."""
        with self._lock:
            for uid in suspects:
                self.crash_counts[uid] = self.crash_counts.get(uid, 0) + 1
                if self.crash_counts[uid] >= self.blacklist_after:
                    self.blacklist.add(uid)
            if len(suspects) == 1:
                self.blacklist.add(suspects[0])
            black = set(self.blacklist)
        kept: List[DriverHandle] = []
        for h in survivors:
            if h.uid in black:
                self._retire_error(old, h, exc, now)
            else:
                kept.append(h)
        return kept

    def _retire_error(self, drv: EngineDriver, h: DriverHandle,
                      exc: BaseException, now: float) -> None:
        why = (f"{drv._crash_detail(exc)}; request blacklisted as crash "
               f"suspect (strike {self.crash_counts.get(h.uid, 1)})")
        with drv._cond:
            if h.done:  # never double-retire
                return
            drv._finish_locked(h, RequestResult(
                uid=h.uid, tokens=tuple(h.output),
                finish_reason=FINISH_ERROR, truncated=h.truncated,
                t_submit=h.t_submit, t_first=h.t_first, t_done=now,
                t_admit=h.t_admit, error=why))

    def _retire_all(self, drv: EngineDriver, handles: List[DriverHandle],
                    exc: BaseException) -> None:
        now = self._clock()
        for h in handles:
            self._retire_error(drv, h, exc, now)

    def _watch_first_replay(self, h: DriverHandle,
                            rec: Dict[str, Any]) -> None:
        """One-shot subscriber stamping the first *new* token a replayed
        handle delivers (history replays with index < the pre-crash
        cursor, so they're filtered) — the MTTR endpoint the recovery
        bench reads."""
        d0 = h._delivered

        def watch(ev: tuple) -> None:
            if ev[0] == "token" and ev[1] >= d0 \
                    and rec["t_first_replayed_token"] is None:
                rec["t_first_replayed_token"] = self._clock()

        h.subscribe(watch)

    # ------------------------------------------------------------- binding
    def _bind(self, engine) -> EngineDriver:
        """Build and start the driver for the current generation, carry
        uid allocation forward, and re-register the supervisor's metrics
        on the fresh engine's registry."""
        drv = EngineDriver(engine, fairness=self._fairness_factory(),
                           name=f"engine-driver-gen{self.generation}")
        drv.generation = self.generation
        drv.on_fatal = self._on_fatal
        with self._lock:
            prev = self._driver
        if prev is not None:
            drv._next_uid = max(drv._next_uid, prev._next_uid)
        self._register_metrics(engine)
        drv.start()
        with self._lock:
            self._driver = drv
        self._gen_ready.set()
        return drv

    def _register_metrics(self, engine) -> None:
        reg = engine.obs.registry
        if "serving_engine_restarts_total" in reg:
            return
        reg.counter("serving_engine_restarts_total",
                    poll=lambda: self.restarts,
                    help="engine rebuilds performed by the supervisor")
        reg.counter("serving_requests_replayed_total",
                    poll=lambda: self.replayed,
                    help="requests replayed onto a rebuilt engine")
        reg.gauge("serving_engine_generation",
                  poll=lambda: self.generation,
                  help="current engine generation id (0 = never restarted)")
        reg.gauge("serving_degraded",
                  poll=lambda: int(self.degraded),
                  help="1 while the crash-loop breaker sheds new submits")
        hist = reg.histogram("serving_recovery_seconds", unit="seconds",
                             help="engine death detected -> survivors "
                                  "requeued on the rebuilt engine")
        for d in self._recovery_durations:  # history survives the rebuild
            hist.observe(d)
