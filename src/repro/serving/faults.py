"""Deterministic fault injection for the serving stack.

The containment machinery in ``repro.serving.engine`` (deadline sweep,
load-shedding, per-slot quarantine) is only trustworthy if it can be
*proved* — and faults found in production are neither schedulable nor
repeatable. This module makes them both:

  * :class:`FaultPlan` — a declarative, seeded schedule of faults:
    NaN-poison the logits that produce generated token *k* of request *r*
    (on device, through the real non-finite detection path), raise from the
    *n*-th prefill/decode dispatch (before the device call, so state is
    never half-written), stall the engine's wall clock past a deadline
    at a chosen engine step, kill the whole engine at a chosen dispatch
    (``engine_crash`` — raises ``EngineCrash``, which escapes containment
    and exercises the supervisor's rebuild-and-replay path), and hang a
    chosen step (``stall_step`` — the injected clock jumps and the hook
    blocks until ``release_stalls()``, tripping the hung-step watchdog).
  * :class:`FaultInjector` — the engine-side hook that executes a plan.
    Pass it to ``ServingEngine(..., injector=...)``; a ``None`` injector
    (production) compiles every injection input out of the hot loop.
  * :class:`VirtualClock` — a manually advanced time source substituted
    for ``time.perf_counter`` so deadline expiry is exact and test suites
    never sleep.
  * :func:`corrupt_artifact_shard` / :func:`truncate_artifact_shard` —
    flip a seeded byte in (or tear the tail off) an on-disk trit-plane
    artifact, returning exactly what was damaged so tests can assert the
    reader's integrity report names it.

The keystone property (gated by ``tests/test_faults.py`` and the
``bench_serving_api`` chaos scenario): under any plan, requests the plan
does *not* touch finish with outputs bit-identical to a fault-free run —
injection is row-local, dispatch vetoes happen pre-dispatch, and the
per-request RNG contract makes retirement of a neighbor invisible.
"""

from __future__ import annotations

import dataclasses
import json
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["VirtualClock", "FaultPlan", "FaultInjector",
           "corrupt_artifact_shard", "truncate_artifact_shard"]


class VirtualClock:
    """A deterministic ``time.perf_counter`` stand-in: only advances when
    told to. Engines built with an injector carrying one stamp every
    timestamp (submit, first token, finish) from it."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, "time only moves forward"
        self.now += dt
        return self.now


@dataclasses.dataclass(frozen=True)
class _NanFault:
    uid: int          # request to poison
    gen_index: int    # generated-token index whose logits go NaN


@dataclasses.dataclass(frozen=True)
class _DispatchFault:
    kind: str                 # "prefill" | "decode"
    index: int                # which dispatch of that kind (0-based count)
    uid: Optional[int] = None  # attribute to this request's slot (else the
    #                            whole dispatch is the containment unit)


@dataclasses.dataclass(frozen=True)
class _ClockStall:
    at_step: int      # engine step() ordinal (1-based, first step is 1)
    advance_s: float  # seconds the virtual clock jumps before that step


@dataclasses.dataclass(frozen=True)
class _EngineCrashFault:
    kind: str                  # "prefill" | "decode"
    index: int                 # which dispatch of that kind (0-based count)
    uid: Optional[int] = None  # blame this request (else the whole dispatch
    #                            is suspect — ambiguous attribution)


@dataclasses.dataclass(frozen=True)
class _StallStep:
    at_step: int      # engine step() ordinal (1-based) that hangs
    hang_s: float     # VirtualClock seconds the step appears to take


class FaultPlan:
    """A schedulable set of faults, fully determined at construction.

    The plan is data, not callbacks — two runs of the same plan against the
    same trace inject the same faults at the same points, which is what
    lets the chaos benchmark diff survivor outputs bit-for-bit against a
    fault-free run. ``seed`` feeds only the artifact-corruption helpers
    (choosing which byte to flip); the serving-side schedule is exact.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.nans: List[_NanFault] = []
        self.dispatch_faults: List[_DispatchFault] = []
        self.stalls: List[_ClockStall] = []
        self.crashes: List[_EngineCrashFault] = []
        self.step_stalls: List[_StallStep] = []

    # ------------------------------------------------------------- authoring
    def nan_logits(self, uid: int, gen_index: int) -> "FaultPlan":
        """NaN the logits that would produce generated token ``gen_index``
        of request ``uid`` (0 = the prefill-finisher token)."""
        assert gen_index >= 0
        self.nans.append(_NanFault(uid, gen_index))
        return self

    def dispatch_error(self, kind: str, index: int,
                       uid: Optional[int] = None) -> "FaultPlan":
        """Raise :class:`~repro.serving.engine.EngineFault` from the
        ``index``-th dispatch of ``kind`` ("prefill" | "decode"), attributed
        to ``uid``'s slot when given (else unattributed — the engine must
        contain the whole dispatch)."""
        assert kind in ("prefill", "decode"), kind
        self.dispatch_faults.append(_DispatchFault(kind, index, uid))
        return self

    def stall_clock(self, at_step: int, advance_s: float) -> "FaultPlan":
        """Jump the virtual clock forward by ``advance_s`` seconds at the
        start of engine step ``at_step`` — the deterministic way to expire
        a deadline mid-flight."""
        self.stalls.append(_ClockStall(at_step, advance_s))
        return self

    def engine_crash(self, kind: str, index: int,
                     uid: Optional[int] = None) -> "FaultPlan":
        """Raise :class:`~repro.serving.engine.EngineCrash` from the
        ``index``-th dispatch of ``kind`` — engine death, not a contained
        fault: the exception escapes ``step()`` and kills the driver.
        ``uid`` marks the poison request (the engine attributes it as the
        sole suspect when resident); omitted, every participating row is
        suspect (ambiguous attribution, the supervisor replays them all
        and blacklists repeat offenders)."""
        assert kind in ("prefill", "decode"), kind
        self.crashes.append(_EngineCrashFault(kind, index, uid))
        return self

    def stall_step(self, at_step: int, hang_s: float) -> "FaultPlan":
        """Hang engine step ``at_step``: the injector advances the
        VirtualClock by ``hang_s`` and then blocks inside ``on_step``
        until :meth:`FaultInjector.release_stalls` — from the watchdog's
        point of view the step never returns. ``hang_s`` past the
        supervisor's ``watchdog_step_timeout_s`` makes detection exact
        without any real-time sleeping."""
        assert hang_s >= 0.0
        self.step_stalls.append(_StallStep(at_step, hang_s))
        return self

    def describe(self) -> Dict[str, Any]:
        """JSON-able summary (recorded by the chaos benchmark)."""
        return {
            "seed": self.seed,
            "nan_logits": [dataclasses.asdict(f) for f in self.nans],
            "dispatch_errors": [dataclasses.asdict(f)
                                for f in self.dispatch_faults],
            "clock_stalls": [dataclasses.asdict(f) for f in self.stalls],
            "engine_crashes": [dataclasses.asdict(f) for f in self.crashes],
            "step_stalls": [dataclasses.asdict(f) for f in self.step_stalls],
        }


class FaultInjector:
    """Executes a :class:`FaultPlan` against one engine.

    The engine calls three hooks (see ``ServingEngine``):

      * ``on_step(engine)``     — start of every ``step()``; applies clock
        stalls scheduled for that step.
      * ``before_dispatch(engine, kind, index, slots)`` — may raise
        ``EngineFault`` per the plan (once per planned fault).
      * ``poison_index(uid, gen0, n_steps)`` — the gen-index in
        ``[gen0, gen0 + n_steps)`` at which to NaN that request's logits,
        or None.

    ``clock`` (a :class:`VirtualClock` or None for real time) becomes the
    engine's single time source. One injector drives one engine: fired
    dispatch faults are consumed, so a retried dispatch (survivors repeat
    the step a contained fault skipped) is not re-failed.
    """

    def __init__(self, plan: Optional[FaultPlan] = None,
                 clock: Optional[VirtualClock] = None):
        self.plan = plan or FaultPlan()
        self.clock = clock
        self._fired: set = set()
        self.log: List[Tuple[str, Any]] = []  # what actually fired, in order
        # stall_step machinery: the hook blocks here until release_stalls()
        # (or the test tears the run down); stall_engaged lets a test wait
        # for the hang to actually be in progress before asserting on it
        self._stall_gate = threading.Event()
        self.stall_engaged = threading.Event()

    # --------------------------------------------------------- engine hooks
    def on_step(self, engine):
        for s in self.plan.stalls:
            key = ("stall", s.at_step, s.advance_s)
            if engine.engine_steps == s.at_step and key not in self._fired:
                self._fired.add(key)
                if self.clock is None:
                    raise RuntimeError("stall_clock needs a VirtualClock")
                self.clock.advance(s.advance_s)
                self.log.append(("stall", dataclasses.asdict(s)))
        for s in self.plan.step_stalls:
            key = ("stall_step", s.at_step)
            if engine.engine_steps == s.at_step and key not in self._fired:
                self._fired.add(key)
                if self.clock is None:
                    raise RuntimeError("stall_step needs a VirtualClock")
                # the step "takes" hang_s on the injected clock, then the
                # driver thread wedges until released — exactly what a hung
                # device call looks like to the supervisor's watchdog
                self.clock.advance(s.hang_s)
                self.log.append(("stall_step", dataclasses.asdict(s)))
                self.stall_engaged.set()
                self._stall_gate.wait()

    def release_stalls(self) -> None:
        """Unblock every fired (and future) ``stall_step`` hang. The
        supervisor abandons a hung driver thread rather than joining it;
        tests call this so the daemon thread can exit and the process can
        wind down cleanly."""
        self._stall_gate.set()

    def before_dispatch(self, engine, kind: str, index: int,
                        slots: List[int]):
        from repro.serving.engine import EngineCrash, EngineFault

        for f in self.plan.crashes:
            key = ("crash", f.kind, f.index)
            if f.kind != kind or f.index != index or key in self._fired:
                continue
            self._fired.add(key)
            self.log.append(("crash", dataclasses.asdict(f)))
            raise EngineCrash(
                f"injected engine crash at {kind} dispatch #{index}",
                uid=f.uid)
        for f in self.plan.dispatch_faults:
            key = ("dispatch", f.kind, f.index)
            if f.kind != kind or f.index != index or key in self._fired:
                continue
            self._fired.add(key)
            slot = None
            if f.uid is not None:
                slot = next((i for i, h in enumerate(engine.slots)
                             if h is not None and h.uid == f.uid), None)
            self.log.append(("dispatch", dataclasses.asdict(f)))
            raise EngineFault(
                f"injected {kind} dispatch fault #{index}", slot=slot)

    def poison_index(self, uid: int, gen0: int,
                     n_steps: int) -> Optional[int]:
        for f in self.plan.nans:
            if f.uid == uid and gen0 <= f.gen_index < gen0 + n_steps:
                key = ("nan", f.uid, f.gen_index)
                if key not in self._fired:
                    self._fired.add(key)
                    self.log.append(("nan", dataclasses.asdict(f)))
                return f.gen_index
        return None


# ---------------------------------------------------------------------------
# artifact corruption (the torn/corrupt-shard axis of the plan)
# ---------------------------------------------------------------------------

def _load_manifest(artifact_dir) -> Dict[str, Any]:
    from repro.artifacts.format import MANIFEST_NAME

    return json.loads((Path(artifact_dir) / MANIFEST_NAME).read_text())


def corrupt_artifact_shard(artifact_dir, *, seed: int = 0,
                           tensor: Optional[str] = None,
                           xor: int = 0xFF) -> Dict[str, Any]:
    """Flip one seeded byte inside a committed artifact buffer.

    Picks (deterministically from ``seed``) a tensor buffer — or a buffer
    of the named ``tensor`` — and XORs one in-range byte of its shard.
    Returns {tensor, buffer, shard, shard_offset, buffer_offset, crc32}
    describing the damage, so a test can assert the reader's
    checksum-failure report names exactly this buffer.
    """
    manifest = _load_manifest(artifact_dir)
    rng = np.random.default_rng(seed)
    names = sorted(manifest["tensors"])
    if tensor is None:
        tensor = names[int(rng.integers(len(names)))]
    rec = manifest["tensors"][tensor]
    bufs = sorted(rec["buffers"])
    bname = bufs[int(rng.integers(len(bufs)))]
    buf = rec["buffers"][bname]
    off = buf["offset"] + int(rng.integers(buf["nbytes"]))
    path = Path(artifact_dir) / buf["shard"]
    mask = (xor & 0xFF) or 0x01  # xor=0 would be a no-op "corruption"
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)
        f.seek(off)
        f.write(bytes([byte[0] ^ mask]))
    return {"tensor": tensor, "buffer": bname, "shard": buf["shard"],
            "shard_offset": off, "buffer_offset": off - buf["offset"],
            "crc32": buf["crc32"]}


def truncate_artifact_shard(artifact_dir, *, seed: int = 0,
                            drop_bytes: int = 1) -> Dict[str, Any]:
    """Tear the tail off a seeded shard file (a torn copy / partial
    download). Returns {shard, old_size, new_size}; the reader's
    ``verify="sizes"`` fast mode must reject the artifact without reading
    any tensor bytes."""
    manifest = _load_manifest(artifact_dir)
    rng = np.random.default_rng(seed)
    shard = manifest["shards"][int(rng.integers(len(manifest["shards"])))]
    path = Path(artifact_dir) / shard["file"]
    old = path.stat().st_size
    new = max(old - int(drop_bytes), 0)
    with open(path, "r+b") as f:
        f.truncate(new)
    return {"shard": shard["file"], "old_size": old, "new_size": new}
