"""Zero-perturbation serving observability: metrics registry + lifecycle
trace recorder (the v1.3 contract section in ``repro.serving``).

Two instruments, one clock:

* :class:`MetricsRegistry` — monotone counters, gauges, and fixed-bucket
  histograms under the **frozen** ``SERVING_METRICS`` name/unit schema
  (frozen the way ``api.FINISH_REASONS`` is: dashboards and the heartbeat
  digest depend on these names). Counters and gauges may be *polled*
  (registered with a zero-arg callable reading the engine's own
  bookkeeping ints), which is what makes ``engine.health()`` literally a
  read of the same counters the registry exports — one source of truth,
  two read surfaces. Snapshots export as a JSON dict (one line each in a
  JSONL stream) and as Prometheus text exposition.
* :class:`TraceRecorder` — a bounded ring buffer of span/instant events
  (oldest dropped first, drops counted) covering per-request lifecycle
  (submitted → queued → admitted → prefill chunks → first token → decode
  → retired, with finish_reason and slot/page annotations) and per-step
  engine phases (sweep, admit, prefill dispatch/sync, sample-collect,
  decode dispatch/sync, collect, page maintenance). Exports Chrome/
  Perfetto ``trace.json`` (load in ``ui.perfetto.dev`` or
  ``chrome://tracing``).

:class:`Observability` bundles both behind the engine's single injectable
clock (``repro.runtime.clock``; ``faults.VirtualClock`` substitutes it
wholesale, making every timestamp — and therefore every span duration and
histogram observation — deterministic in tests).

The zero-perturbation contract (carried from every prior PR): nothing in
this module touches the device or the jit cache — all instrumentation is
host-side bookkeeping around (never inside) the compiled dispatches, so
tokens are bit-identical with tracing on, off, or unconfigured, and no
new compile-cache axis exists. Overhead is measured, not assumed:
``benchmarks/bench_observability.py`` gates the traced/untraced tok/s
delta at < 3% and asserts bit-identity.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.runtime import clock as rtclock

__all__ = ["MetricSpec", "SERVING_METRICS", "PHASES",
           "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "TraceRecorder", "Observability"]


# ---------------------------------------------------------------------------
# the frozen metric schema (v1.3)
# ---------------------------------------------------------------------------

#: engine-step phase names (trace span names on the engine track, and the
#: ``serving_phase_<name>_seconds_total`` counter suffixes). ``page_maint``
#: nests inside whichever phase triggered the page bookkeeping (admit,
#: prefill, or decode), so its seconds are also counted by its parent.
PHASES = ("sweep", "admit", "prefill_dispatch", "prefill_sync",
          "sample_collect", "decode_dispatch", "decode_sync", "collect",
          "page_maint")

#: default latency histogram bucket upper bounds, seconds
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
COUNT_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One frozen registry entry: name, kind, unit, meaning."""

    name: str
    kind: str                    # "counter" | "gauge" | "histogram"
    unit: str                    # "1", "seconds", "tokens", "pages", ...
    help: str
    buckets: Optional[Tuple[float, ...]] = None  # histograms only
    paged_only: bool = False     # registered only under kv_layout="paged"


def _phase_specs() -> Tuple[MetricSpec, ...]:
    return tuple(
        MetricSpec(f"serving_phase_{p}_seconds_total", "counter", "seconds",
                   f"cumulative host seconds spent in the '{p}' step phase")
        for p in PHASES)


#: The frozen serving metric schema (v1.3 contract). Names and units are
#: stable the way FINISH_REASONS is: additions are allowed in later
#: contract versions, renames/removals are not.
SERVING_METRICS: Tuple[MetricSpec, ...] = (
    # ---- request lifecycle counters
    MetricSpec("serving_requests_submitted_total", "counter", "1",
               "submit() calls accepted into the engine (incl. sheds)"),
    MetricSpec("serving_requests_completed_total", "counter", "1",
               "requests finished with reason stop/length"),
    MetricSpec("serving_requests_cancelled_total", "counter", "1",
               "requests finished with reason cancelled"),
    MetricSpec("serving_requests_shed_total", "counter", "1",
               "requests rejected at submit by admission control"),
    MetricSpec("serving_requests_timeout_total", "counter", "1",
               "requests retired by the deadline sweep"),
    MetricSpec("serving_requests_error_total", "counter", "1",
               "requests retired by fault containment"),
    MetricSpec("serving_admits_total", "counter", "1",
               "requests admitted into a slot"),
    # ---- engine work counters
    MetricSpec("serving_engine_steps_total", "counter", "1",
               "step() calls"),
    MetricSpec("serving_decode_steps_total", "counter", "1",
               "fused decode steps dispatched (token positions per slot)"),
    MetricSpec("serving_prefill_dispatches_total", "counter", "1",
               "prefill dispatches (bucketed chunks or serial prompts)"),
    MetricSpec("serving_tokens_generated_total", "counter", "tokens",
               "tokens delivered to request outputs"),
    MetricSpec("serving_prefill_tokens_total", "counter", "tokens",
               "prompt tokens consumed by prefill dispatches"),
    MetricSpec("serving_trace_dropped_total", "counter", "1",
               "trace events dropped by the bounded ring buffer"),
    *_phase_specs(),
    # ---- fleet gauges
    MetricSpec("serving_queue_depth", "gauge", "1",
               "requests waiting for a slot"),
    MetricSpec("serving_resident_slots", "gauge", "1",
               "occupied slots"),
    MetricSpec("serving_free_slots", "gauge", "1",
               "admissible slots (excludes quarantined)"),
    MetricSpec("serving_quarantined_slots", "gauge", "1",
               "slots removed from the admission pool by containment"),
    MetricSpec("serving_resident_tokens", "gauge", "tokens",
               "committed tokens over queued + resident requests"),
    # ---- latency / throughput histograms
    MetricSpec("serving_ttft_seconds", "histogram", "seconds",
               "submit -> first generated token", LATENCY_BUCKETS),
    MetricSpec("serving_time_to_token_seconds", "histogram", "seconds",
               "per-request mean seconds per generated token after the "
               "first (observed at retirement)", LATENCY_BUCKETS),
    MetricSpec("serving_queue_wait_seconds", "histogram", "seconds",
               "submit -> admission into a slot", LATENCY_BUCKETS),
    MetricSpec("serving_step_seconds", "histogram", "seconds",
               "engine step() wall duration", LATENCY_BUCKETS),
    MetricSpec("serving_tokens_per_step", "histogram", "tokens",
               "tokens delivered per engine step", COUNT_BUCKETS),
    MetricSpec("serving_prefill_chunk_seconds", "histogram", "seconds",
               "prefill chunk dispatch+sync wall duration",
               LATENCY_BUCKETS),
    # ---- paged-KV pool (registered only for kv_layout="paged" engines)
    MetricSpec("serving_pages_alloc_total", "counter", "pages",
               "physical pages taken from the pool", paged_only=True),
    MetricSpec("serving_pages_release_total", "counter", "pages",
               "page references dropped", paged_only=True),
    MetricSpec("serving_page_forks_total", "counter", "pages",
               "copy-on-write forks", paged_only=True),
    MetricSpec("serving_prefix_hits_total", "counter", "pages",
               "prefix-cache pages reused", paged_only=True),
    MetricSpec("serving_prefix_misses_total", "counter", "1",
               "prefix lookups that ended cold", paged_only=True),
    MetricSpec("serving_prefix_evictions_total", "counter", "pages",
               "prefix-cache entries dropped under pressure",
               paged_only=True),
    MetricSpec("serving_pages_free", "gauge", "pages",
               "unowned physical pages", paged_only=True),
    MetricSpec("serving_pages_used", "gauge", "pages",
               "physical pages with ref > 0", paged_only=True),
    MetricSpec("serving_pages_shared", "gauge", "pages",
               "physical pages with ref > 1 (COW-protected)",
               paged_only=True),
    MetricSpec("serving_page_churn_pages", "histogram", "pages",
               "page alloc+release events per engine step", COUNT_BUCKETS,
               paged_only=True),
    # ---- supervised recovery (v1.5; registered by EngineSupervisor)
    MetricSpec("serving_engine_restarts_total", "counter", "1",
               "engine rebuilds performed by the supervisor"),
    MetricSpec("serving_requests_replayed_total", "counter", "1",
               "requests replayed onto a rebuilt engine"),
    MetricSpec("serving_engine_generation", "gauge", "1",
               "current engine generation id (0 = never restarted)"),
    MetricSpec("serving_degraded", "gauge", "1",
               "1 while the crash-loop breaker sheds new submits"),
    MetricSpec("serving_recovery_seconds", "histogram", "seconds",
               "engine death detected -> survivors requeued on the "
               "rebuilt engine", LATENCY_BUCKETS),
)

SPEC_BY_NAME: Dict[str, MetricSpec] = {s.name: s for s in SERVING_METRICS}


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

class Counter:
    """Monotone push counter (int/float). Never decremented in operation;
    ``reset()`` exists for bench re-baselining only."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Push gauge: last value wins."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v: Union[int, float]) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram with an exact bounded sample window.

    Buckets (cumulative ``le`` counts, Prometheus semantics) plus ``sum``
    and ``count`` are monotone across snapshots. ``percentile(q)`` is
    computed from a bounded ring of the most recent raw observations
    (``window``; exact while ``count <= window``, a recent-window estimate
    after), which is what lets tests reconcile reported percentiles with
    trace span durations bit-for-bit under a virtual clock.
    """

    __slots__ = ("buckets", "bucket_counts", "sum", "count", "max",
                 "_samples")

    def __init__(self, buckets: Tuple[float, ...] = LATENCY_BUCKETS,
                 window: int = 4096):
        self.buckets = tuple(float(b) for b in buckets)
        assert list(self.buckets) == sorted(set(self.buckets)), \
            "bucket bounds must be strictly increasing"
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +Inf last
        self.sum = 0.0
        self.count = 0
        self.max = 0.0
        self._samples: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        self.max = max(self.max, v)
        self._samples.append(v)
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def percentile(self, q: float) -> float:
        """q in [0, 100]; exact over the retained sample window (0.0 when
        empty)."""
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), q))

    def summary(self) -> Dict[str, Any]:
        cum, cumulative = 0, {}
        for b, c in zip(self.buckets, self.bucket_counts):
            cum += c
            cumulative[b] = cum
        return {
            "count": self.count, "sum": self.sum, "max": self.max,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99), "buckets": cumulative,
        }


@dataclasses.dataclass
class _Entry:
    spec: MetricSpec
    instrument: Optional[Union[Counter, Gauge, Histogram]]
    poll: Optional[Callable[[], Union[int, float]]]


class MetricsRegistry:
    """Name → instrument table with polled-read support and two exporters.

    ``counter``/``gauge`` return a push instrument unless ``poll=`` is
    given, in which case snapshots evaluate the callable (the engine's own
    bookkeeping int stays the single source of truth). Histograms are
    always push. Registering a name from ``SERVING_METRICS`` checks the
    kind matches the frozen spec; unknown names are allowed (callers may
    extend) but must not collide.
    """

    def __init__(self):
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()

    # ---- registration -----------------------------------------------------
    def _spec(self, name: str, kind: str, unit: str, help_: str,
              buckets=None) -> MetricSpec:
        frozen = SPEC_BY_NAME.get(name)
        if frozen is not None:
            assert frozen.kind == kind, \
                (f"{name} is frozen as a {frozen.kind}, not a {kind} "
                 "(SERVING_METRICS names/kinds/units are the v1.3 contract)")
            return frozen
        return MetricSpec(name, kind, unit, help_,
                          tuple(buckets) if buckets else None)

    def _add(self, entry: _Entry) -> None:
        if entry.spec.name in self._entries:
            raise ValueError(f"metric {entry.spec.name!r} already registered")
        self._entries[entry.spec.name] = entry

    def counter(self, name: str, *, poll: Optional[Callable] = None,
                unit: str = "1", help: str = "") -> Optional[Counter]:
        spec = self._spec(name, "counter", unit, help)
        inst = None if poll is not None else Counter()
        self._add(_Entry(spec, inst, poll))
        return inst

    def gauge(self, name: str, *, poll: Optional[Callable] = None,
              unit: str = "1", help: str = "") -> Optional[Gauge]:
        spec = self._spec(name, "gauge", unit, help)
        inst = None if poll is not None else Gauge()
        self._add(_Entry(spec, inst, poll))
        return inst

    def histogram(self, name: str, *, buckets: Optional[Tuple] = None,
                  unit: str = "seconds", help: str = "") -> Histogram:
        spec = self._spec(name, "histogram", unit, help, buckets)
        inst = Histogram(spec.buckets or LATENCY_BUCKETS)
        self._add(_Entry(spec, inst, None))
        return inst

    # ---- reads ------------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> List[str]:
        return list(self._entries)

    def spec(self, name: str) -> MetricSpec:
        return self._entries[name].spec

    def value(self, name: str) -> Union[int, float]:
        """Current scalar value of a counter or gauge (polled or push)."""
        e = self._entries[name]
        assert e.spec.kind != "histogram", f"{name} is a histogram"
        return e.poll() if e.poll is not None else e.instrument.value

    def get_histogram(self, name: str) -> Histogram:
        e = self._entries[name]
        assert e.spec.kind == "histogram", f"{name} is not a histogram"
        return e.instrument

    def counters(self) -> Dict[str, Union[int, float]]:
        """name → value for every counter (the monotonicity test surface)."""
        return {n: self.value(n) for n, e in self._entries.items()
                if e.spec.kind == "counter"}

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-able observation of every metric: counters/gauges →
        number, histograms → ``Histogram.summary()`` dicts."""
        out: Dict[str, Any] = {}
        for n, e in self._entries.items():
            out[n] = (e.instrument.summary() if e.spec.kind == "histogram"
                      else self.value(n))
        return out

    # ---- exporters --------------------------------------------------------
    def render_prometheus(self) -> str:
        """Prometheus text exposition format (one frozen name per family)."""
        lines: List[str] = []
        for n, e in self._entries.items():
            s = e.spec
            if s.help:
                lines.append(f"# HELP {n} {s.help}")
            lines.append(f"# TYPE {n} {s.kind}")
            if s.kind != "histogram":
                v = self.value(n)
                lines.append(f"{n} {v:.9g}" if isinstance(v, float)
                             else f"{n} {v}")
                continue
            h: Histogram = e.instrument
            cum = 0
            for b, c in zip(h.buckets, h.bucket_counts):
                cum += c
                lines.append(f'{n}_bucket{{le="{b:.9g}"}} {cum}')
            lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{n}_sum {h.sum:.9g}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"

    def jsonl_line(self, t: Optional[float] = None) -> str:
        """One snapshot as a single JSON line (append to a ``.jsonl``
        stream; ``t`` stamps the observation)."""
        snap = self.snapshot()
        if t is not None:
            snap = {"t": t, **snap}
        return json.dumps(snap, default=float, sort_keys=False)

    def summary_table(self) -> str:
        """Human-readable shutdown table: non-zero counters and gauges,
        then histogram count/p50/p90/p99/max."""
        rows: List[Tuple[str, str]] = []
        hist_rows: List[Tuple[str, str]] = []
        for n, e in self._entries.items():
            if e.spec.kind == "histogram":
                h: Histogram = e.instrument
                if h.count:
                    hist_rows.append(
                        (n, f"n={h.count} p50={h.percentile(50):.4g} "
                            f"p90={h.percentile(90):.4g} "
                            f"p99={h.percentile(99):.4g} max={h.max:.4g} "
                            f"[{e.spec.unit}]"))
            else:
                v = self.value(n)
                if v:
                    rows.append((n, f"{v:.6g}" if isinstance(v, float)
                                 else str(v)))
        if not rows and not hist_rows:
            return "(no observations)"
        width = max(len(n) for n, _ in rows + hist_rows)
        return "\n".join(f"{n:<{width}}  {v}" for n, v in rows + hist_rows)


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------

# Track = (process_name, thread_id): which timeline row an event lands on.
TRACK_ENGINE = ("engine", 0)
TRACK_BOOT = ("boot", 0)


def request_track(uid: int) -> Tuple[str, int]:
    return ("requests", uid)


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    name: str
    cat: str
    ph: str            # "X" complete | "i" instant
    track: Tuple[str, int]
    ts: float          # seconds (clock domain of the recorder's owner)
    dur: float         # seconds (0 for instants)
    args: Optional[Dict[str, Any]] = None


class TraceRecorder:
    """Bounded ring buffer of :class:`TraceEvent`; oldest events drop first
    and ``dropped`` counts them, so a long-lived server's recorder is a
    flight recorder, never a leak. Purely host-side; O(1) per event."""

    def __init__(self, capacity: int = 65536):
        assert capacity >= 1
        self.capacity = int(capacity)
        self._events: deque = deque()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    def _push(self, ev: TraceEvent) -> None:
        if len(self._events) >= self.capacity:
            self._events.popleft()
            self.dropped += 1
        self._events.append(ev)

    def complete(self, name: str, track: Tuple[str, int], t0: float,
                 t1: float, cat: str = "serving",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A span [t0, t1] (Chrome "X" complete event)."""
        self._push(TraceEvent(name, cat, "X", track, t0,
                              max(t1 - t0, 0.0), args))

    def instant(self, name: str, track: Tuple[str, int], t: float,
                cat: str = "serving",
                args: Optional[Dict[str, Any]] = None) -> None:
        self._push(TraceEvent(name, cat, "i", track, t, 0.0, args))

    def events(self) -> List[TraceEvent]:
        return list(self._events)

    # ---- export -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome Trace Event JSON object (Perfetto-loadable):
        ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with ``ts`` /
        ``dur`` in microseconds and process/thread metadata naming the
        tracks (engine / requests / boot; request tids are uids)."""
        pids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        seen_threads = set()
        for ev in self._events:
            pname, tid = ev.track
            pid = pids.setdefault(pname, len(pids) + 1)
            rec: Dict[str, Any] = {
                "name": ev.name, "cat": ev.cat, "ph": ev.ph,
                "pid": pid, "tid": tid, "ts": ev.ts * 1e6,
            }
            if ev.ph == "X":
                rec["dur"] = ev.dur * 1e6
            if ev.ph == "i":
                rec["s"] = "t"  # thread-scoped instant
            if ev.args:
                rec["args"] = ev.args
            events.append(rec)
            seen_threads.add((pname, tid))
        meta: List[Dict[str, Any]] = []
        for pname, pid in pids.items():
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": pname}})
        for pname, tid in sorted(seen_threads):
            tname = f"req {tid}" if pname == "requests" else pname
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": pids[pname], "tid": tid,
                         "args": {"name": tname}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "format": "repro.serving v1.3"}}

    def write(self, path) -> None:
        from pathlib import Path

        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.chrome_trace(), default=float))


# ---------------------------------------------------------------------------
# the bundle the engine carries
# ---------------------------------------------------------------------------

class Observability:
    """Registry + (optional) trace recorder behind one injectable clock.

    Construction is cheap and tracing is **off by default** — an engine
    always has a registry (counters/gauges poll its own bookkeeping ints;
    histograms observe at the points the engine already syncs), while the
    ring-buffer recorder only exists when asked for (``trace=True`` or a
    :class:`TraceRecorder`). The engine that adopts this bundle overwrites
    ``clock`` with its own single time source (a ``faults.VirtualClock``
    under an injector), so traces and metrics share the deadline domain.
    One Observability binds to at most one engine.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 trace: Union[bool, TraceRecorder, None] = None,
                 trace_capacity: int = 65536):
        self.clock: Callable[[], float] = clock or rtclock.MONOTONIC
        self.registry = MetricsRegistry()
        if trace is True:
            trace = TraceRecorder(trace_capacity)
        elif trace is False:
            trace = None
        # identity check, not truthiness: an *empty* TraceRecorder is
        # len() == 0 and must still count as tracing-on
        self.trace: Optional[TraceRecorder] = trace
        self.phase_seconds: Dict[str, float] = {p: 0.0 for p in PHASES}
        self._engine = None
        # histogram shortcuts (None until bind_engine registers them)
        self.h_ttft = self.h_ttt = self.h_queue_wait = None
        self.h_step = self.h_tokens_step = self.h_prefill_chunk = None
        self.h_page_churn = None

    def now(self) -> float:
        return self.clock()

    # ---- span helpers -----------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, track: Tuple[str, int] = TRACK_ENGINE,
             cat: str = "phase", args: Optional[Dict[str, Any]] = None):
        """Time a host-side section: accumulates into the phase counter
        (when ``name`` is a known engine phase) and records a trace span
        (when tracing is on). Timestamps come from the bundle clock, so a
        VirtualClock yields exact, deterministic spans."""
        t0 = self.clock()
        try:
            yield
        finally:
            t1 = self.clock()
            if name in self.phase_seconds:
                self.phase_seconds[name] += t1 - t0
            if self.trace is not None:
                self.trace.complete(name, track, t0, t1, cat=cat, args=args)

    def instant(self, name: str, track: Tuple[str, int], t: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        if self.trace is not None:
            self.trace.instant(name, track, t, args=args)

    # ---- engine wiring ----------------------------------------------------
    def bind_engine(self, engine) -> None:
        """Register the frozen serving metric set, polled from ``engine``'s
        own bookkeeping (and its :class:`~repro.serving.paging.
        PageAllocator` under the paged layout). Called once by the engine
        constructor."""
        assert self._engine is None, \
            "an Observability binds to exactly one engine"
        self._engine = engine
        reg, e = self.registry, engine
        for name, fn in (
            ("serving_requests_submitted_total", lambda: e.submitted),
            ("serving_requests_completed_total", lambda: e.completed),
            ("serving_requests_cancelled_total", lambda: e.cancelled),
            ("serving_requests_shed_total", lambda: e.sheds),
            ("serving_requests_timeout_total", lambda: e.timeouts),
            ("serving_requests_error_total", lambda: e.errors),
            ("serving_admits_total", lambda: e.admits),
            ("serving_engine_steps_total", lambda: e.engine_steps),
            ("serving_decode_steps_total", lambda: e.steps),
            ("serving_prefill_dispatches_total", lambda: e.prefill_steps),
            ("serving_tokens_generated_total", lambda: e.tokens_generated),
            ("serving_prefill_tokens_total", lambda: e.prefill_tokens),
            ("serving_trace_dropped_total",
             lambda: self.trace.dropped if self.trace is not None else 0),
        ):
            reg.counter(name, poll=fn, unit=SPEC_BY_NAME[name].unit,
                        help=SPEC_BY_NAME[name].help)
        for p in PHASES:
            name = f"serving_phase_{p}_seconds_total"
            reg.counter(name, poll=(lambda p=p: self.phase_seconds[p]),
                        unit="seconds", help=SPEC_BY_NAME[name].help)
        for name, fn in (
            ("serving_queue_depth", lambda: len(e.queue)),
            ("serving_resident_slots",
             lambda: sum(1 for s in e.slots if s is not None)),
            ("serving_free_slots",
             lambda: (len(e.slots)
                      - sum(1 for s in e.slots if s is not None)
                      - len(e.quarantined))),
            ("serving_quarantined_slots", lambda: len(e.quarantined)),
            ("serving_resident_tokens", lambda: e.resident_tokens()),
        ):
            reg.gauge(name, poll=fn, unit=SPEC_BY_NAME[name].unit,
                      help=SPEC_BY_NAME[name].help)
        self.h_ttft = self._hist(reg, "serving_ttft_seconds")
        self.h_ttt = self._hist(reg, "serving_time_to_token_seconds")
        self.h_queue_wait = self._hist(reg, "serving_queue_wait_seconds")
        self.h_step = self._hist(reg, "serving_step_seconds")
        self.h_tokens_step = self._hist(reg, "serving_tokens_per_step")
        self.h_prefill_chunk = self._hist(reg,
                                          "serving_prefill_chunk_seconds")
        if getattr(e, "paged", False):
            a = e.alloc
            for name, fn in (
                ("serving_pages_alloc_total", lambda: a.allocs),
                ("serving_pages_release_total", lambda: a.releases),
                ("serving_page_forks_total", lambda: a.forks),
                ("serving_prefix_hits_total", lambda: a.hits),
                ("serving_prefix_misses_total", lambda: a.misses),
                ("serving_prefix_evictions_total", lambda: a.evictions),
            ):
                reg.counter(name, poll=fn, unit=SPEC_BY_NAME[name].unit,
                            help=SPEC_BY_NAME[name].help)
            for name, fn in (
                ("serving_pages_free", lambda: a.free_pages),
                ("serving_pages_used", lambda: a.used_pages()),
                ("serving_pages_shared", lambda: a.shared_pages()),
            ):
                reg.gauge(name, poll=fn, unit=SPEC_BY_NAME[name].unit,
                          help=SPEC_BY_NAME[name].help)
            self.h_page_churn = self._hist(reg, "serving_page_churn_pages")

    @staticmethod
    def _hist(reg: MetricsRegistry, name: str) -> Histogram:
        spec = SPEC_BY_NAME[name]
        return reg.histogram(name, buckets=spec.buckets, unit=spec.unit,
                             help=spec.help)

    # ---- per-request lifecycle --------------------------------------------
    def request_submitted(self, h) -> None:
        if self.trace is not None:
            self.instant("submitted", request_track(h.uid), h.t_submit,
                         args={"uid": h.uid, "prompt_tokens": len(h.prompt),
                               "truncated": h.truncated})

    def request_admitted(self, h, slot: int,
                         pages: Optional[Dict[str, int]] = None) -> None:
        if self.h_queue_wait is not None:
            self.h_queue_wait.observe(max(h.t_admit - h.t_submit, 0.0))
        if self.trace is not None:
            args = {"uid": h.uid, "slot": slot}
            if pages:
                args.update(pages)
            self.instant("admitted", request_track(h.uid), h.t_admit,
                         args=args)

    def prefill_chunk(self, h, slot: int, t0: float, t1: float,
                      take: int, cursor: int) -> None:
        if self.trace is not None:
            self.trace.complete(
                "prefill_chunk", request_track(h.uid), t0, t1,
                cat="lifecycle",
                args={"slot": slot, "tokens": take, "cursor": cursor})

    def request_first_token(self, h) -> None:
        if self.trace is not None:
            self.instant("first_token", request_track(h.uid), h.t_first,
                         args={"uid": h.uid})

    def request_retired(self, h, slot: Optional[int]) -> None:
        """Observe completion histograms and emit the per-request lifecycle
        spans whose durations reconcile exactly with the
        ``RequestResult`` timestamps (t_submit/t_admit/t_first/t_done)."""
        if self.h_ttft is not None and h.t_first:
            self.h_ttft.observe(h.t_first - h.t_submit)
            if len(h.output) > 1:
                self.h_ttt.observe((h.t_done - h.t_first)
                                   / (len(h.output) - 1))
        if self.trace is None:
            return
        tr, track = self.trace, request_track(h.uid)
        args = {"uid": h.uid, "finish_reason": h.finish_reason,
                "tokens": len(h.output), "truncated": h.truncated}
        if slot is not None:
            args["slot"] = slot
        if h.error:
            args["error"] = h.error
        tr.complete("request", track, h.t_submit, h.t_done, cat="lifecycle",
                    args=args)
        t_admit = h.t_admit if h.t_admit else None
        tr.complete("queued", track, h.t_submit,
                    t_admit if t_admit is not None else h.t_done,
                    cat="lifecycle")
        if t_admit is not None and h.t_first:
            tr.complete("prefill", track, t_admit, h.t_first,
                        cat="lifecycle")
        if h.t_first:
            tr.complete("decode", track, h.t_first, h.t_done,
                        cat="lifecycle")
        tr.instant("retired", track, h.t_done,
                   args={"finish_reason": h.finish_reason})

    # ---- heartbeat digest -------------------------------------------------
    def digest(self) -> Dict[str, Any]:
        """Small flat dict for the heartbeat payload: lifecycle counters
        plus headline latency percentiles (seconds)."""
        reg = self.registry
        out: Dict[str, Any] = {}
        for name in ("serving_requests_submitted_total",
                     "serving_requests_completed_total",
                     "serving_requests_shed_total",
                     "serving_requests_timeout_total",
                     "serving_requests_error_total",
                     "serving_tokens_generated_total",
                     "serving_engine_steps_total"):
            if name in reg:
                out[name] = reg.value(name)
        # supervised serving: generation + restart count ride the heartbeat
        # (HEARTBEAT_SCHEMA 3) so the fleet monitor can spot crash-loopers
        if "serving_engine_generation" in reg:
            out["engine_generation"] = reg.value("serving_engine_generation")
        if "serving_engine_restarts_total" in reg:
            out["engine_restarts"] = reg.value(
                "serving_engine_restarts_total")
        for name, key in (("serving_ttft_seconds", "ttft"),
                          ("serving_queue_wait_seconds", "queue_wait"),
                          ("serving_step_seconds", "step")):
            if name in reg:
                hist = reg.get_histogram(name)
                if hist.count:
                    out[f"{key}_p50_s"] = hist.percentile(50)
                    out[f"{key}_p99_s"] = hist.percentile(99)
        return out
