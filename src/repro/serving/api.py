"""Serving API v1: the request/response surface of the engine.

``SamplingParams`` is the frozen per-request contract (what to generate);
``RequestHandle`` is what ``Engine.submit`` returns (how to consume it):
stream tokens as the engine produces them, block for the final
``RequestResult``, or ``cancel()`` at any point. (The pre-v1 ``Request``
record had its one PR of deprecation grace and is gone; ``submit`` takes
token ids + ``SamplingParams`` only.)

Determinism contract
--------------------
A request's output is a pure function of ``(model params, prompt,
SamplingParams)``. The engine derives every random draw for a request from
``SamplingParams.seed`` alone: the i-th generated token (i = 0 for the
token sampled as prefill completes) is drawn with the key
``fold_in(PRNGKey(seed), i)``. No draw consults engine-global state, so
the output cannot depend on co-batched traffic, the scheduler
(``ServingEngine`` vs ``SerialAdmitEngine``), decode/prefill chunk sizes,
or the order requests were admitted. Temperature 0 is pure argmax and uses
no randomness at all.
"""

from __future__ import annotations

import dataclasses
from typing import Any, FrozenSet, Iterable, Iterator, List, Optional

from repro.runtime import clock as rtclock

FINISH_STOP = "stop"          # hit a stop-token id (incl. EngineConfig.eos_id)
FINISH_LENGTH = "length"      # produced max_new_tokens
FINISH_CANCELLED = "cancelled"
FINISH_TIMEOUT = "timeout"    # deadline_s / ttft_deadline_s expired
FINISH_REJECTED = "rejected"  # shed at submit by admission control
FINISH_ERROR = "error"        # fault contained to this request (see .error)

#: every value ``RequestResult.finish_reason`` may take (the v1.1 frozen set)
FINISH_REASONS = (FINISH_STOP, FINISH_LENGTH, FINISH_CANCELLED,
                  FINISH_TIMEOUT, FINISH_REJECTED, FINISH_ERROR)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Frozen per-request generation parameters.

    Attributes:
      max_new_tokens: token budget (the request finishes with reason
        ``"length"`` when it is reached).
      temperature: 0 → greedy argmax (no randomness); > 0 → sample from
        ``softmax(logits / temperature)``.
      top_k: keep only the k highest-probability tokens (0 → disabled).
      top_p: nucleus sampling — keep the smallest prefix of the
        probability-sorted vocabulary whose cumulative mass reaches
        ``top_p`` (1.0 → disabled). Composes with ``top_k`` (both masks
        apply).
      seed: the request's private RNG stream (see module docstring); two
        requests with the same prompt and params produce identical output
        on any scheduler, in any fleet.
      stop: token ids that terminate generation (the stop token itself is
        the last token of the output, matching EOS semantics). The
        engine-wide ``EngineConfig.eos_id`` is always honored in addition.
      deadline_s: end-to-end wall budget, measured from submit. The engine
        sweeps expirations at the start of every ``step()``; an expired
        request (queued or resident) retires with finish_reason
        ``"timeout"``, keeping whatever tokens it already produced.
        ``None`` disables.
      ttft_deadline_s: budget for the *first* token, measured from submit.
        A request that has not produced token 0 when it expires retires
        with ``"timeout"``; once the first token lands this deadline is
        satisfied and only ``deadline_s`` still applies. ``None`` disables.
      tenant: scheduling identity (v1.4). The fair frontend scheduler
        (``repro.serving.frontend``) queues and meters admission per
        tenant; the engine itself ignores it. **Not** a sampling input:
        the determinism contract is over (prompt, the sampling fields) —
        two requests differing only in ``tenant`` produce identical
        output. ``""`` is the anonymous default tenant.
    """

    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop: FrozenSet[int] = frozenset()
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None
    tenant: str = ""

    def __post_init__(self):
        object.__setattr__(self, "stop", frozenset(self.stop))
        if not isinstance(self.tenant, str):
            raise TypeError("tenant must be a string")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1] (1.0 disables)")
        for name in ("deadline_s", "ttft_deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0.0:
                raise ValueError(f"{name} must be > 0 (None disables)")

    @property
    def needs_mask(self) -> bool:
        """True when sampling must run the top-k/top-p support mask."""
        return self.top_k > 0 or self.top_p < 1.0


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """Immutable completion record returned by ``RequestHandle.result()``."""

    uid: int
    tokens: tuple                # generated token ids (prompt not included)
    finish_reason: str           # one of FINISH_REASONS
    truncated: bool              # prompt was clipped to engine capacity
    t_submit: float              # engine clock at submit()
    t_first: float               # engine clock at first generated token
    t_done: float                # engine clock at finish/cancel/retire
    t_admit: float = 0.0         # engine clock at admission into a slot
    #                              (0.0 if the request never admitted)
    error: Optional[str] = None  # contained-fault detail ("error"/"rejected")

    @property
    def ttft(self) -> float:
        """Submit → first token, seconds (0.0 if no token was produced)."""
        return max(self.t_first - self.t_submit, 0.0) if self.t_first else 0.0

    @property
    def queue_wait(self) -> float:
        """Submit → admission, seconds (0.0 if never admitted)."""
        return max(self.t_admit - self.t_submit, 0.0) if self.t_admit else 0.0


class RequestHandle:
    """Live view of one in-flight request; returned by ``Engine.submit``.

    The handle *drives* the engine on demand: iterating ``tokens()`` or
    calling ``result()`` calls ``engine.step()`` until the request
    progresses, so a single-request caller never needs to touch the engine
    loop — while a batch caller may keep calling ``engine.step()`` (or
    ``run()``) itself and just read handles afterwards. Both styles
    compose: a step produces tokens for every resident request at once.
    """

    def __init__(self, engine: Any, uid: int, prompt: List[int],
                 params: SamplingParams):
        self.uid = uid
        self.prompt = list(prompt)
        self.params = params
        self.output: List[int] = []   # generated tokens, grows per step
        self.finish_reason: Optional[str] = None
        self.error: Optional[str] = None  # contained-fault / shed detail
        self.truncated = False
        self.t_submit = 0.0
        self.t_admit = 0.0            # engine clock at admission into a slot
        self.t_first = 0.0
        self.t_done = 0.0
        self._engine = engine
        self._slot: Optional[int] = None  # last slot occupied (trace label)
        self._stop_ids: FrozenSet[int] = params.stop

    # ------------------------------------------------------------ lifecycle
    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def cancelled(self) -> bool:
        return self.finish_reason == FINISH_CANCELLED

    def tokens(self) -> Iterator[int]:
        """Yield each generated token as the engine step producing it
        completes (the first yield lands in the same engine step that
        finishes the prompt's prefill — stream TTFT is engine TTFT).

        Drives ``engine.step()`` while no new token is buffered; safe to
        interleave with other handles' iterators or external ``step()``
        calls.
        """
        i = 0
        while True:
            while i < len(self.output):
                yield self.output[i]
                i += 1
            if self.done:
                return
            self._engine.step()

    def result(self) -> RequestResult:
        """Drive the engine until this request finishes; return the record."""
        while not self.done:
            self._engine.step()
        return RequestResult(
            uid=self.uid, tokens=tuple(self.output),
            finish_reason=self.finish_reason, truncated=self.truncated,
            t_submit=self.t_submit, t_first=self.t_first, t_done=self.t_done,
            t_admit=self.t_admit, error=self.error)

    def cancel(self) -> bool:
        """Cancel the request: a queued request never admits; a resident one
        frees its slot immediately (mid-prefill or mid-decode — the next
        admission reuses the slot cleanly). Tokens already generated stay
        in ``output``. Returns False if the request had already finished.
        """
        return self._engine.cancel(self)


def make_handle(engine: Any, prompt: Any, params: Optional[SamplingParams],
                uid: Optional[int]) -> RequestHandle:
    """Normalize ``submit``'s inputs into a ``RequestHandle`` and stamp
    ``t_submit``."""
    if isinstance(prompt, (str, bytes)):
        raise TypeError("prompt must be a sequence of token ids, not "
                        "text — tokenize first")
    if isinstance(prompt, Iterable):
        prompt = list(prompt)
    else:
        raise TypeError("prompt must be a sequence of token ids")
    h = RequestHandle(engine, uid if uid is not None else -1, prompt,
                      params if params is not None else SamplingParams())
    if not h.prompt:
        raise ValueError("empty prompt")
    # provisional stamp; the engine's own clock overwrites it at submit()
    h.t_submit = rtclock.now()
    return h
