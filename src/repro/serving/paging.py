"""Host-side physical page accounting for the paged int8 KV cache.

The device holds one physical page pool shared by every slot
(``pages_*`` leaves, see ``models.attention.paged_cache_init``); this
module owns which physical page backs which logical page, entirely in
numpy on the host — allocation never touches the device.

Three ideas, one invariant:

* **Refcounts.** Every physical page has a count of table entries that
  point at it, plus one for a prefix-cache hold. A page returns to the
  free list exactly when its count hits zero. Physical page 0 is the
  reserved *null page* (pos ≡ -1 on device, never written); its count is
  pinned so it can never be allocated or freed.
* **Copy-on-write.** A page with refcount > 1 is shared and must never
  be written. The engine calls :meth:`fork` before dispatching a write
  that lands on a shared page: the writer gets a fresh physical id, the
  old id loses one reference, and the device copies the payload
  (``ServingEngine._page_maintenance``). Readers keep bit-identical
  history; the writer diverges privately.
* **Prefix cache.** Fully-written prompt pages are published under their
  *exact* token-tuple key (no hashing — a hash collision would silently
  splice one prompt's KV into another and break determinism). The cache
  holds one reference per entry; entries whose only reference is the
  cache's (refcount == 1) are evictable, LRU-first, when allocation
  would otherwise fail.

Invariant: ``free + Σ(ref > 0)`` partitions the pool — every page is
either on the free list with ref 0, or off it with ref > 0.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["PageAllocator", "PageCacheKey"]

# A prefix-cache key: the exact prompt tokens the page holds, i.e.
# tuple(prompt[: (j + 1) * page_size]) for logical page j. Keys are
# cumulative, so page j's key is a strict extension of page j-1's —
# consecutive-hit lookup walks them in order and stops at the first miss.
PageCacheKey = Tuple[int, ...]


class PageAllocator:
    """Refcounted free-list allocator with LRU prefix-cache eviction.

    Physical ids run 1..n_pages; id 0 is the null page and is never
    handed out. All methods are host-side and O(pages touched).
    """

    def __init__(self, n_pages: int, page_size: int, *,
                 prefix_cache: bool = True):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.prefix_cache_enabled = bool(prefix_cache)
        # pop() takes from the tail: keep low ids first-out for
        # reproducible layouts run-to-run.
        self._free: List[int] = list(range(self.n_pages, 0, -1))
        self.ref = np.zeros(self.n_pages + 1, np.int32)
        self.ref[0] = 1  # null page: pinned, never allocated
        # key -> physical id; insertion order is LRU order (move_to_end
        # on touch), so eviction pops from the front.
        self._cache: "OrderedDict[PageCacheKey, int]" = OrderedDict()
        self._by_page: Dict[int, PageCacheKey] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.forks = 0
        self.peak_used = 0
        # churn totals (monotone): pages taken by alloc() / references
        # dropped by release() — the per-step difference is the page-pool
        # churn metric the observability registry exports
        self.allocs = 0
        self.releases = 0

    # -- gauges ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def shared_pages(self) -> int:
        """Pages referenced more than once (COW-protected)."""
        return int((self.ref[1:] > 1).sum())

    def available(self) -> int:
        """Pages obtainable right now: free ∪ evictable cache entries."""
        evictable = sum(1 for pid in self._cache.values()
                        if self.ref[pid] == 1)
        return len(self._free) + evictable

    # -- allocation --------------------------------------------------------

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh pages (ref = 1 each), evicting cache-only
        entries LRU-first if the free list runs short. All-or-nothing:
        raises MemoryError and restores prior state if ``n`` can't be met
        (evicted cache *entries* are not restored — only page ownership)."""
        got: List[int] = []
        while len(got) < n:
            if not self._free and not self._evict_one():
                for pid in got:  # roll back
                    self.ref[pid] = 0
                    self._free.append(pid)
                raise MemoryError(
                    f"out of KV pages: need {n}, had {len(got)} "
                    f"(pool {self.n_pages}, used {self.used_pages()})")
            pid = self._free.pop()
            self.ref[pid] = 1
            got.append(pid)
        self.allocs += len(got)
        self.peak_used = max(self.peak_used, self.used_pages())
        return got

    def retain(self, pid: int) -> None:
        if pid == 0:
            return  # null page holds are meaningless
        if self.ref[pid] <= 0:
            raise RuntimeError(f"retain of free page {pid}")
        self.ref[pid] += 1

    def release(self, pid: int) -> None:
        if pid == 0:
            return
        if self.ref[pid] <= 0:
            raise RuntimeError(f"release of free page {pid}")
        self.releases += 1
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            # a cached page's cache hold is one of its refs, so reaching
            # zero means it was already evicted (or never cached).
            self._free.append(pid)

    def fork(self, pid: int) -> int:
        """COW: give the caller a private copy-target for shared ``pid``.

        Drops the caller's reference on ``pid`` and returns a fresh page;
        the device-side payload copy is the engine's job."""
        if self.ref[pid] <= 1:
            raise RuntimeError(f"fork of unshared page {pid} "
                               f"(ref {int(self.ref[pid])})")
        new = self.alloc(1)[0]
        self.release(pid)
        self.forks += 1
        return new

    # -- prefix cache ------------------------------------------------------

    def cache_lookup(self, keys: Sequence[PageCacheKey]) -> List[int]:
        """Longest consecutive run of cached pages for ``keys`` (the
        per-page cumulative keys of one prompt, in order). Each returned
        page is retained for the caller. Counters (``hits``/``misses``) are
        the caller's to update — a lookup may be rolled back (admission
        plan aborted for lack of pages), and only committed plans should
        count."""
        out: List[int] = []
        if not self.prefix_cache_enabled:
            return out
        for key in keys:
            pid = self._cache.get(key)
            if pid is None:
                break
            self._cache.move_to_end(key)
            self.retain(pid)
            out.append(pid)
        return out

    def cache_insert(self, key: PageCacheKey, pid: int) -> None:
        """Publish ``pid`` (which the caller owns) under ``key``. The
        cache takes its own reference; duplicate keys just refresh LRU."""
        if not self.prefix_cache_enabled:
            return
        if key in self._cache:
            self._cache.move_to_end(key)
            return
        self.retain(pid)
        self._cache[key] = pid
        self._by_page[pid] = key

    def cached_pages(self) -> int:
        return len(self._cache)

    def _evict_one(self) -> bool:
        """Drop the LRU cache entry whose page nothing else holds."""
        for key, pid in self._cache.items():
            if self.ref[pid] == 1:
                del self._cache[key]
                del self._by_page[pid]
                self.release(pid)
                self.evictions += 1
                return True
        return False

    # -- invariants (tests) -------------------------------------------------

    def check(self) -> None:
        assert self.ref[0] == 1, "null page ref must stay pinned"
        free = set(self._free)
        assert len(free) == len(self._free), "free list has duplicates"
        for pid in range(1, self.n_pages + 1):
            on_free = pid in free
            assert on_free == (self.ref[pid] == 0), (
                f"page {pid}: ref {int(self.ref[pid])}, free={on_free}")
        for key, pid in self._cache.items():
            assert self.ref[pid] >= 1 and self._by_page[pid] == key
