"""``repro.serving`` — Serving API v1: the stable request/response contract.

Like ``repro.artifacts`` freezes the artifact manifest schema, this package
docstring freezes the serving surface every later layer (HTTP frontend,
sharded serving, TPU deployment) builds against. The contract, v1:

Submission
----------
``engine.submit(prompt: list[int], params: SamplingParams = SamplingParams())
-> RequestHandle``. ``SamplingParams`` is frozen: ``max_new_tokens``,
``temperature`` (0 → greedy), ``top_k`` (0 → off), ``top_p`` (1.0 → off),
``seed`` (the request's private RNG stream), ``stop`` (a set of token ids
that terminate generation, honored in addition to the engine-wide
``EngineConfig.eos_id``; the stop token is the last token of the output).

Consumption
-----------
``RequestHandle.tokens()`` — a generator yielding each generated token in
the engine step that produced it (it drives ``engine.step()`` on demand, so
the first yield lands in the same step the prompt's prefill completes:
stream TTFT **is** engine TTFT). ``RequestHandle.result()`` — block until
finished, returning an immutable ``RequestResult`` (tokens, finish_reason
``"stop" | "length" | "cancelled"``, ``truncated``, and the timing triplet
``t_submit / t_first / t_done``). ``RequestHandle.cancel()`` — a queued
request never admits; a resident one frees its slot immediately
(mid-prefill or mid-decode) without perturbing co-resident requests.
Batch callers may instead drive ``engine.step()`` / ``engine.run()``
themselves and read the same handles afterwards — both styles compose.

Determinism (the testable guarantee)
------------------------------------
A request's output is a pure function of (model params, prompt,
``SamplingParams``). Every random draw comes from the request's own stream
— token i uses ``fold_in(PRNGKey(params.seed), i)``, evaluated on device
inside the fused decode scan — never from engine-global state. Output is
therefore bit-identical whether the request runs alone, co-batched with
arbitrary traffic, on ``ServingEngine`` or ``SerialAdmitEngine``, or across
any decode/prefill chunking. Temperature 0 is pure argmax (no RNG at all)
and matches the teacher-forced ``forward`` argmax path.

Engines
-------
``ServingEngine`` — bucketed batched admission + chunked prefill
interleaved with the fused multi-step decode loop (the production
scheduler). ``SerialAdmitEngine`` — the PR-1 one-prompt-at-a-time
admission baseline. Both implement the identical v1 contract, which is
what makes the determinism guarantee scheduler-independent.
"""

from repro.serving.api import RequestHandle, RequestResult, SamplingParams
from repro.serving.engine import (EngineConfig, SerialAdmitEngine,
                                  ServingEngine)
from repro.serving.sampling import (request_keys, sample_token, sample_tokens,
                                    sample_tokens_per_request,
                                    top_k_top_p_mask)

__all__ = [
    "SamplingParams", "RequestHandle", "RequestResult",
    "ServingEngine", "SerialAdmitEngine", "EngineConfig",
    "sample_token", "sample_tokens", "sample_tokens_per_request",
    "request_keys", "top_k_top_p_mask",
]
