"""``repro.serving`` — Serving API v1: the stable request/response contract.

Like ``repro.artifacts`` freezes the artifact manifest schema, this package
docstring freezes the serving surface every later layer (HTTP frontend,
sharded serving, TPU deployment) builds against. The contract, v1:

Submission
----------
``engine.submit(prompt: list[int], params: SamplingParams = SamplingParams())
-> RequestHandle``. ``SamplingParams`` is frozen: ``max_new_tokens``,
``temperature`` (0 → greedy), ``top_k`` (0 → off), ``top_p`` (1.0 → off),
``seed`` (the request's private RNG stream), ``stop`` (a set of token ids
that terminate generation, honored in addition to the engine-wide
``EngineConfig.eos_id``; the stop token is the last token of the output),
``deadline_s`` / ``ttft_deadline_s`` (wall budgets measured from submit;
None → none).

Deadlines (v1.1)
----------------
The engine sweeps expirations at the start of every ``step()``: a request
past ``deadline_s`` — or past ``ttft_deadline_s`` with no first token yet —
retires with frozen ``finish_reason`` ``"timeout"``, wherever it is
(queued, mid-prefill, or mid-decode), keeping the tokens it already
produced. The freed slot is reusable at that same step's admission, and
co-batched survivors are bit-unperturbed (the cancellation guarantee,
extended to every retirement path).

Admission control (v1.1)
------------------------
``EngineConfig.max_queue`` caps waiting requests and
``EngineConfig.max_resident_tokens`` caps the committed token footprint
(clipped prompt + generation budget) over queued + resident work. A submit
that would exceed a cap is **shed** under ``admission_policy="reject"`` —
the handle returns already finished with reason ``"rejected"`` and a
human-readable ``error`` — or, under ``"block"``, drives ``step()`` until
the fleet drains enough to accept (a request too large to *ever* fit is
rejected regardless). Overload therefore degrades to fast rejections or
progress-coupled blocking, never unbounded queue growth.

Fault containment (v1.1)
------------------------
Non-finite logits detected for a row (checked on device every decode step
and at prefill completion) and device dispatch exceptions retire the
offending request with frozen reason ``"error"`` (detail in ``.error``) and
quarantine its slot out of the admission pool (``engine.rehabilitate()``
row-resets and restores quarantined slots); the engine keeps stepping and
co-batched survivors are bit-unperturbed. ``engine.health()`` returns a
``repro.runtime.monitor.HealthSnapshot`` (queue depth, occupancy,
quarantined slots, shed/timeout/error counters). The deterministic
fault-injection harness in ``repro.serving.faults`` (``FaultPlan`` /
``FaultInjector`` / ``VirtualClock``) schedules all of the above
repeatably; ``ServingEngine(..., injector=None)`` — the production default
— compiles every injection input out.

The full frozen ``finish_reason`` set (``api.FINISH_REASONS``):
``"stop" | "length" | "cancelled" | "timeout" | "rejected" | "error"``.

Paged KV cache (v1.2)
---------------------
``EngineConfig.kv_layout="paged"`` virtualizes every slot's KV ring into
``page_size``-token physical pages drawn from one pool of ``max_pages``
pages shared by the whole fleet (default: exactly the ring footprint,
``max_slots · capacity/page_size``; set lower to overcommit). Semantics:

* **Paged semantics.** A slot's logical ring is unchanged — same
  capacity, same sliding-window/wrap masking, same int8 quantization —
  only its storage is indirected through a per-slot page table
  (``repro.kernels.chunk_attention.chunk_attention_paged``). ``"ring"``
  remains the default layout and the bit-identity oracle.
* **COW prefix sharing** (``EngineConfig.prefix_cache``, default on).
  Fully prompt-filled pages are published under their *exact* prompt-
  prefix token tuple (never a hash — a collision would splice one
  request's KV into another). A later request adopts the longest cached
  run read-only and those tokens skip prefill entirely (lower TTFT); any
  write to a shared page forks it first, so readers keep bit-identical
  history. Reuse auto-disables for models with recurrent mixers (their
  state cannot skip tokens) and for truncated prompts.
* **Determinism guarantee.** A request's output remains a pure function
  of (params, prompt, ``SamplingParams``) — bit-identical whether its
  prefix was shared or recomputed, and identical to the ``"ring"``
  layout. (The skipped-prefix length is trimmed to a ``prefill_chunk``
  multiple so warm runs replay the cold run's dispatch sequence.)
* **Page-budget admission rule.** Admission reserves a request's
  worst-case page need up front — ``min(ceil((clipped_prompt +
  max_new_tokens)/page_size), capacity/page_size)`` pages, counting COW
  fork targets for wrap-bound requests — composing with ``max_queue`` /
  ``max_resident_tokens``: the queue head waits (strict FIFO) until the
  pool can cover it, a request whose worst case exceeds the whole pool
  sheds at submit, and every retirement path (finish, cancel, timeout,
  error) returns its pages. Under pool pressure, unreferenced prefix-
  cache pages evict LRU-first.

``engine.health()`` gains page-pool gauges (``pages_free/used/shared``,
``prefix_hits/misses/evictions``) and ``engine.memory_stats()`` reports
``kv_resident_bytes`` — bytes of *used* pages, the requests-per-GB number
— under paging.

Observability (v1.3)
--------------------
Every engine carries an ``Observability`` bundle (``engine.obs``; pass
``observability=`` to share one across boot + engine, or leave it unset —
a default bundle with tracing off is always attached). Its parts:

* **Metrics registry** (``engine.obs.registry``, a ``MetricsRegistry``).
  The metric *names, kinds, and units* in
  ``observability.SERVING_METRICS`` are frozen exactly like
  ``FINISH_REASONS`` — scrape pipelines and dashboards may depend on
  them. Counters are monotone for the engine's lifetime; gauges describe
  the instant of the read; histograms expose Prometheus cumulative
  buckets plus exact windowed percentiles (``percentile(q)`` over the
  last 4096 observations). Export as Prometheus text
  (``registry.render_prometheus()``), a JSONL snapshot line
  (``registry.jsonl_line()``), or an aligned summary table. The page-pool
  metrics register only under ``kv_layout="paged"``.
  ``engine.health()`` is now *derived from* the registry — a snapshot
  and a scrape can never disagree.
* **Lifecycle + step tracing** (``engine.obs.trace``, a bounded-ring
  ``TraceRecorder``; ``Observability(trace=True)`` enables it, default
  off). Each request emits spans submitted → queued → admitted →
  prefill chunks → first token → decode → retired on its own track
  (annotated with slot, pages, and ``finish_reason``); each engine step
  emits phase spans (sweep, admit, prefill dispatch/sync, sample
  collect, decode dispatch/sync, collect, page maintenance); artifact
  boot phases land on a "boot" track. ``trace.write(path)`` emits
  Chrome/Perfetto ``trace.json``. When the ring overflows, the *oldest*
  events drop and ``serving_trace_dropped_total`` counts them.
* **Clock injection.** All engine timestamps flow through one injectable
  clock (``repro.runtime.clock``; ``faults.VirtualClock`` duck-types
  it), so a seeded ``FaultPlan`` run produces a fully deterministic
  trace whose span durations reconcile *exactly* with
  ``RequestResult.t_submit/t_first/t_done`` and the histogram
  percentiles. Direct wall-clock calls are banned from the serving and
  model layers by a static guard test.
* **Zero perturbation** (the testable guarantee, like determinism): a
  request's tokens are bit-identical with tracing on, off, or the
  bundle left unconfigured. Instrumentation is host-side only and never
  adds a compile-cache axis; ``benchmarks/bench_observability.py``
  bounds the tok/s overhead of tracing at < 3%.

``RequestResult`` additionally carries ``t_admit`` and the derived
``queue_wait`` (0.0 for never-admitted requests); heartbeat payloads are
now versioned (``runtime.monitor.HEARTBEAT_SCHEMA``) and
``HealthSnapshot.beat(..., metrics=engine.obs.digest())`` folds a metrics
digest into the heartbeat file a ``StragglerDetector`` reads.

Concurrent frontend (v1.4)
--------------------------
``repro.serving.frontend`` is the concurrent serving surface; the
engines themselves stay single-threaded and the cooperative style below
remains the in-process baseline (and the bit-identity oracle).

* **Driver threading rules.** ``EngineDriver(engine).start()`` spawns
  the one thread that owns the device: after ``start()``, no other
  thread may call any engine method. Clients use the driver's
  thread-safe ``submit(prompt, params, tenant=...)`` / ``cancel`` and
  the returned ``DriverHandle`` — same reading surface as
  ``RequestHandle`` but passive: ``tokens()`` reads a per-request queue
  fed in the engine step that produced each token (stream TTFT is
  engine TTFT), ``result()`` waits instead of stepping, and
  ``subscribe(fn)`` replays history then attaches (no token can be
  lost to the submit/attach race). Engine reads while the driver runs
  go through ``driver.call(fn)``, which executes ``fn(engine)`` on the
  driver thread between steps. ``drain()`` stops intake (waiting
  requests shed ``"rejected"``; offered work finishes or deadlines
  out); ``close()`` cancels the rest and joins. Determinism is
  unchanged — outputs through the driver are bit-identical to
  cooperative ``submit()``, any thread interleaving.
* **The tenant field.** ``SamplingParams.tenant`` (default ``""``) is a
  scheduling identity, not a sampling input: the determinism contract
  is over (params, prompt, the sampling fields) and ignores it. The
  driver's ``FairScheduler`` holds accepted requests in per-tenant
  queues under deficit-weighted round-robin (quantum/weights in
  committed tokens — the v1.1 unit) and offers the engine at most its
  free admissible slots, so DRR order *is* admission order while the
  engine's internal FIFO (and the v1.1/v1.2 caps and page budgets,
  which still apply to every offer) stays shallow. Per-tenant
  ``tenant_max_resident_tokens`` caps a tenant's committed tokens in
  the engine; a capped tenant skips its turn without banking deficit,
  so a flooding tenant bounds no one's admission latency but its own.
* **HTTP status mapping.** The asyncio frontend (``HttpServer``;
  ``serve.py --http HOST:PORT``) maps terminal outcomes known before
  the response body starts: ``"rejected"`` → 429 with ``Retry-After``,
  ``"timeout"`` → 504, ``"error"`` → 500; malformed input → 400. Every
  ``/v1/completions`` response carries ``X-Request-Id: <uid>`` (the id
  trace spans are annotated with). ``POST /v1/completions`` with
  ``"stream": true`` is SSE — one ``data:`` event per token, a
  terminal result event, ``data: [DONE]``; client disconnect cancels
  the request. ``GET /healthz`` is the ``HealthSnapshot`` as JSON;
  ``GET /metrics`` is ``render_prometheus()`` (plus frontend-only
  additions ``serving_frontend_shed_total`` /
  ``serving_frontend_queue_depth``, registered when a driver starts).
  Once streaming has begun the status is committed; late outcomes
  arrive in the terminal SSE event instead.

Supervised recovery (v1.5)
--------------------------
``EngineSupervisor`` (``repro.serving.frontend.supervisor``; ``serve.py
--supervise``) wraps the driver lifecycle so engine *death* — an
exception escaping ``engine.step()`` (``EngineCrash`` from the fault
plan's ``engine_crash``, or any real crash) or a hung step flagged by
the watchdog (``step_age() > watchdog_step_timeout_s``, read off the
injectable clock) — becomes a recovery, not a fleet-wide ``"error"``:

* **Engine generations.** The supervisor owns an engine *factory*
  (rebuild from the memmap artifact or in-process quantization). Each
  rebuild gets a fresh engine, driver, and registry under a new integer
  generation id (gauge ``serving_engine_generation``; heartbeats carry
  ``engine_generation`` / ``engine_restarts`` under HEARTBEAT_SCHEMA 3).
* **Replay guarantee.** Every non-retired request is re-queued on the
  new generation, keeping its uid, handle, subscribers, and original
  timestamps. The determinism contract (output is a pure function of
  (params, prompt, SamplingParams)) means replay regenerates the same
  stream from token 0; the handle's delivered-token cursor skips the
  already-streamed prefix, so an SSE client sees its stream continue
  with **no duplicated and no dropped token** and a final result
  bit-identical to a crash-free run.
* **Suspects and the blacklist.** The request mid-dispatch at the crash
  is the suspect. A single-attributed suspect retires ``"error"``
  exactly once (crash detail in ``.error`` and the HTTP 500 body) and
  never replays; an ambiguous multi-row crash replays everyone but
  counts strikes, and a repeat offender is blacklisted — a poison
  request cannot crash-loop the fleet.
* **Degraded mode.** Exponential backoff between restarts; ≥
  ``max_restarts`` crashes inside ``crash_window_s`` open the circuit
  breaker: new submits shed with HTTP **503 + Retry-After**
  (``DegradedError``) while replayable work finishes, and a crash-free
  window closes the breaker. ``GET /healthz`` carries the supervisor
  block (generation, restarts, degraded, blacklist).
* **Unchanged surface.** ``FINISH_REASONS`` is untouched — recovery
  introduces no new terminal state (crash victims that cannot replay
  retire with the existing ``"error"``), and the supervisor duck-types
  the driver's client surface, so every v1.4 rule above applies
  verbatim under supervision.

Consumption
-----------
``RequestHandle.tokens()`` — a generator yielding each generated token in
the engine step that produced it (it drives ``engine.step()`` on demand, so
the first yield lands in the same step the prompt's prefill completes:
stream TTFT **is** engine TTFT). ``RequestHandle.result()`` — block until
finished, returning an immutable ``RequestResult`` (tokens, a
``finish_reason`` from ``FINISH_REASONS``, ``truncated``, ``error`` detail
for contained faults/sheds, and the timing triplet
``t_submit / t_first / t_done``). ``RequestHandle.cancel()`` — a queued
request never admits; a resident one frees its slot immediately
(mid-prefill or mid-decode) without perturbing co-resident requests.
Batch callers may instead drive ``engine.step()`` / ``engine.run()``
themselves and read the same handles afterwards — both styles compose.

Determinism (the testable guarantee)
------------------------------------
A request's output is a pure function of (model params, prompt,
``SamplingParams``). Every random draw comes from the request's own stream
— token i uses ``fold_in(PRNGKey(params.seed), i)``, evaluated on device
inside the fused decode scan — never from engine-global state. Output is
therefore bit-identical whether the request runs alone, co-batched with
arbitrary traffic, on ``ServingEngine`` or ``SerialAdmitEngine``, or across
any decode/prefill chunking. Temperature 0 is pure argmax (no RNG at all)
and matches the teacher-forced ``forward`` argmax path.

Engines
-------
``ServingEngine`` — bucketed batched admission + chunked prefill
interleaved with the fused multi-step decode loop (the production
scheduler). ``SerialAdmitEngine`` — the PR-1 one-prompt-at-a-time
admission baseline. Both implement the identical v1 contract, which is
what makes the determinism guarantee scheduler-independent.
"""

from repro.runtime.monitor import HealthSnapshot
from repro.serving.api import (FINISH_REASONS, RequestHandle, RequestResult,
                               SamplingParams)
from repro.serving.engine import (EngineConfig, EngineCrash, EngineFault,
                                  SerialAdmitEngine, ServingEngine)
from repro.serving.faults import FaultInjector, FaultPlan, VirtualClock
from repro.serving.frontend import (DegradedError, DriverHandle, EngineDriver,
                                    EngineSupervisor, FairScheduler,
                                    HttpServer, ThreadedHttpServer)
from repro.serving.observability import (SERVING_METRICS, MetricsRegistry,
                                         Observability, TraceRecorder)
from repro.serving.paging import PageAllocator
from repro.serving.sampling import (request_keys, sample_token, sample_tokens,
                                    sample_tokens_per_request,
                                    top_k_top_p_mask)

__all__ = [
    "SamplingParams", "RequestHandle", "RequestResult", "FINISH_REASONS",
    "ServingEngine", "SerialAdmitEngine", "EngineConfig", "EngineFault",
    "EngineCrash",
    "FaultPlan", "FaultInjector", "VirtualClock", "HealthSnapshot",
    "PageAllocator",
    "EngineDriver", "DriverHandle", "FairScheduler", "HttpServer",
    "ThreadedHttpServer", "EngineSupervisor", "DegradedError",
    "Observability", "MetricsRegistry", "TraceRecorder", "SERVING_METRICS",
    "sample_token", "sample_tokens", "sample_tokens_per_request",
    "request_keys", "top_k_top_p_mask",
]
