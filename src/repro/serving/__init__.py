from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.sampling import sample_token

__all__ = ["ServingEngine", "EngineConfig", "Request", "sample_token"]
