from repro.serving.engine import (EngineConfig, Request, SerialAdmitEngine,
                                  ServingEngine)
from repro.serving.sampling import sample_token, sample_tokens

__all__ = ["ServingEngine", "SerialAdmitEngine", "EngineConfig", "Request",
           "sample_token", "sample_tokens"]
