"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

Two generations of the surface live here:

  * ``sample_token`` / ``sample_tokens`` — the pre-v1 forms (single key for
    the whole batch, scalar ``top_k``). Kept because they are the right
    tool when requests *should* share a stream (benchmark baselines) and
    as the reference the per-request forms are tested against at
    temperature 0.
  * ``request_keys`` + ``sample_tokens_per_request`` — the Serving API v1
    forms: every batch row draws from its own key, so a row's tokens are a
    pure function of its ``SamplingParams.seed`` and its own logits
    regardless of what shares the batch. ``top_k``/``top_p`` are per-row
    vectors (0 / 1.0 disable per row), applied through one sorted support
    mask (``top_k_top_p_mask``) that matches the NumPy reference in
    tests/test_serving.py row for row.

Everything is shape-static and fully vectorized, so all of it fuses into
the jitted decode ``lax.scan`` — no host branching per slot.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key: jax.Array, *,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (B, V) -> tokens (B,). temperature==0 → greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperatures: jax.Array, *, top_k: int = 0) -> jax.Array:
    """Per-row temperatures, one shared key: logits (B, V) -> tokens (B,).

    Rows with temperature <= 0 take the argmax; the rest sample from
    logits / temperature (optionally top-k-truncated). Pre-v1 form — rows
    share one draw stream, so a row's tokens depend on batch composition;
    the engine uses :func:`sample_tokens_per_request` instead.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures.astype(jnp.float32), 1e-6)[:, None]
    scaled = logits.astype(jnp.float32) / t
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperatures <= 0.0, greedy, sampled)


# ---------------------------------------------------------------------------
# Serving API v1: per-request streams + row-wise top-k / top-p
# ---------------------------------------------------------------------------

def request_keys(seeds: jax.Array, indices: jax.Array) -> jax.Array:
    """The per-request RNG stream: keys (B, 2) for the ``indices[b]``-th
    generated token of a request seeded ``seeds[b]``.

    ``fold_in(PRNGKey(seed), i)`` is position-addressed, not split-chained:
    the key for token i never depends on how many tokens were drawn per
    dispatch, which is what makes a request's output invariant to decode
    chunk boundaries, scheduler choice, and fleet composition.
    """
    return jax.vmap(
        lambda s, i: jax.random.fold_in(jax.random.PRNGKey(s), i)
    )(seeds.astype(jnp.uint32), indices)


def top_k_top_p_mask(logits: jax.Array,
                     top_k: Optional[jax.Array] = None,
                     top_p: Optional[jax.Array] = None) -> jax.Array:
    """Row-wise sampling-support mask: True where a token stays eligible.

    logits (B, V); top_k (B,) int (0 disables that row); top_p (B,) float
    (1.0 disables that row). Top-p keeps the smallest probability-sorted
    prefix whose cumulative mass reaches top_p (the max-probability token
    always survives). One descending sort serves both masks.
    """
    v = logits.shape[-1]
    order = jnp.argsort(-logits, axis=-1)
    sorted_l = jnp.take_along_axis(logits, order, axis=-1)
    keep = jnp.ones(logits.shape, bool)
    if top_k is not None:
        k = jnp.where(top_k > 0, top_k, v).astype(jnp.int32)[:, None]
        keep &= jnp.arange(v)[None, :] < k
    if top_p is not None:
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # token i survives iff the mass *before* it is still short of top_p;
        # a row with top_p >= 1 keeps everything *exactly* (not just up to
        # cumsum rounding) so its draw is bit-identical whether or not a
        # co-batched neighbor forced the mask to compile in
        tp = top_p.astype(jnp.float32)[:, None]
        keep &= ((cum - probs) < tp) | (tp >= 1.0)
    # back to vocabulary order: scatter through the permutation (O(V), vs
    # a second argsort) — each row of `order` is a permutation, so every
    # position is written exactly once
    rows = jnp.arange(logits.shape[0])[:, None]
    return jnp.zeros(logits.shape, bool).at[rows, order].set(keep)


def sample_tokens_per_request(logits: jax.Array, keys: jax.Array,
                              temperatures: jax.Array, *,
                              top_k: Optional[jax.Array] = None,
                              top_p: Optional[jax.Array] = None
                              ) -> jax.Array:
    """Per-request sampling: logits (B, V), keys (B, 2) from
    :func:`request_keys`, temperatures (B,) -> tokens (B,).

    Rows with temperature <= 0 take the argmax (bit-identical to the
    pre-v1 greedy path); the rest draw categorically from their own key
    over logits / temperature restricted to the row's top-k/top-p support.
    Pass ``top_k``/``top_p`` as None (static) to compile the mask out
    entirely when no request in the fleet needs it.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures.astype(jnp.float32), 1e-6)[:, None]
    scaled = logits.astype(jnp.float32) / t
    if top_k is not None or top_p is not None:
        keep = top_k_top_p_mask(scaled, top_k, top_p)
        scaled = jnp.where(keep, scaled, -jnp.inf)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperatures <= 0.0, greedy, sampled)
