"""Token sampling: greedy / temperature / top-k, jit-friendly.

``sample_token`` is the scalar-temperature form (the serial-admit engine's
per-request prefill path); ``sample_tokens`` is the vectorized per-slot form
used both inside the jitted fused decode loop and for the bucketed
scheduler's prefill finishers (every row whose prompt completed this step
samples its first token in one call): each batch row carries its own
temperature, with temperature 0 meaning greedy for that row only — slots
never share a sampler, and `jax.random.categorical` draws independently per
row from a single key.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key: jax.Array, *,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (B, V) -> tokens (B,). temperature==0 → greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperatures: jax.Array, *, top_k: int = 0) -> jax.Array:
    """Per-row sampling: logits (B, V), temperatures (B,) -> tokens (B,).

    Rows with temperature <= 0 take the argmax; the rest sample from
    logits / temperature (optionally top-k-truncated). Fully vectorized so
    it fuses into the jitted decode loop — no host branching per slot.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures.astype(jnp.float32), 1e-6)[:, None]
    scaled = logits.astype(jnp.float32) / t
    if top_k > 0:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperatures <= 0.0, greedy, sampled)
