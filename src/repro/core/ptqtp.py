"""PTQTP: progressive trit-plane approximation with adaptive ridge regression.

Implements the paper's core contribution (Sec. 3, Alg. 1/2):

    W ≈ Ŵ = diag(α¹)·T¹ + diag(α²)·T²,  Tᵏ ∈ {-1,0,1},  α ∈ R²  per group-row.

The weight matrix is reshaped group-wise (G columns per group-row, G=128 by
default, Eq. 6), then alternately optimized:

  * ridge step  — closed-form 2×2 adjugate solve for α (Eq. 1/6/7),
  * adaptive λ  — condition-number-driven regularization growth (Eq. 2-3),
  * trit step   — per-element exhaustive search over the 9 ternary pairs (Eq. 5),

inside a ``lax.while_loop`` with the paper's convergence criterion
``max_i ||α_i,(t) - α_i,(t-1)|| < ε`` and ``t <= T_max``.

Everything is vectorized over group-rows; the whole quantizer is a single
jittable function whose cost is O(T_max · n · d) — the paper's complexity claim.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PTQTPConfig",
    "ptqtp_quantize",
    "ptqtp_dequantize",
    "ptqtp_error",
    "CANDIDATES",
]

# The 9 ternary candidate pairs (c1, c2) of Eq. 5 / Alg. 2 line 14.
# (0, 0) first so that exact ties (e.g. w == 0) prefer the sparse assignment.
CANDIDATES = np.array(
    [
        [0, 0],
        [0, 1],
        [0, -1],
        [1, 0],
        [-1, 0],
        [1, 1],
        [-1, -1],
        [1, -1],
        [-1, 1],
    ],
    dtype=np.float32,
)


@dataclasses.dataclass(frozen=True)
class PTQTPConfig:
    """Hyper-parameters of the PTQTP quantizer (paper Sec. 4.1 defaults)."""

    group_size: int = 128          # G, Eq. 6
    t_max: int = 50                # max progressive iterations
    eps: float = 1e-4              # convergence tolerance on ||Δα||
    lambda_init: float = 1e-8      # λ₀  (Alg. 2 line 4)
    lambda_max: float = 1.0        # λmax (Eq. 3)
    cond_bound: float = 1e12       # κ threshold (Eq. 3); swept in Table 7
    use_search_kernel: bool = False  # route trit step through the Pallas kernel

    def __post_init__(self):
        assert self.group_size >= 2
        assert self.t_max >= 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A PTQTP-quantized weight.

    Attributes:
      t1, t2:  int8 trit-planes with values in {-1, 0, 1}, shape = w.shape.
      alpha:   f32/bf16 scaling pairs, shape (n_rows, n_groups, 2) where
               n_groups = d // G and w.shape == (n_rows, d).
      group_size: G.
      iters:   number of progressive iterations actually run (traced scalar).
    """

    t1: jax.Array
    t2: jax.Array
    alpha: jax.Array
    group_size: int
    iters: jax.Array

    @property
    def shape(self):
        return self.t1.shape

    def tree_flatten(self):
        return (self.t1, self.t2, self.alpha, self.iters), (self.group_size,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        t1, t2, alpha, iters = children
        return cls(t1, t2, alpha, aux[0], iters)


def _reshape_groups(w: jax.Array, group_size: int) -> jax.Array:
    """(n, d) -> (n * d // G, G) group-rows (Eq. 6 reshaping)."""
    n, d = w.shape
    if d % group_size != 0:
        raise ValueError(
            f"last dim {d} not divisible by group size {group_size}; "
            "pad the matrix or choose a divisor group size"
        )
    return w.reshape(n * (d // group_size), group_size)


def _ridge_solve(t1, t2, w, lam):
    """Closed-form 2x2 ridge solve per group-row (Eq. 1/6 + adjugate Eq. 7).

    Args:
      t1, t2: (R, G) float32 trit-planes.
      w:      (R, G) float32 weights.
      lam:    (R,)   float32 per-row regularization.
    Returns:
      alpha (R, 2), kappa (R,) condition estimate of the *unregularized-λ* A.
    """
    s11 = jnp.sum(t1 * t1, axis=-1)
    s12 = jnp.sum(t1 * t2, axis=-1)
    s22 = jnp.sum(t2 * t2, axis=-1)
    b1 = jnp.sum(t1 * w, axis=-1)
    b2 = jnp.sum(t2 * w, axis=-1)

    a11 = s11 + lam
    a22 = s22 + lam
    det = a11 * a22 - s12 * s12
    # κ ≈ ||A||_F ||A^{-1}||_F ; for 2x2, ||adj(A)||_F == ||A||_F, so
    # κ = ||A||_F^2 / |det A|  (Eq. 2).
    fro2 = a11 * a11 + a22 * a22 + 2.0 * s12 * s12
    kappa = fro2 / jnp.maximum(jnp.abs(det), 1e-30)

    inv_det = 1.0 / jnp.where(jnp.abs(det) < 1e-30, 1e-30, det)
    alpha1 = (a22 * b1 - s12 * b2) * inv_det
    alpha2 = (-s12 * b1 + a11 * b2) * inv_det
    return jnp.stack([alpha1, alpha2], axis=-1), kappa


def _trit_search(w, alpha, candidates):
    """Per-element exhaustive search over the 9 ternary pairs (Eq. 5).

    Args:
      w: (R, G) float32.
      alpha: (R, 2) float32.
      candidates: (9, 2) float32.
    Returns:
      t1, t2: (R, G) float32 in {-1, 0, 1}.
    """
    # vals[r, m] = alpha1[r]*c1[m] + alpha2[r]*c2[m]
    vals = alpha @ candidates.T  # (R, 9)
    err = (w[:, :, None] - vals[:, None, :]) ** 2  # (R, G, 9)
    best = jnp.argmin(err, axis=-1)  # (R, G)
    c = jnp.asarray(candidates)
    t1 = c[best, 0]
    t2 = c[best, 1]
    return t1, t2


def _trit_search_kernel(w, alpha, candidates):
    """Same as _trit_search but routed through the Pallas ptqtp_search kernel."""
    from repro.kernels.ptqtp_search import ops as search_ops

    return search_ops.ptqtp_search(w, alpha)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "t_max", "lambda_max", "cond_bound",
                     "use_search_kernel"),
)
def _quantize_grouped(
    wg: jax.Array,
    *,
    group_size: int,
    t_max: int,
    eps: float,
    lambda_init: float,
    lambda_max: float,
    cond_bound: float,
    use_search_kernel: bool,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Run Alg. 1/2 on group-rows wg (R, G). Returns (t1, t2, alpha, iters)."""
    wg = wg.astype(jnp.float32)
    R, G = wg.shape
    cand = jnp.asarray(CANDIDATES)

    # Alg. 2 line 2: sign init with 0 -> 1 replacement.
    sgn = jnp.where(wg >= 0.0, 1.0, -1.0)
    t1 = sgn
    t2 = sgn
    alpha = jnp.ones((R, 2), jnp.float32)  # line 3
    lam = jnp.full((R,), lambda_init, jnp.float32)  # line 4
    eps = jnp.float32(eps)

    search = _trit_search_kernel if use_search_kernel else _trit_search

    def body(state):
        t1, t2, alpha_prev, lam, t, _ = state
        # --- continuous step: adaptive ridge (Alg. 2 lines 6-13) ---
        _, kappa = _ridge_solve(t1, t2, wg, lam)
        lam_new = jnp.where(
            kappa >= cond_bound,
            jnp.minimum(lam * jnp.sqrt(kappa / cond_bound), lambda_max),
            lam,
        )
        alpha, _ = _ridge_solve(t1, t2, wg, lam_new)
        # --- discrete step: 9-candidate exhaustive search (lines 14-21) ---
        t1n, t2n = search(wg, alpha, cand)
        # --- convergence (lines 22-25) ---
        delta = jnp.max(jnp.sqrt(jnp.sum((alpha - alpha_prev) ** 2, axis=-1)))
        converged = delta < eps
        return t1n, t2n, alpha, lam_new, t + 1, converged

    def cond(state):
        *_, t, converged = state
        return jnp.logical_and(t < t_max, jnp.logical_not(converged))

    init = (t1, t2, alpha, lam, jnp.int32(0), jnp.bool_(False))
    t1, t2, alpha, lam, iters, _ = jax.lax.while_loop(cond, body, init)
    # Final α refit against the final trit-planes (keeps ridge/trit consistent).
    alpha, _ = _ridge_solve(t1, t2, wg, lam)
    return t1.astype(jnp.int8), t2.astype(jnp.int8), alpha, iters


def ptqtp_quantize(w: jax.Array, cfg: Optional[PTQTPConfig] = None) -> QuantizedTensor:
    """Quantize a 2-D weight matrix to two trit-planes + group scales.

    Args:
      w:   (n, d) weight matrix (any float dtype).
      cfg: PTQTPConfig (paper defaults if None).

    Returns:
      QuantizedTensor with t1/t2 of shape (n, d) and alpha of shape
      (n, d // G, 2).
    """
    cfg = cfg or PTQTPConfig()
    if w.ndim != 2:
        raise ValueError(f"ptqtp_quantize expects a 2-D matrix, got {w.shape}")
    n, d = w.shape
    wg = _reshape_groups(w, cfg.group_size)
    t1, t2, alpha, iters = _quantize_grouped(
        wg,
        group_size=cfg.group_size,
        t_max=cfg.t_max,
        eps=cfg.eps,
        lambda_init=cfg.lambda_init,
        lambda_max=cfg.lambda_max,
        cond_bound=cfg.cond_bound,
        use_search_kernel=cfg.use_search_kernel,
    )
    n_groups = d // cfg.group_size
    return QuantizedTensor(
        t1=t1.reshape(n, d),
        t2=t2.reshape(n, d),
        alpha=alpha.reshape(n, n_groups, 2),
        group_size=cfg.group_size,
        iters=iters,
    )


def ptqtp_dequantize(q: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """Reconstruct Ŵ = diag(α¹)T¹ + diag(α²)T² with group-wise α."""
    n, d = q.t1.shape
    g = q.group_size
    t1 = q.t1.reshape(n, d // g, g).astype(jnp.float32)
    t2 = q.t2.reshape(n, d // g, g).astype(jnp.float32)
    a = q.alpha.astype(jnp.float32)
    w_hat = t1 * a[..., 0:1] + t2 * a[..., 1:2]
    return w_hat.reshape(n, d).astype(dtype)


def ptqtp_error(w: jax.Array, q: QuantizedTensor) -> jax.Array:
    """Relative Frobenius reconstruction error ||W - Ŵ||_F / ||W||_F."""
    w = w.astype(jnp.float32)
    w_hat = ptqtp_dequantize(q)
    return jnp.linalg.norm(w - w_hat) / jnp.maximum(jnp.linalg.norm(w), 1e-30)


def quantize_with_history(w: jax.Array, cfg: Optional[PTQTPConfig] = None):
    """Unrolled variant that records per-iteration Frobenius error.

    Used by tests (monotonicity property) and the Fig. 3 ablation benchmark.
    Returns (QuantizedTensor, errors[t_max+1]) — errors[t] is the error after
    iteration t (errors[0] = after sign init with α=[1,1]).
    """
    cfg = cfg or PTQTPConfig()
    n, d = w.shape
    wg = _reshape_groups(w.astype(jnp.float32), cfg.group_size)
    cand = jnp.asarray(CANDIDATES)

    sgn = jnp.where(wg >= 0.0, 1.0, -1.0)
    t1, t2 = sgn, sgn
    alpha = jnp.ones((wg.shape[0], 2), jnp.float32)
    lam = jnp.full((wg.shape[0],), cfg.lambda_init, jnp.float32)

    def err(t1, t2, alpha):
        w_hat = t1 * alpha[:, 0:1] + t2 * alpha[:, 1:2]
        return jnp.linalg.norm(wg - w_hat)

    errors = [err(t1, t2, alpha)]
    iters_run = 0
    for _ in range(cfg.t_max):
        _, kappa = _ridge_solve(t1, t2, wg, lam)
        lam = jnp.where(
            kappa >= cfg.cond_bound,
            jnp.minimum(lam * jnp.sqrt(kappa / cfg.cond_bound), cfg.lambda_max),
            lam,
        )
        alpha_new, _ = _ridge_solve(t1, t2, wg, lam)
        t1, t2 = _trit_search(wg, alpha_new, cand)
        errors.append(err(t1, t2, alpha_new))
        delta = jnp.max(jnp.sqrt(jnp.sum((alpha_new - alpha) ** 2, axis=-1)))
        alpha = alpha_new
        iters_run += 1
        if bool(delta < cfg.eps):
            break
    q = QuantizedTensor(
        t1=t1.astype(jnp.int8).reshape(n, d),
        t2=t2.astype(jnp.int8).reshape(n, d),
        alpha=alpha.reshape(n, d // cfg.group_size, 2),
        group_size=cfg.group_size,
        iters=jnp.int32(iters_run),
    )
    return q, jnp.stack(errors)
