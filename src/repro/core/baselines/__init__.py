"""Baseline PTQ methods the paper compares against (Table 1/2, Fig. 1).

All baselines expose  quantize(w, **kw) -> (w_hat, meta)  returning the
dequantized approximation (for quality comparison) plus bookkeeping.
"""

from repro.core.baselines.rtn import rtn_quantize
from repro.core.baselines.gptq import gptq_quantize
from repro.core.baselines.awq import awq_quantize
from repro.core.baselines.billm import billm_quantize

__all__ = ["rtn_quantize", "gptq_quantize", "awq_quantize", "billm_quantize"]
