"""AWQ baseline (Lin et al., 2024): activation-aware per-channel scaling.

Searches a per-input-channel scale s = act_scaleʳ (grid over r ∈ [0, 1]),
quantizes W·diag(s) with group-wise RTN, folds 1/s back, and keeps the r that
minimizes reconstruction error on the calibration batch:  ‖(Ŵ − W)·Xᵀ‖².
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.baselines.rtn import rtn_quantize


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "n_grid"))
def awq_quantize(
    w: jax.Array,
    x: jax.Array,
    bits: int = 3,
    group_size: int = 128,
    n_grid: int = 20,
):
    """Quantize (n, d) weights with activation stats from x (..., d).

    Returns (w_hat, meta) where meta carries the chosen ratio and scales.
    """
    n, d = w.shape
    w = w.astype(jnp.float32)
    xf = x.reshape(-1, d).astype(jnp.float32)
    act_scale = jnp.maximum(jnp.mean(jnp.abs(xf), axis=0), 1e-8)  # (d,)

    def attempt(ratio):
        s = jnp.power(act_scale, ratio)
        s = s / jnp.sqrt(jnp.maximum(jnp.max(s) * jnp.min(s), 1e-20))
        s = jnp.maximum(s, 1e-4)
        w_hat_s, _ = rtn_quantize(w * s[None, :], bits=bits, group_size=group_size)
        w_hat = w_hat_s / s[None, :]
        err = jnp.sum(((w_hat - w) @ xf.T) ** 2)
        return err, w_hat

    ratios = jnp.linspace(0.0, 1.0, n_grid)
    errs, w_hats = jax.vmap(attempt)(ratios)
    best = jnp.argmin(errs)
    return w_hats[best], {"ratio": ratios[best], "err": errs[best]}
