"""GPTQ baseline (Frantar et al., 2022): Hessian-guided error-compensated RTN.

Layer-wise optimal rounding with second-order error feedback:
  H = Xᵀ X + damp·I  from calibration activations,
  for each column j (in order):
      q_j   = quant(w_j)                       (group-wise symmetric RTN)
      e     = (w_j − q_j) / Hinv[j, j]
      W[:, j+1:] −= e ⊗ Hinv[j, j+1:]          (compensate remaining columns)
with Hinv the upper-Cholesky factor of H⁻¹, exactly as the GPTQ paper's fast
algorithm. Scales are per-(row, group) symmetric, computed from the original
weights (the standard simplification used in open reimplementations).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _hessian_inv_chol(x: jax.Array, d: int, damp_frac: float = 0.01):
    """Upper Cholesky of H⁻¹ for H = XᵀX + damp·I. x: (samples, d) or None."""
    if x is None:
        h = jnp.eye(d, dtype=jnp.float32)
    else:
        xf = x.reshape(-1, d).astype(jnp.float32)
        h = xf.T @ xf
    damp = damp_frac * jnp.mean(jnp.diag(h)) + 1e-6
    h = h + damp * jnp.eye(d, dtype=jnp.float32)
    hinv = jnp.linalg.inv(h)
    # upper triangular factor: H⁻¹ = Uᵀ U with U upper ⇒ U = chol(H⁻¹, upper)
    u = jnp.linalg.cholesky(hinv, upper=True)
    return u


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "damp_frac"))
def gptq_quantize(
    w: jax.Array,
    x: jax.Array | None = None,
    bits: int = 3,
    group_size: int = 128,
    damp_frac: float = 0.01,
):
    """Quantize (n, d) weights against calibration activations x (..., d).

    Returns (w_hat, meta).
    """
    n, d = w.shape
    g = group_size if group_size > 0 else d
    assert d % g == 0
    w = w.astype(jnp.float32)

    # per-(row, group) symmetric scales from the original weights
    qmax = 2 ** (bits - 1) - 1
    maxabs = jnp.max(jnp.abs(w.reshape(n, d // g, g)), axis=-1)  # (n, d//g)
    scale_g = jnp.maximum(maxabs / qmax, 1e-10)
    scale_cols = jnp.repeat(scale_g, g, axis=1)  # (n, d)

    hinv = _hessian_inv_chol(x, d, damp_frac)  # (d, d) upper
    col_idx = jnp.arange(d)

    def body(j, carry):
        wc, w_hat = carry
        wj = jax.lax.dynamic_slice(wc, (0, j), (n, 1))[:, 0]
        sj = jax.lax.dynamic_slice(scale_cols, (0, j), (n, 1))[:, 0]
        qj = jnp.clip(jnp.round(wj / sj), -qmax - 1, qmax) * sj
        hjj = jnp.maximum(hinv[j, j], 1e-10)
        err = (wj - qj) / hjj
        row = hinv[j]  # (d,)
        mask = (col_idx > j).astype(jnp.float32)
        wc = wc - err[:, None] * (row * mask)[None, :]
        w_hat = jax.lax.dynamic_update_slice(w_hat, qj[:, None], (0, j))
        return wc, w_hat

    w_hat0 = jnp.zeros_like(w)
    _, w_hat = jax.lax.fori_loop(0, d, body, (w, w_hat0))
    return w_hat, {"scale": scale_g}
