"""Round-to-nearest (RTN) uniform quantization baseline at k bits.

Group-wise symmetric/asymmetric min-max quantization — the vanilla PTQ
baseline underlying AWQ/GPTQ comparisons.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "symmetric"))
def rtn_quantize(w: jax.Array, bits: int = 3, group_size: int = 128,
                 symmetric: bool = False):
    """Quantize (n, d) weights to `bits` with per-(row, group) scales.

    Returns (w_hat, meta) with meta = {"q": int8 codes, "scale", "zero"}.
    """
    n, d = w.shape
    g = group_size if group_size > 0 else d
    assert d % g == 0
    wg = w.astype(jnp.float32).reshape(n, d // g, g)
    if symmetric:
        maxabs = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
        qmax = 2 ** (bits - 1) - 1
        scale = jnp.maximum(maxabs / qmax, 1e-10)
        q = jnp.clip(jnp.round(wg / scale), -qmax - 1, qmax)
        w_hat = q * scale
        zero = jnp.zeros_like(scale)
    else:
        lo = jnp.min(wg, axis=-1, keepdims=True)
        hi = jnp.max(wg, axis=-1, keepdims=True)
        qmax = 2**bits - 1
        scale = jnp.maximum((hi - lo) / qmax, 1e-10)
        zero = jnp.round(-lo / scale)
        q = jnp.clip(jnp.round(wg / scale) + zero, 0, qmax)
        w_hat = (q - zero) * scale
    return w_hat.reshape(n, d), {"q": q.astype(jnp.int32), "scale": scale, "zero": zero}
