"""BiLLM-style binary PTQ baseline (Huang et al., 2024), simplified.

Unstructured binary quantization with salient-weight preservation:
  * the top `salient_frac` input columns (by calibration activation energy,
    falling back to column norm) get *residual* binarization —
    two sign planes with optimal per-row α (second-order),
  * the remaining columns are split by magnitude into two groups
    ("bell-shaped split"), each binarized with its own per-row α.

Average bits ≈ 1 + salient_frac (+ bitmap overhead), matching the ~1.06-1.1
effective bit-widths reported by BiLLM/ARB-LLM. This is the structured-vs-
unstructured comparison point for PTQTP (Table 1/2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _residual_binarize(w):
    """Two-plane residual sign binarization with optimal per-row scales."""
    b1 = jnp.sign(w)
    b1 = jnp.where(b1 == 0, 1.0, b1)
    a1 = jnp.mean(jnp.abs(w), axis=-1, keepdims=True)
    r = w - a1 * b1
    b2 = jnp.sign(r)
    b2 = jnp.where(b2 == 0, 1.0, b2)
    a2 = jnp.mean(jnp.abs(r), axis=-1, keepdims=True)
    return a1 * b1 + a2 * b2


def _split_binarize(w):
    """Magnitude-split single-plane binarization (per row, two α groups)."""
    mag = jnp.abs(w)
    thresh = jnp.median(mag, axis=-1, keepdims=True)
    hi = mag > thresh
    sgn = jnp.where(jnp.sign(w) == 0, 1.0, jnp.sign(w))

    def group_alpha(mask):
        cnt = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
        return jnp.sum(mag * mask, axis=-1, keepdims=True) / cnt

    a_hi = group_alpha(hi.astype(jnp.float32))
    a_lo = group_alpha((~hi).astype(jnp.float32))
    return jnp.where(hi, a_hi * sgn, a_lo * sgn)


@functools.partial(jax.jit, static_argnames=("salient_frac",))
def billm_quantize(w: jax.Array, x: jax.Array | None = None,
                   salient_frac: float = 0.05):
    """Quantize (n, d) weights. Returns (w_hat, meta)."""
    n, d = w.shape
    w = w.astype(jnp.float32)
    if x is not None:
        xf = x.reshape(-1, d).astype(jnp.float32)
        col_energy = jnp.sum(xf * xf, axis=0) * jnp.sum(w * w, axis=0)
    else:
        col_energy = jnp.sum(w * w, axis=0)
    k = max(1, int(d * salient_frac))
    thresh = jnp.sort(col_energy)[-k]
    salient = col_energy >= thresh  # (d,)

    w_sal = _residual_binarize(w)
    w_rest = _split_binarize(w)
    w_hat = jnp.where(salient[None, :], w_sal, w_rest)
    eff_bits = 1.0 + salient_frac + 1.0 / 128.0
    return w_hat, {"salient": salient, "effective_bits": eff_bits}
