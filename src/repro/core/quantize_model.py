"""Model-tree quantization: walk a params pytree, quantize every linear kernel.

The paper's deployment recipe ("all linear layers were quantized", Sec. 4.1):
every 2-D dense kernel — and every scan-stacked (L, in, out) kernel — becomes a
``QuantizedKernel`` (two packed trit-planes + group scales). Embedding gathers,
norms, biases, routers, and vector-sized recurrence parameters stay FP
(DESIGN.md §4). Model-agnostic: the walk needs no architecture knowledge.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptqtp
from repro.core.packing import pack_trits, ptqtp_weight_bytes

EXCLUDE_SUBSTRINGS = ("embed", "router", "norm", "decay", "lora", "conv", "rglru")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedKernel:
    """PTQTP replacement for a dense kernel of logical shape (d_in, d_out).

    Stored transposed (output-major) to match the quantizer/matmul layout:
      t1p, t2p : (d_out, d_in // 4) uint8 packed trit-planes
      alpha    : (d_out, d_in // G, 2) fp
    Stacked kernels carry an extra leading layer dim on every buffer.
    """

    t1p: jax.Array
    t2p: jax.Array
    alpha: jax.Array
    d_in: int
    d_out: int
    group_size: int

    def tree_flatten(self):
        return (self.t1p, self.t2p, self.alpha), (self.d_in, self.d_out,
                                                  self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def logical_shape(self):
        return (self.d_in, self.d_out)

    def nbytes(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in (self.t1p, self.t2p, self.alpha))


def quantize_kernel(kernel: jax.Array, cfg: ptqtp.PTQTPConfig) -> QuantizedKernel:
    """Quantize a (d_in, d_out) kernel; any leading dims (scan-stacked layers,
    MoE experts — e.g. (L, E, d_in, d_out)) are vmapped over."""
    lead = kernel.shape[:-2]
    d_in, d_out = kernel.shape[-2:]
    if lead:
        flat = kernel.reshape((-1,) + kernel.shape[-2:])
        t1p, t2p, alpha = jax.vmap(lambda k: _quantize_2d(k, cfg))(flat)
        t1p = t1p.reshape(lead + t1p.shape[1:])
        t2p = t2p.reshape(lead + t2p.shape[1:])
        alpha = alpha.reshape(lead + alpha.shape[1:])
    else:
        t1p, t2p, alpha = _quantize_2d(kernel, cfg)
    return QuantizedKernel(t1p, t2p, alpha, int(d_in), int(d_out), cfg.group_size)


def _quantize_2d(kernel: jax.Array, cfg: ptqtp.PTQTPConfig):
    # Quantizer layout: rows = outputs, groups along the contraction dim.
    q = ptqtp.ptqtp_quantize(kernel.T, cfg)
    return pack_trits(q.t1), pack_trits(q.t2), q.alpha


def dequantize_kernel(qk: QuantizedKernel, dtype=jnp.float32) -> jax.Array:
    """Back to a dense (d_in, d_out) kernel (testing / fallback path)."""
    from repro.core.packing import unpack_trits

    def deq(t1p, t2p, alpha):
        n, db = t1p.shape
        d = db * 4
        g = qk.group_size
        t1 = unpack_trits(t1p).reshape(n, d // g, g).astype(jnp.float32)
        t2 = unpack_trits(t2p).reshape(n, d // g, g).astype(jnp.float32)
        a = alpha.astype(jnp.float32)
        w = (t1 * a[..., 0:1] + t2 * a[..., 1:2]).reshape(n, d)
        return w.T  # (d_in, d_out)

    lead = qk.t1p.shape[:-2]
    if lead:
        flat = jax.vmap(deq)(
            qk.t1p.reshape((-1,) + qk.t1p.shape[-2:]),
            qk.t2p.reshape((-1,) + qk.t2p.shape[-2:]),
            qk.alpha.reshape((-1,) + qk.alpha.shape[-3:]))
        return flat.reshape(lead + flat.shape[1:]).astype(dtype)
    return deq(qk.t1p, qk.t2p, qk.alpha).astype(dtype)


def default_predicate(path: str, leaf: Any, group_size: int) -> bool:
    if not isinstance(leaf, jax.Array) and not isinstance(leaf, np.ndarray):
        return False
    if leaf.ndim < 2 or leaf.ndim > 4:
        return False
    lowered = path.lower()
    if any(s in lowered for s in EXCLUDE_SUBSTRINGS):
        return False
    if not lowered.endswith("kernel"):
        return False
    d_in = leaf.shape[-2]
    return d_in % group_size == 0 and d_in % 4 == 0


def quantize_tree(
    params: Dict[str, Any],
    cfg: Optional[ptqtp.PTQTPConfig] = None,
    predicate: Optional[Callable[[str, Any, int], bool]] = None,
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Quantize every matching kernel in a nested-dict params tree.

    Returns (new_params, report) where report maps path -> dict with
    original/compressed byte counts; report["__total__"] aggregates.
    """
    cfg = cfg or ptqtp.PTQTPConfig()
    predicate = predicate or default_predicate
    report: Dict[str, Any] = {}
    tot_before = tot_after = tot_eq13 = 0

    def walk(node, path):
        nonlocal tot_before, tot_after, tot_eq13
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{path}/{i}") for i, v in enumerate(node))
        if predicate(path, node, cfg.group_size):
            qk = quantize_kernel(node, cfg)
            before = int(np.prod(node.shape)) * 2  # vs fp16 storage
            # All leading dims (scan stack, MoE experts: (L, E, in, out))
            # multiply the per-matrix bytes; the quantizer stores the matrix
            # transposed, so groups run along d_in = shape[-2]. after_bytes
            # is the exact packed footprint (== QuantizedKernel.nbytes());
            # after_bytes_eq13 is the paper's Eq. 13 with fp16 scales.
            lead = int(np.prod(node.shape[:-2], dtype=np.int64))
            layout = (node.shape[-1], node.shape[-2])  # (d_out, d_in)
            after = lead * ptqtp_weight_bytes(
                layout, cfg.group_size, scale_bytes=qk.alpha.dtype.itemsize)
            after_eq13 = lead * ptqtp_weight_bytes(layout, cfg.group_size)
            report[path] = {"before_bytes": before, "after_bytes": after,
                            "after_bytes_eq13": after_eq13,
                            "shape": tuple(node.shape)}
            tot_before += before
            tot_after += after
            tot_eq13 += after_eq13
            return qk
        return node

    out = walk(params, "")
    report["__total__"] = {
        "before_bytes": tot_before,
        "after_bytes": tot_after,
        "after_bytes_eq13": tot_eq13,
        "compression": (tot_before / tot_after) if tot_after else float("nan"),
        "compression_eq13":
            (tot_before / tot_eq13) if tot_eq13 else float("nan"),
        "n_quantized": len(report),
    }
    return out, report
