"""Trit packing: 4 ternary values per byte (2-bit fields).

Storage format (paper App. A.3: "each trit-plane ... stored as a 2bit datatype"):
  field encoding  0b00 -> 0,  0b01 -> +1,  0b10 -> -1   (0b11 unused)
  byte layout     trit j occupies bits [2*(j%4), 2*(j%4)+1] of byte j//4.

This gives 0.25 byte / trit / plane -> 0.5 byte/weight for two planes, plus
2 fp16 scales per group of 128 weights (0.03125 byte/weight) = 0.53125 byte per
weight vs 2.0 for fp16 (3.76x; the paper's ~4x trit-plane compression claim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pack_trits", "unpack_trits", "packed_nbytes", "ptqtp_weight_bytes"]


def _encode(t: jax.Array) -> jax.Array:
    """{-1,0,1} int -> 2-bit field value {2,0,1} (uint8)."""
    t = t.astype(jnp.int8)
    return jnp.where(t == -1, jnp.uint8(2), t.astype(jnp.uint8))


def pack_trits(t: jax.Array) -> jax.Array:
    """Pack an int8 trit array (..., d) with d % 4 == 0 into (..., d//4) uint8."""
    if t.shape[-1] % 4 != 0:
        raise ValueError(f"last dim {t.shape[-1]} must be divisible by 4")
    enc = _encode(t)
    e = enc.reshape(*t.shape[:-1], t.shape[-1] // 4, 4)
    b = (
        e[..., 0]
        | (e[..., 1] << 2)
        | (e[..., 2] << 4)
        | (e[..., 3] << 6)
    )
    return b.astype(jnp.uint8)


def unpack_trits(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """Unpack (..., b) uint8 -> (..., 4*b) trits in {-1,0,1} of `dtype`."""
    p = packed
    fields = jnp.stack(
        [(p >> (2 * i)) & jnp.uint8(3) for i in range(4)], axis=-1
    )  # (..., b, 4)
    t = (fields == 1).astype(jnp.int8) - (fields == 2).astype(jnp.int8)
    return t.reshape(*packed.shape[:-1], packed.shape[-1] * 4).astype(dtype)


def packed_nbytes(shape) -> int:
    """Bytes used by one packed trit-plane of logical `shape`."""
    n = int(np.prod(shape))
    assert n % 4 == 0
    return n // 4


def ptqtp_weight_bytes(shape, group_size: int = 128, scale_bytes: int = 2) -> int:
    """Total PTQTP storage for a weight of `shape` (2 planes + 2 scales/group).

    Mirrors Eq. 13 of the paper:
      M = 2 * n * d * 2bit + ceil(d/G) * 2n * fp16.
    """
    n = int(np.prod(shape[:-1]))
    d = int(shape[-1])
    n_groups = -(-d // group_size)
    return 2 * packed_nbytes((n, d)) + n_groups * n * 2 * scale_bytes
