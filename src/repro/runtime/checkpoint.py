"""Sharded, atomic, elastic checkpointing.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json          tree structure, shapes, dtypes, shard map
        host0000.npz           this host's param/opt shards (flat path keys)
    ckpt_dir/step_000123.tmp_* staging dir, atomically renamed on commit
    ckpt_dir/LATEST            text file holding the last committed step

Fault-tolerance posture (DESIGN.md §5):
  * **atomic** — writes stage into a tmp dir; `rename()` commits. A crash
    mid-write never corrupts the previous checkpoint; LATEST is updated last.
  * **per-host shards** — each host saves only the addressable shards of its
    local devices (here: the single process saves everything, but addressing
    is by global flat path so the format is multi-host ready).
  * **elastic restore** — restore only needs the manifest + shard files; the
    target mesh/sharding may differ from the save-time mesh (`load_checkpoint`
    returns host arrays; the caller re-`device_put`s with its own shardings).
  * **retention** — keep the newest `keep` checkpoints, delete older ones
    after a successful commit (never before).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.artifacts.format import (QK_KEY_PREFIX, decode_quantized_kernel,
                                    encode_quantized_kernel)
from repro.core.quantize_model import QuantizedKernel

_SEP = "//"


def _flatten(tree: Any) -> Dict[str, Any]:
    """Nested dict tree -> {path: leaf}; QuantizedKernel explodes to fields
    via the artifact leaf codec (one codec, two formats — they can't drift)."""
    out: Dict[str, Any] = {}

    def walk(node, path):
        if isinstance(node, QuantizedKernel):
            for key, arr in encode_quantized_kernel(node).items():
                out[f"{path}{_SEP}{key}"] = arr
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}{_SEP}{k}" if path else k)
            return
        out[path] = node

    walk(tree, "")
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    # regroup QuantizedKernel fields first
    qk_groups: Dict[str, Dict[str, Any]] = {}
    plain: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split(_SEP)
        if parts[-1].startswith(QK_KEY_PREFIX):
            qk_groups.setdefault(_SEP.join(parts[:-1]), {})[parts[-1]] = leaf
        else:
            plain[path] = leaf
    for base, fields in qk_groups.items():
        plain[base] = decode_quantized_kernel(fields)

    root: Dict[str, Any] = {}
    for path, leaf in plain.items():
        parts = path.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any,
                    *, host_id: int = 0, extra: Optional[Dict] = None) -> Path:
    """Atomically write checkpoint `step`. Returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    stage = Path(tempfile.mkdtemp(prefix=final.name + ".tmp_", dir=ckpt_dir))
    try:
        flat = _flatten(tree)
        arrays = {}
        manifest = {"step": step, "time": time.time(), "extra": extra or {},
                    "leaves": {}}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[path] = arr
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "host": host_id,
            }
        np.savez(stage / f"host{host_id:04d}.npz", **arrays)
        with open(stage / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():  # overwrite-same-step: replace
            shutil.rmtree(final)
        os.rename(stage, final)
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise
    # LATEST last: readers never see a pointer to an uncommitted dir
    latest_tmp = ckpt_dir / ".LATEST.tmp"
    latest_tmp.write_text(str(step))
    os.rename(latest_tmp, ckpt_dir / "LATEST")
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def load_checkpoint(ckpt_dir: str | Path, step: Optional[int] = None,
                    ) -> Tuple[int, Any, Dict]:
    """Load checkpoint (host arrays). Caller re-shards onto its own mesh —
    this is what makes restore *elastic* to mesh-shape changes."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no LATEST in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    flat: Dict[str, Any] = {}
    for shard in sorted(d.glob("host*.npz")):
        with np.load(shard) as z:
            for k in z.files:
                flat[k] = z[k]
    tree = _unflatten(flat)
    return step, tree, manifest.get("extra", {})


def restore_sharded(tree_host: Any, shardings: Any = None) -> Any:
    """device_put each host array with the target sharding (elastic restore).
    shardings=None → default placement (single-device / tests)."""
    if shardings is None:
        return jax.tree.map(jax.device_put, tree_host)

    def put(leaf, sh):
        return jax.device_put(leaf) if sh is None else jax.device_put(leaf, sh)

    return jax.tree.map(put, tree_host, shardings)


@dataclasses.dataclass
class CheckpointManager:
    """Periodic + on-demand checkpointing with retention."""

    ckpt_dir: str
    interval_steps: int = 100
    keep: int = 3
    host_id: int = 0

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval_steps == 0

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        path = save_checkpoint(self.ckpt_dir, step, tree,
                               host_id=self.host_id, extra=extra)
        self._gc()
        return path

    def restore_latest(self):
        return load_checkpoint(self.ckpt_dir)

    def _gc(self):
        root = Path(self.ckpt_dir)
        steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                       if p.is_dir() and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(root / f"step_{s:08d}", ignore_errors=True)
