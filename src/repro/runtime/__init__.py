from repro.runtime.checkpoint import (CheckpointManager, load_checkpoint,
                                      save_checkpoint)
from repro.runtime.monitor import HeartbeatMonitor, StragglerDetector
from repro.runtime.preempt import PreemptionGuard

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "HeartbeatMonitor", "StragglerDetector", "PreemptionGuard"]
