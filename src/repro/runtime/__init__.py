from repro.runtime import clock
from repro.runtime.checkpoint import (CheckpointManager, load_checkpoint,
                                      save_checkpoint)
from repro.runtime.clock import MONOTONIC, WALL, Clock
from repro.runtime.monitor import (HEARTBEAT_SCHEMA, HeartbeatMonitor,
                                   StragglerDetector)
from repro.runtime.preempt import PreemptionGuard

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "HeartbeatMonitor", "StragglerDetector", "PreemptionGuard",
           "Clock", "MONOTONIC", "WALL", "clock", "HEARTBEAT_SCHEMA"]
