"""The single injectable time source for the serving stack.

Every timestamp in ``repro.serving`` and ``repro.models`` flows through a
:class:`Clock` instance (or this module's :func:`now` / :func:`wall_now`
helpers), never through a raw ``time.time()`` / ``time.perf_counter()``
call — the invariant that lets ``repro.serving.faults.VirtualClock`` swap
deterministic time under an entire engine (deadlines, lifecycle
timestamps, trace spans, histogram observations) without a single sleep,
and that keeps wall-clock reads out of (and fully substitutable around)
the jitted loops. The invariant is enforced *statically*: a tier-1 guard
test greps those trees for raw calls (see tests/test_observability.py).

Two concrete clocks:

* :class:`MonotonicClock` (module singleton :data:`MONOTONIC`) — wraps
  ``time.perf_counter``; the default for latency measurement (TTFT,
  queue wait, step phases). Its origin is arbitrary: only differences
  are meaningful.
* :class:`WallClock` (module singleton :data:`WALL`) — wraps
  ``time.time``; for timestamps that must be comparable *across hosts*
  (heartbeat files, artifact manifests).

A clock is just a zero-arg callable returning seconds as ``float``, so
``repro.serving.faults.VirtualClock`` (advance-on-demand) and any test
stub satisfy the interface without inheriting from :class:`Clock`.
"""

from __future__ import annotations

import time

__all__ = ["Clock", "MonotonicClock", "WallClock", "MONOTONIC", "WALL",
           "now", "wall_now"]


class Clock:
    """Zero-arg callable returning seconds (float). Subclass or duck-type."""

    def __call__(self) -> float:  # pragma: no cover - abstract
        raise NotImplementedError


class MonotonicClock(Clock):
    """``time.perf_counter`` — monotone, arbitrary origin, high resolution."""

    def __call__(self) -> float:
        return time.perf_counter()


class WallClock(Clock):
    """``time.time`` — epoch seconds, comparable across hosts (NTP caveats
    apply; see ``runtime.monitor``'s clock-skew handling)."""

    def __call__(self) -> float:
        return time.time()


MONOTONIC = MonotonicClock()
WALL = WallClock()


def now() -> float:
    """Monotonic seconds (the default latency clock)."""
    return MONOTONIC()


def wall_now() -> float:
    """Wall-clock epoch seconds (for cross-host timestamps)."""
    return WALL()
