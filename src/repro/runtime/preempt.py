"""Preemption handling: SIGTERM → checkpoint-at-next-step-boundary.

Cloud TPU/TRN fleets deliver SIGTERM with a grace window before eviction.
The guard flips an event; the train loop checks it once per step and performs
a final checkpoint + clean exit, so a preempted worker loses at most one step.
"""

from __future__ import annotations

import signal
import threading
from typing import Optional


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._event = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.getsignal(s)
            try:
                signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests): poll-only mode
                pass
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            try:
                signal.signal(s, h)
            except ValueError:
                pass
        return False

    def _handler(self, signum, frame):
        self._event.set()

    def request(self):
        """Programmatic preemption request (used by tests)."""
        self._event.set()

    @property
    def preempted(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)
