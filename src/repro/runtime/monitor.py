"""Heartbeats + straggler detection for multi-host training, and the
serving-side health snapshot built on the same idiom.

Each host writes a heartbeat file (step, wall time, step duration) every step;
the rank-0 monitor reads all heartbeats and flags:

  * **dead hosts**  — no heartbeat within `dead_after_s`,
  * **stragglers**  — per-step time > `straggler_factor` × fleet median,
  * **clock-skewed hosts** — heartbeat timestamp in the *future* by more than
    `skew_tolerance_s`: a skewed clock would otherwise make a host look
    freshly alive forever, hiding a real death behind a bad NTP sync.

On a real fleet the orchestrator restarts dead hosts from the latest
checkpoint (runtime/checkpoint.py is elastic, so a *smaller* healthy mesh can
also resume — straggler *mitigation by exclusion*). Here the detector's
decision logic is exercised directly by unit tests.

:class:`HealthSnapshot` is the per-request analogue for the serving engine:
one frozen record of queue depth, slot occupancy, and the fault-containment
counters (sheds, timeouts, quarantines), produced by
``ServingEngine.health()`` each time it is asked and writable as a heartbeat
(``snapshot.beat(monitor)``) so a serving host shows up in the same fleet
assessment as a training host.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import clock as rtclock

#: Heartbeat payload schema version. History:
#:   1 (implicit) — {host, step, t, step_time_s, **metrics}; pre-PR-8
#:     payloads carry no "schema" key and are read as v1.
#:   2 — adds "schema" and (for serving hosts) the observability metrics
#:     digest. Readers must tolerate missing keys beyond {host, t}: the
#:     fleet never upgrades atomically, so one detector version always
#:     overlaps older writers.
#:   3 — supervised serving hosts add "engine_generation" and
#:     "engine_restarts" (via the digest) so the fleet monitor can spot
#:     crash-looping hosts; readers default both to 0 (a host that never
#:     reports them has simply never restarted its engine).
HEARTBEAT_SCHEMA = 3


@dataclasses.dataclass
class HeartbeatMonitor:
    """Per-host heartbeat writer."""

    run_dir: str
    host_id: int = 0

    def __post_init__(self):
        self._dir: Optional[Path] = None  # created once, on first beat

    def beat(self, step: int, step_time_s: float, **metrics):
        if self._dir is None:
            d = Path(self.run_dir) / "heartbeats"
            d.mkdir(parents=True, exist_ok=True)
            self._dir = d
        tmp = self._dir / f".host{self.host_id:04d}.tmp"
        payload = {"schema": HEARTBEAT_SCHEMA, "host": self.host_id,
                   "step": step, "t": rtclock.wall_now(),
                   "step_time_s": step_time_s, **metrics}
        tmp.write_text(json.dumps(payload))
        tmp.rename(self._dir / f"host{self.host_id:04d}.json")


@dataclasses.dataclass
class StragglerDetector:
    """Rank-0 fleet health assessment from heartbeat files."""

    run_dir: str
    dead_after_s: float = 120.0
    straggler_factor: float = 2.0
    skew_tolerance_s: float = 5.0

    def read(self) -> List[Dict]:
        """Parse every heartbeat file, tolerating *any* schema version: a
        payload needs only ``host`` and ``t`` to be assessable (liveness
        and skew are timestamp properties); everything else is normalized
        — missing ``schema`` reads as v1, missing ``step_time_s`` as None
        (the host is alive but contributes nothing to the straggler
        median). A fleet mid-upgrade therefore never KeyErrors the
        detector."""
        d = Path(self.run_dir) / "heartbeats"
        if not d.exists():
            return []
        out = []
        for p in sorted(d.glob("host*.json")):
            try:
                b = json.loads(p.read_text())
            except (json.JSONDecodeError, OSError):
                continue  # torn read: skip this cycle
            if not isinstance(b, dict) or "host" not in b or "t" not in b:
                continue  # unassessable payload: skip, don't crash
            b.setdefault("schema", 1)
            b.setdefault("step", 0)
            b.setdefault("step_time_s", None)
            b.setdefault("engine_generation", 0)
            b.setdefault("engine_restarts", 0)
            out.append(b)
        return out

    def assess(self, now: Optional[float] = None) -> Dict:
        now = rtclock.wall_now() if now is None else now
        beats = self.read()
        if not beats:
            return {"healthy": [], "dead": [], "stragglers": [],
                    "skewed": [], "median_step_s": None}
        # a timestamp from the future is a broken clock, not a fresh beat:
        # the host's liveness cannot be assessed, so it is flagged instead
        # of silently counting as alive until its skew drains
        skewed = [b["host"] for b in beats
                  if b["t"] - now > self.skew_tolerance_s]
        dead = [b["host"] for b in beats
                if b["host"] not in skewed and now - b["t"] > self.dead_after_s]
        alive = [b for b in beats
                 if b["host"] not in dead and b["host"] not in skewed]
        times = [b["step_time_s"] for b in alive
                 if b["step_time_s"] is not None]
        med = float(np.median(times)) if times else None
        stragglers = [b["host"] for b in alive
                      if med and b["step_time_s"] is not None
                      and b["step_time_s"] > self.straggler_factor * med]
        healthy = [b["host"] for b in alive if b["host"] not in stragglers]
        return {"healthy": healthy, "dead": dead, "stragglers": stragglers,
                "skewed": skewed, "median_step_s": med}


@dataclasses.dataclass(frozen=True)
class HealthSnapshot:
    """One observation of a serving engine's health (``engine.health()``).

    Gauges describe the instant the snapshot was taken; counters are
    monotone totals since engine construction, so a monitor can difference
    two snapshots for rates. ``quarantined_slots`` lists slots a contained
    fault removed from the admission pool (``engine.rehabilitate()``
    returns them after a row reset).
    """

    t: float                      # wall time of the observation
    steps: int                    # decode dispatches so far (counter)
    queue_depth: int              # requests waiting for a slot (gauge)
    resident: int                 # occupied slots (gauge)
    free_slots: int               # admissible slots (gauge)
    quarantined_slots: Tuple[int, ...]  # suspect slots, out of the pool
    resident_tokens: int          # committed tokens of queued+resident work
    completed: int                # finished stop/length (counter)
    cancelled: int                # finished cancelled (counter)
    sheds: int                    # rejected at submit by admission control
    timeouts: int                 # retired by deadline sweep (counter)
    errors: int                   # retired by fault containment (counter)
    # ---- page-pool gauges (paged KV engines only; None/0 under the ring
    # layout so pre-paging snapshots and heartbeats stay comparable)
    pages_free: Optional[int] = None    # unowned physical pages (gauge)
    pages_used: Optional[int] = None    # pages with ref > 0 (gauge)
    pages_shared: Optional[int] = None  # pages with ref > 1, COW-protected
    prefix_hits: int = 0          # prefix-cache pages reused (counter)
    prefix_misses: int = 0        # lookups that ended cold (counter)
    prefix_evictions: int = 0     # cache entries dropped under pressure

    def beat(self, monitor: HeartbeatMonitor, step_time_s: float = 0.0,
             metrics: Optional[Dict] = None):
        """Publish this snapshot through the training-side heartbeat file
        protocol, so one :class:`StragglerDetector` watches both kinds of
        host. ``metrics`` (e.g. ``engine.obs.digest()``) merges extra
        flat keys into the payload — the serving metrics digest rides the
        same file."""
        extra = {k: v for k, v in dataclasses.asdict(self).items()
                 if k not in ("t", "steps")}
        if metrics:
            extra.update(metrics)
        monitor.beat(self.steps, step_time_s, **extra)

    def summary(self) -> str:
        """One log line (what ``launch/serve.py`` prints)."""
        q = ",".join(map(str, self.quarantined_slots)) or "-"
        line = (f"queue={self.queue_depth} resident={self.resident} "
                f"free={self.free_slots} quarantined=[{q}] "
                f"tokens={self.resident_tokens} done={self.completed} "
                f"cancelled={self.cancelled} shed={self.sheds} "
                f"timeout={self.timeouts} error={self.errors}")
        if self.pages_free is not None:
            line += (f" pages={self.pages_used}u/{self.pages_free}f"
                     f"/{self.pages_shared}s prefix={self.prefix_hits}h"
                     f"/{self.prefix_misses}m/{self.prefix_evictions}e")
        return line
