"""Heartbeats + straggler detection for multi-host training.

Each host writes a heartbeat file (step, wall time, step duration) every step;
the rank-0 monitor reads all heartbeats and flags:

  * **dead hosts**  — no heartbeat within `dead_after_s`,
  * **stragglers**  — per-step time > `straggler_factor` × fleet median.

On a real fleet the orchestrator restarts dead hosts from the latest
checkpoint (runtime/checkpoint.py is elastic, so a *smaller* healthy mesh can
also resume — straggler *mitigation by exclusion*). Here the detector's
decision logic is exercised directly by unit tests.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    """Per-host heartbeat writer."""

    run_dir: str
    host_id: int = 0

    def beat(self, step: int, step_time_s: float, **metrics):
        d = Path(self.run_dir) / "heartbeats"
        d.mkdir(parents=True, exist_ok=True)
        tmp = d / f".host{self.host_id:04d}.tmp"
        payload = {"host": self.host_id, "step": step, "t": time.time(),
                   "step_time_s": step_time_s, **metrics}
        tmp.write_text(json.dumps(payload))
        tmp.rename(d / f"host{self.host_id:04d}.json")


@dataclasses.dataclass
class StragglerDetector:
    """Rank-0 fleet health assessment from heartbeat files."""

    run_dir: str
    dead_after_s: float = 120.0
    straggler_factor: float = 2.0

    def read(self) -> List[Dict]:
        d = Path(self.run_dir) / "heartbeats"
        if not d.exists():
            return []
        out = []
        for p in sorted(d.glob("host*.json")):
            try:
                out.append(json.loads(p.read_text()))
            except (json.JSONDecodeError, OSError):
                continue  # torn read: skip this cycle
        return out

    def assess(self, now: Optional[float] = None) -> Dict:
        now = time.time() if now is None else now
        beats = self.read()
        if not beats:
            return {"healthy": [], "dead": [], "stragglers": [],
                    "median_step_s": None}
        dead = [b["host"] for b in beats if now - b["t"] > self.dead_after_s]
        alive = [b for b in beats if b["host"] not in dead]
        med = float(np.median([b["step_time_s"] for b in alive])) if alive \
            else None
        stragglers = [b["host"] for b in alive
                      if med and b["step_time_s"] > self.straggler_factor * med]
        healthy = [b["host"] for b in alive if b["host"] not in stragglers]
        return {"healthy": healthy, "dead": dead, "stragglers": stragglers,
                "median_step_s": med}
