"""Activation-sharding hooks: models call ``constrain(x, name)``; the launcher
installs a rule set mapping names → PartitionSpec under the active mesh.
Without an installed rule set (unit tests, single device) it is the identity.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def current_rules() -> Optional[Dict[str, "jax.sharding.PartitionSpec"]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, "jax.sharding.PartitionSpec"]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x: jax.Array, name: str) -> jax.Array:
    rules = current_rules()
    if not rules or name not in rules:
        return x
    spec = rules[name]
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
