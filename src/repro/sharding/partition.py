"""Sharding rules: param / batch / decode-state PartitionSpecs.

Mesh axes (launch/mesh.py):
  single pod:  ("data", "model") = (16, 16)
  multi-pod:   ("pod", "data", "model") = (2, 16, 16)

Strategy (DESIGN.md §5):
  * TP over "model": attention heads, FFN hidden, vocab, MoE experts
    (expert-parallel when E % tp == 0, else FFN-dim TP),
  * FSDP/ZeRO over "data" (+"pod"): the non-TP dim of every large matrix and
    its optimizer moments,
  * batch over ("pod","data"),
  * long-context decode: KV-cache sequence dim sharded over "data" (SP).

Every rule is divisibility-checked against the actual mesh axis sizes and
falls back to replication for a dim that does not divide — so the same rule
set serves full configs, smoke configs, and any elastic mesh shape.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quantize_model import QuantizedKernel

Axis = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# parallelism policy (hillclimb iteration 2, EXPERIMENTS.md §Perf):
#   "tp"       — TP over "model" + FSDP over "data"(+"pod")  [default]
#   "fsdp_all" — no TP; FSDP/ZeRO-3 + batch over EVERY mesh axis. For small
#                dense models at large token batches, TP's per-layer
#                activation all-reduces dwarf FSDP's param all-gathers —
#                fsdp_all trades ~6 (B,S,D)-sized all-reduces per layer for
#                ~3× param-bytes of all-gathers.
# ---------------------------------------------------------------------------
import contextlib
import threading

_policy_state = threading.local()


def current_policy() -> str:
    return getattr(_policy_state, "policy", "tp")


@contextlib.contextmanager
def parallelism_policy(policy: str):
    assert policy in ("tp", "fsdp_all")
    prev = current_policy()
    _policy_state.policy = policy
    try:
        yield
    finally:
        _policy_state.policy = prev


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def _maybe(mesh: Mesh, axis: Axis, dim: int) -> Axis:
    """Use `axis` for a dim only if the dim divides the axis size."""
    size = _axis_size(mesh, axis)
    return axis if (size > 1 and dim % size == 0) else None


def fsdp_axes(mesh: Mesh) -> Axis:
    if current_policy() == "fsdp_all":
        return tuple(mesh.axis_names)  # ZeRO-3 over every axis
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_axes(mesh: Mesh) -> Axis:
    return fsdp_axes(mesh)


def tp_axis(mesh: Mesh) -> Axis:
    return None if current_policy() == "fsdp_all" else "model"


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _dense_kernel_rule(path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for a 2/3-D dense kernel identified by its path."""
    fsdp = fsdp_axes(mesh)
    tp = tp_axis(mesh)

    def spec2(a: Axis, b: Axis, d0: int, d1: int) -> P:
        return P(_maybe(mesh, a, d0), _maybe(mesh, b, d1))

    d = shape[-2], shape[-1]
    if "embed/embedding" in path:
        return spec2(tp, fsdp, *d)
    if "lm_head" in path:
        return spec2(fsdp, tp, *d)
    if "/experts/" in path:
        e = shape[0]
        ep = tp is not None and e % mesh.shape[tp] == 0
        if path.endswith("wo/kernel"):
            return (P(tp, None, _maybe(mesh, fsdp, d[1])) if ep
                    else P(None, _maybe(mesh, tp, d[0]),
                           _maybe(mesh, fsdp, d[1])))
        return (P(tp, _maybe(mesh, fsdp, d[0]), None) if ep
                else P(None, _maybe(mesh, fsdp, d[0]),
                       _maybe(mesh, tp, d[1])))
    if "router" in path:
        return P(None, None)
    if "/chan/wv" in path or path.endswith("wo/kernel"):
        # contraction dim over TP, output dim over FSDP (row-parallel)
        return spec2(tp, fsdp, *d)
    if any(k in path for k in ("wq", "wk", "wv", "wg", "wi", "wr",
                               "wx", "wgate")):
        # column-parallel: input over FSDP, output over TP
        return spec2(fsdp, tp, *d)
    return P(*([None] * len(shape)))


def _param_rule(path: str, leaf, mesh: Mesh) -> P:
    fsdp = fsdp_axes(mesh)
    shape = leaf.shape
    rank = len(shape)

    stacked = "/blocks/" in path  # scanned stacks carry a leading layer dim

    def finish(spec: P) -> P:
        if stacked:
            return P(*((None,) + tuple(spec)))
        return spec

    core_shape = shape[1:] if stacked else shape
    core_rank = len(core_shape)

    if path.endswith("kernel") and core_rank in (2, 3):
        return finish(_dense_kernel_rule(path, core_shape, mesh))
    if path.endswith("embedding") and core_rank == 2:
        # vocab-sharded embedding table (leaf is "embedding", not "kernel")
        return finish(_dense_kernel_rule(path, core_shape, mesh))
    if path.endswith("bias") and core_rank == 1:
        # biases of TP-column-parallel layers live on the TP'd output dim
        if any(k in path for k in ("wq/", "wk/", "wv/", "wg/", "wi/",
                                   "wx/", "wgate/")):
            return finish(P(_maybe(mesh, tp_axis(mesh), core_shape[0])))
        return finish(P(None))
    if path.endswith("lam") and core_rank == 1:
        return finish(P(_maybe(mesh, tp_axis(mesh), core_shape[0])))
    if "conv/w" in path and core_rank == 2:
        return finish(P(None, _maybe(mesh, tp_axis(mesh), core_shape[1])))
    return finish(P(*([None] * core_rank)))


def _quantized_specs(path: str, qk: QuantizedKernel, mesh: Mesh, stacked: bool):
    """Derive trit-plane/scale specs from the dense kernel's rule.

    Buffer layouts: planes (lead..., d_out, d_in // 4), scales
    (lead..., d_out, d_in // G, 2). Leading dims: scan stack (L) and/or
    MoE experts (E) — E shards over "model" when divisible (EP)."""
    lead = qk.t1p.shape[:-2]
    tp, fsdp = tp_axis(mesh), fsdp_axes(mesh)

    if "/experts/" in path:
        e = lead[-1]
        ep = (tp is not None and mesh.shape[tp] > 1
              and e % mesh.shape[tp] == 0)
        e_ax = tp if ep else None
        if path.endswith("wo/kernel"):   # dense (E, fe, d)
            out_ax, in_ax = (fsdp, None) if ep else (fsdp, tp)
        else:                            # wi/wg: dense (E, d, fe)
            out_ax, in_ax = (None, fsdp) if ep else (tp, fsdp)
        head = (None,) * (len(lead) - 1) + (e_ax,)
    else:
        dense_spec = _dense_kernel_rule(path, (qk.d_in, qk.d_out), mesh)
        in_ax, out_ax = dense_spec[-2], dense_spec[-1]
        head = (None,) * len(lead)

    plane = P(*head, _maybe(mesh, out_ax, qk.d_out),
              _maybe(mesh, in_ax, qk.d_in // 4))
    alpha = P(*head, _maybe(mesh, out_ax, qk.d_out), None, None)
    return plane, plane, alpha


def param_pspecs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching a params tree (dense or quantized)."""

    def walk(node, path):
        if isinstance(node, QuantizedKernel):
            stacked = node.t1p.ndim == 3
            t1s, t2s, als = _quantized_specs(path, node, mesh, stacked)
            return QuantizedKernel(t1s, t2s, als, node.d_in, node.d_out,
                                   node.group_size)
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        return _param_rule(path, node, mesh)

    return walk(params, "")


# ---------------------------------------------------------------------------
# batch / decode-state rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch: Any, mesh: Mesh) -> Any:
    dp = dp_axes(mesh)

    def rule(leaf):
        b = leaf.shape[0]
        return P(*( (_maybe(mesh, dp, b),) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(rule, batch)


def state_pspecs(state: Any, mesh: Mesh, *, sequence_sharded: bool) -> Any:
    """Decode-state specs. sequence_sharded=True → long-context SP mode."""
    dp = dp_axes(mesh)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        shape = node.shape
        stacked = "/blocks/" in path
        core = shape[1:] if stacked else shape
        name = path.rsplit("/", 1)[-1]

        if name in ("k_scale", "v_scale"):  # (B, cap, KV) int8-cache scales
            if sequence_sharded:
                spec = (None, _maybe(mesh, "data", core[1]), None)
            else:
                spec = (_maybe(mesh, dp, core[0]),
                        _maybe(mesh, "model", core[1]), None)
        elif name in ("k", "v"):          # (B, cap, KV, hd)
            if sequence_sharded:
                spec = (None, _maybe(mesh, "data", core[1]), None, None)
            else:
                # batch over dp AND cache sequence over "model" (KV heads are
                # too few to TP; slot-sharding divides cache HBM by tp)
                spec = (_maybe(mesh, dp, core[0]),
                        _maybe(mesh, "model", core[1]), None, None)
        elif name == "pos" and len(core) == 2:   # ring position buffer
            if sequence_sharded:
                spec = (None, _maybe(mesh, "data", core[1]))
            else:
                spec = (_maybe(mesh, dp, core[0]),
                        _maybe(mesh, "model", core[1]))
        elif name == "pos":                      # top-level (B,)
            spec = (_maybe(mesh, dp, core[0]),)
        elif name == "wkv":                      # (B, H, hd, hd)
            spec = (_maybe(mesh, dp, core[0]), None, None, None)
        elif name in ("h",):                     # (B, R)
            spec = (_maybe(mesh, dp, core[0]),
                    _maybe(mesh, "model", core[1]))
        elif name == "conv":                     # (B, W-1, R)
            spec = (_maybe(mesh, dp, core[0]), None,
                    _maybe(mesh, "model", core[2]))
        else:                                    # x_time/x_chan etc. (B, D)
            spec = ((_maybe(mesh, dp, core[0]),) +
                    (None,) * (len(core) - 1))
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    return walk(state, "")


def activation_rules(mesh: Mesh, *, mode: str) -> Dict[str, P]:
    """Rules consumed by repro.sharding.api.constrain inside the models."""
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    if mode == "train" or mode == "prefill":
        return {
            "hidden": P(dp, None, None),
            "logits": P(dp, None, tp),
            "decode_logits": P(dp, tp),
        }
    if mode == "decode":
        return {"decode_logits": P(dp, tp), "hidden": None}
    if mode == "decode_long":   # batch=1: only vocab TP applies
        return {"decode_logits": P(None, tp), "hidden": None}
    raise ValueError(mode)


def named(tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    def conv(node):
        if isinstance(node, P):
            return NamedSharding(mesh, node)
        return node

    # QuantizedKernel nodes hold specs in their children; map over leaves
    return jax.tree.map(conv, tree,
                        is_leaf=lambda x: isinstance(x, P))
