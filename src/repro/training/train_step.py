"""Train-step builder: microbatched gradient accumulation + AdamW update.

The step is a single jittable function over
  state = {"params", "opt", "step"}   and   batch = {"tokens"/"embeddings",
                                                     "labels"}
Gradient accumulation (`cfg.microbatches`) reshapes the global batch to
(M, B/M, ...) and `lax.scan`s the value-and-grad over chunks — the activation
-memory lever that fits llama3-405b's 1M-token batches on 256 chips.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.optim.adamw import AdamW


def make_train_step(cfg, opt: AdamW):
    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        m = cfg.microbatches
        if m > 1:
            def split(x):
                b = x.shape[0]
                assert b % m == 0, (b, m)
                return x.reshape(m, b // m, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                loss, g = grads_of(params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (loss_sum + loss, g_sum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, g_sum), _ = jax.lax.scan(acc, (jnp.float32(0.0), g0),
                                                micro)
            loss = loss_sum / m
            grads = jax.tree.map(lambda g: (g / m).astype(jnp.float32), g_sum)
        else:
            loss, grads = grads_of(params, batch)

        new_params, new_opt = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss}
        return new_state, metrics

    return train_step


def init_train_state(cfg, params, opt: AdamW) -> Dict[str, Any]:
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}
