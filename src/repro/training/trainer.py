"""Trainer: data pipeline + jit'd train step + fault-tolerance plumbing.

Wires together every runtime substrate (DESIGN.md §5):
  * deterministic sharded loader (resume-aware — restarts mid-epoch exactly),
  * CheckpointManager (periodic, atomic, elastic),
  * PreemptionGuard (SIGTERM → final checkpoint, ≤ 1 step lost),
  * HeartbeatMonitor (straggler/dead-host detection feed),
  * optional int8 gradient compression with error feedback.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, ShardedLoader
from repro.optim.adamw import AdamW
from repro.optim.compress import compress_decompress, init_error_feedback
from repro.runtime.checkpoint import CheckpointManager, restore_sharded
from repro.runtime.monitor import HeartbeatMonitor
from repro.runtime.preempt import PreemptionGuard
from repro.training.train_step import make_train_step
from repro.models import init_params, loss_fn


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    ckpt_keep: int = 2
    log_interval: int = 10
    grad_compress: bool = False
    seed: int = 0
    run_dir: Optional[str] = None    # heartbeats


class Trainer:
    def __init__(self, model_cfg, opt: AdamW, data_cfg: DataConfig,
                 tcfg: TrainerConfig,
                 log_fn: Callable[[str], None] = print):
        self.cfg = model_cfg
        self.opt = opt
        self.tcfg = tcfg
        self.loader = ShardedLoader(data_cfg)
        self.log = log_fn
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_interval,
                                       tcfg.ckpt_keep)
                     if tcfg.ckpt_dir else None)
        self.hb = (HeartbeatMonitor(tcfg.run_dir) if tcfg.run_dir else None)
        self._step_fn = jax.jit(self._build_step(), donate_argnums=(0,))
        self.history: list[Dict[str, float]] = []
        # stub modality frontend for [audio]/[vlm] archs: tokens -> fixed
        # pseudo-embeddings (the frontend is frozen & out of scope, DESIGN §4)
        self._stub_embed = None
        if not model_cfg.embed_inputs:
            rng = np.random.default_rng(tcfg.seed)
            self._stub_embed = rng.standard_normal(
                (512, model_cfg.d_model)).astype(np.float32) * 0.02

    # ------------------------------------------------------------------ build
    def _build_step(self):
        base = make_train_step(self.cfg, self.opt)
        if not self.tcfg.grad_compress:
            return base

        cfg, opt = self.cfg, self.opt

        def step_with_compression(state, batch):
            params = state["params"]
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch))(params)
            grads, err = compress_decompress(grads, state["err"])
            new_params, new_opt = opt.update(grads, state["opt"], params)
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1, "err": err}
            return new_state, {"loss": loss}

        return step_with_compression

    def init_state(self, key=None) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.tcfg.seed) if key is None else key
        params = init_params(self.cfg, key)
        state = {"params": params, "opt": self.opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.tcfg.grad_compress:
            state["err"] = init_error_feedback(params)
        return state

    # ------------------------------------------------------------------- run
    def fit(self, state: Optional[Dict[str, Any]] = None,
            shardings: Any = None,
            guard: Optional[PreemptionGuard] = None) -> Dict[str, Any]:
        start_step = 0
        if state is None:
            if self.ckpt is not None:
                try:
                    start_step, host_tree, _ = self.ckpt.restore_latest()
                    state = restore_sharded(host_tree, shardings)
                    self.log(f"[trainer] resumed from step {start_step}")
                except FileNotFoundError:
                    state = self.init_state()
            else:
                state = self.init_state()

        stream = self.loader.iterate(start_step)
        with (guard or PreemptionGuard()) as guard:
            for step in range(start_step, self.tcfg.total_steps):
                batch = next(stream)
                if self._stub_embed is not None:
                    batch = {"embeddings":
                             self._stub_embed[batch["tokens"] % 512],
                             "labels": batch["labels"]}
                t0 = time.perf_counter()
                state, metrics = self._step_fn(
                    state, jax.tree.map(jnp.asarray, batch))
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.history.append({"step": step + 1, "loss": loss,
                                     "time_s": dt})
                if self.hb:
                    self.hb.beat(step + 1, dt, loss=loss)
                if (step + 1) % self.tcfg.log_interval == 0:
                    self.log(f"[trainer] step {step + 1} "
                             f"loss {loss:.4f} ({dt * 1e3:.0f} ms)")
                if self.ckpt and (self.ckpt.should_save(step + 1)
                                  or guard.preempted):
                    self.ckpt.save(step + 1, state)
                    self.log(f"[trainer] checkpoint @ {step + 1}")
                if guard.preempted:
                    self.log("[trainer] preempted: exiting cleanly")
                    break
        self.loader.close()
        if self.ckpt and not guard.preempted:
            self.ckpt.save(int(state["step"]), state)
        return state
