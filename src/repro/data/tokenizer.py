"""Byte-level tokenizer: vocab = 256 bytes + BOS/EOS/PAD specials.

Offline-friendly (no vocab files) and loss-free: the in-repo perplexity
benchmarks (paper Tables 1/9 in-miniature) tokenize the synthetic corpus with
this and report byte-level PPL.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258

    vocab_size = 259

    def encode(self, text: str, *, bos: bool = True, eos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        data = bytes(i for i in ids if i < 256)
        return data.decode("utf-8", errors="replace")

    def encode_batch(self, texts: Iterable[str], seq_len: int) -> np.ndarray:
        """Fixed-length right-padded batch (B, seq_len) int32."""
        rows = []
        for t in texts:
            ids = self.encode(t)[:seq_len]
            ids = ids + [self.PAD] * (seq_len - len(ids))
            rows.append(ids)
        return np.asarray(rows, np.int32)
