from repro.data.tokenizer import ByteTokenizer
from repro.data.synthetic import synthetic_corpus
from repro.data.pipeline import DataConfig, ShardedLoader

__all__ = ["ByteTokenizer", "synthetic_corpus", "DataConfig", "ShardedLoader"]
