"""Deterministic synthetic corpus with learnable structure.

WikiText/C4 are unavailable offline, so quality claims are validated
in-miniature (DESIGN.md §8.2): we synthesize text with real statistical
structure — a small vocabulary of templated sentences, arithmetic facts, and
key-value recall patterns — so a ~100M-parameter byte LM trained on it reaches
non-trivial perplexity, and quantization-induced degradation is measurable and
ordered (FP > PTQTP > 3-bit > 2-bit > binary, the paper's Table 1 ordering).
"""

from __future__ import annotations

import numpy as np

_SUBJECTS = ["the model", "a tensor", "the kernel", "one pod", "the mesh",
             "a shard", "the cache", "this layer", "the router", "an expert"]
_VERBS = ["computes", "reduces", "gathers", "stores", "emits", "scans",
          "quantizes", "packs", "shards", "streams"]
_OBJECTS = ["two trit planes", "a scaling pair", "the residual", "group scales",
            "eight experts", "the logits", "a block of weights",
            "the key cache", "an update", "ternary values"]
_ADVERBS = ["quickly", "exactly", "in parallel", "per group", "on chip",
            "without loss", "row by row", "every step", "at once", "in place"]


def _sentence(rng: np.random.Generator) -> str:
    kind = rng.integers(0, 4)
    if kind == 0:  # templated sentence (grammar structure)
        return (f"{_SUBJECTS[rng.integers(10)]} {_VERBS[rng.integers(10)]} "
                f"{_OBJECTS[rng.integers(10)]} {_ADVERBS[rng.integers(10)]}. ")
    if kind == 1:  # arithmetic fact (mathematical structure; paper's math-
        a, b = rng.integers(0, 50, size=2)  # reasoning retention claim)
        return f"{a} plus {b} equals {a + b}. "
    if kind == 2:  # key-value recall (in-context structure)
        k, v = rng.integers(0, 100, size=2)
        return f"slot {k} holds {v} ; recall slot {k} gives {v}. "
    # counting pattern (sequence structure)
    s = rng.integers(0, 30)
    return "count " + " ".join(str(s + i) for i in range(4)) + ". "


def synthetic_corpus(n_bytes: int, seed: int = 0) -> bytes:
    """Deterministic pseudo-text corpus of (at least) n_bytes bytes."""
    rng = np.random.default_rng(seed)
    parts, total = [], 0
    while total < n_bytes:
        s = _sentence(rng)
        parts.append(s)
        total += len(s)
    return "".join(parts).encode("utf-8")[:n_bytes]
