"""Deterministic sharded data pipeline.

Design constraints for 1000+ node training (DESIGN.md §5):

  * **Stateless addressing** — batch `step` for host `h` of `H` is a pure
    function of (seed, step, h, H): restart/elastic-rescale needs no data
    checkpoints; a run resumed on a different host count replays no example
    twice within an epoch window.
  * **Host-sharded** — every host materializes only its `global_batch / H`
    slice; the train loop feeds `jax.make_array_from_process_local_data`-style
    per-host arrays (single-process here, but the addressing is multi-host).
  * **Double-buffered** — a background thread prefetches the next batch while
    the step runs (overlap host compute with device compute).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import synthetic_corpus
from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    corpus_bytes: int = 1 << 20
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0


class ShardedLoader:
    """Deterministic loader over a byte corpus, host-sharded + prefetched."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tok = ByteTokenizer()
        corpus = synthetic_corpus(cfg.corpus_bytes, seed=cfg.seed)
        self._ids = np.frombuffer(corpus, np.uint8).astype(np.int32)
        self._n_windows = len(self._ids) - cfg.seq_len - 1
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- stateless batch addressing ---------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Host-local batch for global `step` (pure function of step)."""
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        # per-(step,row) deterministic window offsets (splitmix64, uint64)
        row0 = cfg.host_id * per_host
        rows = np.arange(row0, row0 + per_host, dtype=np.uint64)
        with np.errstate(over="ignore"):
            mix = (np.uint64(step) * np.uint64(0x9E3779B97F4A7C15)
                   + rows * np.uint64(0xBF58476D1CE4E5B9)
                   + np.uint64(cfg.seed))
            mix = (mix ^ (mix >> np.uint64(31))) \
                * np.uint64(0x94D049BB133111EB)
        offs = (mix % np.uint64(self._n_windows)).astype(np.int64)
        tokens = np.stack([self._ids[o:o + cfg.seq_len] for o in offs])
        labels = np.stack([self._ids[o + 1:o + 1 + cfg.seq_len] for o in offs])
        return {"tokens": tokens, "labels": labels}

    # -- prefetching iterator ----------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate(start_step=0)

    def iterate(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetched stream starting at `start_step` (resume-aware)."""
        self._q = queue.Queue(maxsize=self.cfg.prefetch)
        self._stop.clear()

        def producer():
            step = start_step
            try:
                while not self._stop.is_set():
                    batch = self.batch_at(step)
                    while not self._stop.is_set():
                        try:
                            self._q.put(("ok", batch), timeout=0.1)
                            break
                        except queue.Full:
                            continue
                    step += 1
            except BaseException as e:  # propagate, never hang the consumer
                self._q.put(("err", e))

        self._thread = threading.Thread(target=producer, daemon=True)
        self._thread.start()
        try:
            while True:
                kind, payload = self._q.get()
                if kind == "err":
                    raise payload
                yield payload
        finally:
            self.close()

    def close(self):
        self._stop.set()
        if self._q is not None:
            try:  # unblock the producer
                self._q.get_nowait()
            except queue.Empty:
                pass
