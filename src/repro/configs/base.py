"""Model/config schema shared by all assigned architectures.

A model is a *block pattern*: an optional prefix, a repeating period (scanned
with `lax.scan` so compile time is O(1) in depth), and an automatic remainder.
Block kinds compose a token mixer and a channel mixer:

  "attn+mlp"   full-causal GQA + FFN            (llama/qwen/musicgen/phi)
  "local+mlp"  sliding-window GQA + FFN         (gemma3 local, recurrentgemma)
  "attn+moe"   full-causal GQA + MoE FFN        (grok, deepseek)
  "rwkv"       RWKV6 time-mix + channel-mix
  "rglru+mlp"  RG-LRU recurrent block + FFN
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.moe import MoEConfig

SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp_type: str = "swiglu"
    block_pattern: Tuple[str, ...] = ("attn+mlp",)
    prefix_pattern: Tuple[str, ...] = ()
    window: Optional[int] = None       # sliding-window size for "local" blocks
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    embed_inputs: bool = True          # False: stub frontend feeds embeddings
    moe: Optional[MoEConfig] = None
    # rwkv / rglru
    rwkv_head_dim: int = 64
    rglru_width: Optional[int] = None
    rglru_blocks: Optional[int] = None  # block-diag gate blocks (≈ n_heads)
    conv_width: int = 4
    # infra
    scan_layers: bool = True
    remat: str = "full"                # none | full
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (§Perf iteration 5)
    # serving chunk/decode attention backend over the ring cache:
    # auto | pallas | stream | materialized (repro.kernels.chunk_attention)
    attn_backend: str = "auto"
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"   # adam moment dtype (bf16 for 405B)
    microbatches: int = 1              # gradient-accumulation chunks
    q_chunk: int = 1024                # attention query-chunk size
    # dry-run bookkeeping
    supports_long_context: bool = False  # sub-quadratic mixers only

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    # ---- pattern layout -------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        return (self.n_layers - len(self.prefix_pattern)) // self.period

    @property
    def remainder_pattern(self) -> Tuple[str, ...]:
        rem = (self.n_layers - len(self.prefix_pattern)) % self.period
        return self.block_pattern[:rem]

    @property
    def layer_kinds(self):
        """Flat list of all n_layers block kinds, in order."""
        full = list(self.prefix_pattern)
        full += list(self.block_pattern) * self.n_periods
        full += list(self.remainder_pattern)
        assert len(full) == self.n_layers, (len(full), self.n_layers)
        return full

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config derivation for smoke tests."""
        return dataclasses.replace(self, **kw)

    # ---- parameter accounting (roofline: MODEL_FLOPS = 6·N·D) -----------
    def param_counts(self):
        """(total_params, active_params) analytic counts."""
        d, hd = self.d_model, self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        mlps = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp_type]
        mlp = mlps * d * self.d_ff
        rwkv = 5 * d * d + d * self.d_ff * 2 + d * d  # time (5 proj) + channel
        rg = self.rglru_width or d
        nb = self.rglru_blocks or 1
        rglru = 2 * d * rg + rg * d + 2 * nb * (rg // nb) ** 2 + 4 * rg

        total = active = 0
        for kind in self.layer_kinds:
            if kind == "rwkv":
                total += rwkv
                active += rwkv
            elif kind.startswith("rglru"):
                total += rglru + mlp
                active += rglru + mlp
            else:
                total += attn
                active += attn
                if kind.endswith("moe"):
                    m = self.moe
                    e_p = 3 * d * m.d_expert
                    total += m.n_experts * e_p + d * m.n_experts
                    active += m.top_k * e_p + d * m.n_experts
                    if m.n_shared:
                        total += 3 * d * m.n_shared * m.d_expert
                        active += 3 * d * m.n_shared * m.d_expert
                else:
                    total += mlp
                    active += mlp
        emb = self.vocab_size * d
        total += emb * 2  # embed + lm_head
        active += emb * 2
        return total, active
