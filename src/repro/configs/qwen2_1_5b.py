"""qwen2-1.5b — dense GQA (kv=2) with QKV bias [arXiv:2407.10671; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", remat="none", q_chunk=16,
)
