"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a stub — ``input_specs`` provides
precomputed frame embeddings (B, S, d_model); the decoder predicts codebook
tokens over a 2048-entry vocabulary.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    embed_inputs=False,           # stub frontend feeds frame embeddings
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", remat="none", q_chunk=16,
)
