"""Architecture registry: ``--arch <id>`` → (full config, smoke config)."""

from repro.configs import (
    deepseek_moe_16b,
    gemma3_27b,
    grok1_314b,
    llama3_405b,
    musicgen_large,
    phi3_vision_4_2b,
    qwen15_32b,
    qwen2_1_5b,
    recurrentgemma_2b,
    rwkv6_3b,
)
from repro.configs.base import SHAPES, ModelConfig

_MODULES = {
    "rwkv6-3b": rwkv6_3b,
    "qwen1.5-32b": qwen15_32b,
    "qwen2-1.5b": qwen2_1_5b,
    "llama3-405b": llama3_405b,
    "gemma3-27b": gemma3_27b,
    "musicgen-large": musicgen_large,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "grok-1-314b": grok1_314b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "recurrentgemma-2b": recurrentgemma_2b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


def runnable_cells():
    """All (arch, shape) dry-run cells, honoring long-context skips."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context:
                continue  # pure full-attention: documented skip (DESIGN.md §4)
            cells.append((arch, shape))
    return cells
