"""recurrentgemma-2b — Griffin: 2× RG-LRU : 1 local-attn, kv=1
[arXiv:2402.19427; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_type="geglu",
    block_pattern=("rglru+mlp", "rglru+mlp", "local+mlp"),
    window=2048,
    rglru_width=2560,
    rglru_blocks=10,
    conv_width=4,
    supports_long_context=True,    # O(1) state for 2/3 layers, ring for attn
)

SMOKE = CONFIG.scaled(
    n_layers=5,    # one period (3) + remainder (2 rglru)
    d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=512, window=8, rglru_width=64, rglru_blocks=4,
    param_dtype="float32", activation_dtype="float32", remat="none", q_chunk=16,
)
