"""gemma3-27b — 5 local : 1 global GQA, 262k vocab [hf:google/gemma-3-1b-pt; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    mlp_type="geglu",
    block_pattern=("local+mlp",) * 5 + ("attn+mlp",),   # 5:1 local:global
    window=1024,
    rope_theta=1e6,
    microbatches=4,
    # only 1/6 layers carry a full-context KV cache; local layers hold a
    # 1024-slot ring → long_500k decode runs (DESIGN.md §4)
    supports_long_context=True,
)

SMOKE = CONFIG.scaled(
    n_layers=8,   # one scanned period (6) + remainder (2 local)
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, window=8,
    param_dtype="float32", activation_dtype="float32", remat="none",
    q_chunk=16, microbatches=1,
)
