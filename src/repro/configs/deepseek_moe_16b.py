"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]. First layer is a dense FFN (d_ff=10944), the
remaining 27 layers are MoE with per-expert hidden 1408.
"""

from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                        # dense first layer (paper Table 1)
    vocab_size=102400,
    prefix_pattern=("attn+mlp",),      # layer 0 dense
    block_pattern=("attn+moe",),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25),
)

SMOKE = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  capacity_factor=-1.0),
    param_dtype="float32", activation_dtype="float32", remat="none", q_chunk=16,
)
