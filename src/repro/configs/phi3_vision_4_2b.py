"""phi-3-vision-4.2b — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

Backbone only: the CLIP vision tower is a stub — ``input_specs`` provides
precomputed patch/text embeddings (B, S, d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    embed_inputs=False,           # stub CLIP frontend feeds embeddings
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", remat="none", q_chunk=16,
)
