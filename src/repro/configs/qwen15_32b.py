"""qwen1.5-32b — dense MHA (kv=40) with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    microbatches=4,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", remat="none",
    q_chunk=16, microbatches=1,
)
