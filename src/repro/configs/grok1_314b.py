"""grok-1-314b — MoE 8 experts top-2, GQA kv=8 [hf:xai-org/grok-1; unverified]."""

from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    block_pattern=("attn+moe",),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768, capacity_factor=1.25),
    microbatches=8,
    optimizer_dtype="bfloat16",
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=-1.0),
    param_dtype="float32", activation_dtype="float32", remat="none",
    q_chunk=16, microbatches=1, optimizer_dtype="float32",
)
