"""llama3-405b — dense GQA (kv=8), 128k vocab [arXiv:2407.21783; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
    microbatches=16,              # activation memory: 256×4k tokens → 16 chunks
    optimizer_dtype="bfloat16",   # adam moments in bf16 so 405B fits 256 chips
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", remat="none",
    q_chunk=16, microbatches=1, optimizer_dtype="float32",
)
