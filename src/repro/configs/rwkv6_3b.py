"""rwkv6-3b — Finch, attention-free data-dependent-decay SSM [arXiv:2404.05892; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    supports_long_context=True,   # O(T) recurrence → long_500k runs
)

SMOKE = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, rwkv_head_dim=16, param_dtype="float32",
    activation_dtype="float32", remat="none", q_chunk=16,
)
