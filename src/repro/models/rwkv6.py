"""RWKV6 "Finch" block: data-dependent token-shift + decay linear recurrence.

Faithful to arXiv:2404.05892 at the block level:

  time-mix:
    ddlerp token shift    x_j = x + (shift(x) − x) ⊙ (μ_j + lora_j(x))
    projections           r, k, v, g  (D→D);  g gated with SiLU
    data-dependent decay  w_t = exp(−exp(w0 + tanh(x_w W_a) W_b))  per channel
    per-head WKV state    S_t = diag(w_t) S_{t−1} + k_t v_tᵀ      (hd × hd)
    readout               y_t = r_tᵀ (S_{t−1} + diag(u) k_t v_tᵀ)
    group-norm over heads, ⊙ g, output projection.

  channel-mix:
    k = relu(x_k W_k)²;  y = σ(x_r W_r) ⊙ (k W_v)

Training/prefill run the recurrence as a `lax.scan` over time (O(T·D·hd)
FLOPs — the sub-quadratic path that makes `long_500k` runnable); decode is a
single recurrence step on a (B, H, hd, hd) state.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init

_MIX_NAMES = ("w", "k", "v", "r", "g")
_LORA_R = 32       # token-shift lora rank
_DECAY_R = 64      # decay lora rank


def rwkv_time_init(key, d: int, head_dim: int, dtype) -> Dict[str, Any]:
    h = d // head_dim
    ks = jax.random.split(key, 12)
    p: Dict[str, Any] = {
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((len(_MIX_NAMES), d), dtype),
        "mix_lora_a": (jax.random.normal(ks[0], (d, len(_MIX_NAMES) * _LORA_R))
                       * 0.01).astype(dtype),
        "mix_lora_b": (jax.random.normal(ks[1], (len(_MIX_NAMES), _LORA_R, d))
                       * 0.01).astype(dtype),
        "decay_base": jnp.linspace(-6.0, -1.0, d).astype(dtype),
        "decay_lora_a": (jax.random.normal(ks[2], (d, _DECAY_R)) * 0.01).astype(dtype),
        "decay_lora_b": (jax.random.normal(ks[3], (_DECAY_R, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[4], (h, head_dim)) * 0.1).astype(dtype),
        "wr": dense_init(ks[5], d, d, dtype=dtype),
        "wk": dense_init(ks[6], d, d, dtype=dtype),
        "wv": dense_init(ks[7], d, d, dtype=dtype),
        "wg": dense_init(ks[8], d, d, dtype=dtype),
        "wo": dense_init(ks[9], d, d, dtype=dtype),
        "ln_x": {"scale": jnp.ones((d,), dtype)},
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift (B, S, D) -> dict of mixed inputs."""
    sx = x_prev - x
    xx = x + sx * p["mu_x"].astype(x.dtype)
    a = jnp.tanh(jnp.einsum("bsd,dr->bsr", xx, p["mix_lora_a"].astype(x.dtype)))
    a = a.reshape(*a.shape[:-1], len(_MIX_NAMES), _LORA_R)
    adj = jnp.einsum("bsnr,nrd->bsnd", a, p["mix_lora_b"].astype(x.dtype))
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mix = p["mu"][i].astype(x.dtype) + adj[..., i, :]
        out[name] = x + sx * mix
    return out


def _decay(p, xw):
    """Per-token per-channel decay w_t ∈ (0, 1)."""
    lo = jnp.einsum("bsd,dr->bsr", xw, p["decay_lora_a"].astype(xw.dtype))
    lo = jnp.einsum("bsr,rd->bsd", jnp.tanh(lo), p["decay_lora_b"].astype(xw.dtype))
    return jnp.exp(-jnp.exp(p["decay_base"].astype(jnp.float32) +
                            lo.astype(jnp.float32)))


def _group_norm(scale, x, h):
    """Head-wise group norm over (B, S, H*hd)."""
    b = x.shape[:-1]
    d = x.shape[-1]
    xg = x.reshape(*b, h, d // h).astype(jnp.float32)
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(*b, d) * scale.astype(jnp.float32)).astype(x.dtype)


def _masked_last(x, state_prev, mask):
    """Per-row last *valid* timestep of x (B, S, ...); rows with no valid
    step keep their prior state (chunked prefill: a length-0 row is a no-op).
    """
    lengths = jnp.sum(mask.astype(jnp.int32), axis=1)
    idx = jnp.maximum(lengths - 1, 0)
    last = jnp.take_along_axis(
        x, idx.reshape((-1,) + (1,) * (x.ndim - 1)), axis=1)[:, 0]
    prev = state_prev if state_prev is not None else jnp.zeros_like(last)
    live = (lengths > 0).reshape((-1,) + (1,) * (last.ndim - 1))
    return jnp.where(live, last, prev)


def rwkv_time_forward(p, x, head_dim: int, state=None, mask=None):
    """x: (B, S, D). Returns (y, (x_last, S_last)) for cache handoff.

    mask (B, S) bool selects the valid timesteps of a right-padded chunk:
    masked-out steps leave the WKV state untouched and the handoff state is
    taken at each row's last valid step (chunked/bucketed prefill).
    """
    bsz, s, d = x.shape
    h = d // head_dim
    x_prev = jnp.concatenate(
        [state[0][:, None] if state is not None else jnp.zeros_like(x[:, :1]),
         x[:, :-1]], axis=1)
    m = _ddlerp(p, x, x_prev)
    r = dense(p["wr"], m["r"]).reshape(bsz, s, h, head_dim)
    k = dense(p["wk"], m["k"]).reshape(bsz, s, h, head_dim)
    v = dense(p["wv"], m["v"]).reshape(bsz, s, h, head_dim)
    g = jax.nn.silu(dense(p["wg"], m["g"]))
    w = _decay(p, m["w"]).reshape(bsz, s, h, head_dim)  # f32
    u = p["u"].astype(jnp.float32)

    s0 = (state[1] if state is not None
          else jnp.zeros((bsz, h, head_dim, head_dim), jnp.float32))

    def step(carry, inp):
        st = carry  # (B, H, hd, hd)
        rt, kt, vt, wt, mt = inp  # (B, H, hd) each; mt (B,)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        yt = jnp.einsum("bhi,bhij->bhj", rt, st + u[None, :, :, None] * kv)
        st_new = wt[..., None] * st + kv
        st = jnp.where(mt[:, None, None, None], st_new, st)
        return st, yt

    mk = (mask if mask is not None
          else jnp.ones((bsz, s), bool))
    xs = (
        jnp.moveaxis(r, 1, 0).astype(jnp.float32),
        jnp.moveaxis(k, 1, 0).astype(jnp.float32),
        jnp.moveaxis(v, 1, 0).astype(jnp.float32),
        jnp.moveaxis(w, 1, 0),
        jnp.moveaxis(mk, 1, 0),
    )
    s_last, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    y = _group_norm(p["ln_x"]["scale"], y, h) * g
    if mask is None:
        x_last = x[:, -1]
    else:
        x_last = _masked_last(x, state[0] if state is not None else None, mask)
    return dense(p["wo"], y), (x_last, s_last)


def rwkv_time_decode(p, x_t, head_dim: int, state):
    """x_t: (B, D); state = (x_prev (B,D), S (B,H,hd,hd))."""
    y, new_state = rwkv_time_forward(p, x_t[:, None], head_dim, state)
    return y[:, 0], new_state


def rwkv_channel_init(key, d: int, d_ff: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "wk": dense_init(ks[0], d, d_ff, dtype=dtype),
        "wv": dense_init(ks[1], d_ff, d, dtype=dtype),
        "wr": dense_init(ks[2], d, d, dtype=dtype),
    }


def rwkv_channel_forward(p, x, state=None, mask=None):
    """x: (B, S, D) -> (y, x_last). mask as in ``rwkv_time_forward``."""
    x_prev = jnp.concatenate(
        [state[:, None] if state is not None else jnp.zeros_like(x[:, :1]),
         x[:, :-1]], axis=1)
    sx = x_prev - x
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(p["wk"], xk)))
    y = jax.nn.sigmoid(dense(p["wr"], xr)) * dense(p["wv"], k)
    x_last = x[:, -1] if mask is None else _masked_last(x, state, mask)
    return y, x_last


def rwkv_channel_decode(p, x_t, state):
    y, new_state = rwkv_channel_forward(p, x_t[:, None], state)
    return y[:, 0], new_state
