"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based dispatch,
optional shared experts (DeepSeek-MoE style), expert-parallel friendly layout.

Dispatch is the sort/scatter formulation (no (T, E, C) one-hot tensors):
  1. router top-k per token, probabilities renormalized over the k winners,
  2. (token, expert) assignments sorted by expert id,
  3. rank-within-expert via counts/segment offsets,
  4. scatter into dense (E, C, D) buffers (capacity-dropped tokens masked),
  5. per-expert FFN as one stacked einsum over the E axis — shardable over
     the `model` mesh axis when E % tp == 0 (expert parallelism), else the
     FFN hidden dim shards (tensor parallelism),
  6. gather + combine back to (T, D).

Capacity C = ceil(T·k/E · capacity_factor) bounds compute and makes the
FLOP count match the active-parameter roofline (6·N_active·D).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init
from repro.models.mlp import mlp_forward, mlp_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


def moe_init(key, d_model: int, moe: MoEConfig, mlp_type: str, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    e, fe = moe.n_experts, moe.d_expert
    std = 1.0 / jnp.sqrt(d_model).astype(jnp.float32)
    params: Dict[str, Any] = {
        "router": dense_init(ks[0], d_model, e, dtype=jnp.float32),
        "experts": {
            "wi": {"kernel": (jax.random.normal(ks[1], (e, d_model, fe)) * std).astype(dtype)},
            "wg": {"kernel": (jax.random.normal(ks[2], (e, d_model, fe)) * std).astype(dtype)},
            "wo": {"kernel": (jax.random.normal(ks[3], (e, fe, d_model))
                              * (1.0 / jnp.sqrt(fe))).astype(dtype)},
        },
    }
    if moe.n_shared:
        params["shared"] = mlp_init(ks[4], d_model, moe.n_shared * fe, mlp_type,
                                    dtype)
    return params


def _expert_ffn(experts: Dict[str, Any], xe: jax.Array) -> jax.Array:
    """xe: (E, C, D) -> (E, C, D) via per-expert SwiGLU."""
    from repro.core.quantize_model import QuantizedKernel
    from repro.kernels.ternary_matmul.ops import ternary_matmul
    from repro.models.common import matmul_backend

    def mm(p, x, eq):
        k = p["kernel"]
        if isinstance(k, QuantizedKernel):
            def one(xi, t1p, t2p, al):
                return ternary_matmul(xi, t1p, t2p, al, group_size=k.group_size,
                                      backend=matmul_backend(), out_dtype=xi.dtype)
            return jax.vmap(one)(x, k.t1p, k.t2p, k.alpha)
        return jnp.einsum(eq, x, k.astype(x.dtype))

    h = jax.nn.silu(mm(experts["wg"], xe, "ecd,edf->ecf")) * mm(
        experts["wi"], xe, "ecd,edf->ecf")
    return mm(experts["wo"], h, "ecf,efd->ecd")


def moe_forward(params: Dict[str, Any], x: jax.Array, moe: MoEConfig,
                mlp_type: str = "swiglu", valid: Optional[jax.Array] = None
                ) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    valid (B, S) bool: padding tokens of a bucketed/chunked prefill batch.
    Invalid tokens are routed to the overflow slot so they can never
    displace a real token from expert capacity (their output rows are
    garbage either way, but cross-row contamination would not be).
    """
    b, s, d = x.shape
    t = b * s
    e, k = moe.n_experts, moe.top_k
    xf = x.reshape(t, d)

    logits = dense(params["router"], xf.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # flatten (token, slot) assignments and sort by expert
    flat_e = top_e.reshape(-1)                      # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    if valid is not None:
        # expert id `e` = overflow: sorts after every real expert, so ranks
        # of valid assignments are exactly what they'd be without padding
        flat_e = jnp.where(jnp.repeat(valid.reshape(t), k), flat_e, e)
    order = jnp.argsort(flat_e, stable=True)
    se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]

    counts = jnp.bincount(se, length=e)             # (E,) — id e dropped
    starts = jnp.cumsum(counts) - counts            # exclusive prefix
    rank = jnp.arange(t * k) - starts[jnp.minimum(se, e - 1)]

    if moe.capacity_factor <= 0:
        cap = t * k  # exact no-drop mode (tests / tiny decode batches)
    else:
        cap = int(max(1, round(t * k / e * moe.capacity_factor)))
    keep = (rank < cap) & (se < e)
    dst = jnp.where(keep, se * cap + jnp.clip(rank, 0, cap - 1), e * cap)

    # scatter tokens into (E*C (+1 overflow), D)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dst].add(xf[stok] * keep[:, None].astype(x.dtype))
    xe = buf[:-1].reshape(e, cap, d)

    ye = _expert_ffn(params["experts"], xe)         # (E, C, D)
    yf = ye.reshape(e * cap, d)

    # gather back and combine
    contrib = yf[jnp.clip(dst, 0, e * cap - 1)] * (
        sp * keep.astype(jnp.float32))[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)

    if "shared" in params:
        y = y + mlp_forward(params["shared"], xf, mlp_type)
    return y.reshape(b, s, d)


def moe_aux_loss(params: Dict[str, Any], x: jax.Array, moe: MoEConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style): E · Σ_e f_e · p_e."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = dense(params["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, moe.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return moe.n_experts * jnp.sum(frac * imp)
