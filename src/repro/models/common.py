"""Shared building blocks: dense (with PTQTP dispatch), RMSNorm, RoPE, init.

The framework is pure-functional JAX: params are nested dicts of arrays,
modules are (init, apply) function pairs. A dense layer's ``kernel`` leaf may
be replaced post-training by a ``QuantizedKernel`` (two packed trit-planes +
group scales); ``dense`` dispatches on the leaf type, so *every* model in the
zoo serves quantized without architectural change — the paper's
model-agnosticity claim, made structural.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize_model import QuantizedKernel

_state = threading.local()


def matmul_backend() -> str:
    """Active quantized-matmul backend. Defaults to 'auto': the Pallas hand
    kernels (small-m decode fast path included) on TPU, XLA grouped on CPU."""
    return getattr(_state, "backend", "auto")


@contextlib.contextmanager
def use_matmul_backend(backend: str):
    """Select the quantized-matmul backend ('auto'|'grouped'|'pallas'|'ref')."""
    prev = matmul_backend()
    _state.backend = backend
    try:
        yield
    finally:
        _state.backend = prev


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Dict[str, Any]:
    std = scale if scale is not None else (1.0 / np.sqrt(d_in))
    p = {"kernel": (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: Dict[str, Any], x: jax.Array) -> jax.Array:
    """y = x @ kernel (+ bias); kernel may be a QuantizedKernel."""
    k = params["kernel"]
    if isinstance(k, QuantizedKernel):
        from repro.kernels.ternary_matmul.ops import ternary_matmul

        y = ternary_matmul(
            x, k.t1p, k.t2p, k.alpha,
            group_size=k.group_size, backend=matmul_backend(),
            out_dtype=x.dtype,
        )
    else:
        y = jnp.einsum("...d,df->...f", x, k.astype(x.dtype))
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def norm_init(d: int, dtype=jnp.float32) -> Dict[str, Any]:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: Dict[str, Any], x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., H, hd) with positions (..., S) / (...,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]
