"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = dual linear branches → temporal conv1d (width 4) → RG-LRU → gated out:

  x_b = W_x·x ;  g_b = gelu(W_g·x)
  c_t = conv1d(x_b)                                 (depthwise, width 4)
  r_t = σ(BD_a(c_t));  i_t = σ(BD_x(c_t))           (block-diagonal gates)
  a_t = exp(−c·softplus(Λ) ⊙ r_t)                   (c = 8)
  h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ c_t)
  y   = W_o (g_b ⊙ h)

State is (B, R) hidden + (B, conv_width−1, R) conv tail — O(1) per decoded
token, which is what makes recurrentgemma a `long_500k` architecture.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init

_C = 8.0  # Griffin's recurrence-gate sharpness constant


def rglru_init(key, d: int, r: int, n_blocks: int, conv_width: int,
               dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    rb = r // n_blocks
    return {
        "wx": dense_init(ks[0], d, r, dtype=dtype),
        "wgate": dense_init(ks[1], d, r, dtype=dtype),
        "conv": {
            "w": (jax.random.normal(ks[2], (conv_width, r)) * 0.1).astype(dtype),
            "b": jnp.zeros((r,), dtype),
        },
        "gate_a": {"w": (jax.random.normal(ks[3], (n_blocks, rb, rb))
                         * (1.0 / jnp.sqrt(rb))).astype(dtype),
                   "b": jnp.zeros((r,), dtype)},
        "gate_x": {"w": (jax.random.normal(ks[4], (n_blocks, rb, rb))
                         * (1.0 / jnp.sqrt(rb))).astype(dtype),
                   "b": jnp.zeros((r,), dtype)},
        # softplus(Λ) init so a^c ≈ 0.9…0.999 (Griffin's stable range)
        "lam": jnp.linspace(-4.3, -0.7, r).astype(dtype),
        "wo": dense_init(ks[5], r, d, dtype=dtype),
    }


def _block_diag(gate, x, n_blocks: int):
    """x: (..., R) through block-diagonal weight (n_blocks, rb, rb)."""
    r = x.shape[-1]
    rb = r // n_blocks
    xb = x.reshape(*x.shape[:-1], n_blocks, rb)
    y = jnp.einsum("...nr,nrs->...ns", xb, gate["w"].astype(x.dtype))
    return y.reshape(*x.shape[:-1], r) + gate["b"].astype(x.dtype)


def _conv1d(p, x, conv_state=None):
    """Causal depthwise conv. x: (B, S, R); conv_state: (B, W-1, R).

    Returns (y, xp) where xp is the full padded input (B, W-1+S, R) — the
    caller extracts the next conv state (per-row for masked chunks).
    """
    w = p["w"].astype(x.dtype)  # (W, R)
    width = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return y + p["b"].astype(x.dtype), xp


def _lru_scan(a, gx, h0, mask=None):
    """h_t = a_t ⊙ h_{t−1} + gx_t ; all (B, S, R) f32; h0 (B, R).

    mask (B, S): masked-out steps carry h_{t−1} through unchanged.
    """
    def step(h, inp):
        at, gt, mt = inp
        h = jnp.where(mt[:, None], at * h + gt, h)
        return h, h

    mk = mask if mask is not None else jnp.ones(a.shape[:2], bool)
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gx, 1, 0),
          jnp.moveaxis(mk, 1, 0))
    h_last, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), h_last


def rglru_forward(p, x, n_blocks: int, state: Tuple | None = None, mask=None):
    """x: (B, S, D) -> (y, (h_last, conv_state)).

    mask (B, S) marks the valid timesteps of a right-padded chunk: the LRU
    state freezes on padded steps and the conv tail is gathered at each
    row's valid length (chunked/bucketed prefill support).
    """
    b, s, d = x.shape
    conv_state = state[1] if state is not None else None
    h0 = (state[0] if state is not None
          else jnp.zeros((b, p["lam"].shape[0]), jnp.float32))

    xb = dense(p["wx"], x)
    gb = jax.nn.gelu(dense(p["wgate"], x))
    c, xp = _conv1d(p["conv"], xb, conv_state)
    width = p["conv"]["w"].shape[0]
    if mask is None:
        conv_state = xp[:, -(width - 1):]
    else:
        # per-row tail: the W-1 inputs ending at each row's valid length
        # (lengths == 0 reduces to xp[:, :W-1] — the untouched prior state)
        lengths = jnp.sum(mask.astype(jnp.int32), axis=1)
        idx = lengths[:, None] + jnp.arange(width - 1)[None, :]
        conv_state = jnp.take_along_axis(xp, idx[..., None], axis=1)

    rt = jax.nn.sigmoid(_block_diag(p["gate_a"], c, n_blocks)).astype(jnp.float32)
    it = jax.nn.sigmoid(_block_diag(p["gate_x"], c, n_blocks)).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rt
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        it * c.astype(jnp.float32))
    h, h_last = _lru_scan(a, gated_x, h0, mask)
    y = dense(p["wo"], (gb.astype(jnp.float32) * h).astype(x.dtype))
    return y, (h_last, conv_state)


def rglru_decode(p, x_t, n_blocks: int, state):
    y, new_state = rglru_forward(p, x_t[:, None], n_blocks, state)
    return y[:, 0], new_state


def rglru_state_init(batch: int, r: int, conv_width: int, dtype):
    return (jnp.zeros((batch, r), jnp.float32),
            jnp.zeros((batch, conv_width - 1, r), dtype))
