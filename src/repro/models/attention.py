"""GQA attention: chunked full-causal and sliding-window variants + ring cache.

Memory-safe by construction: the (S × S) score matrix is never materialized —
training/prefill scans over query chunks (full attention: each chunk scores
against all keys; local attention: only against the ⌈W/C⌉+1 covering key
chunks, giving the O(S·W) FLOP count that the gemma3/recurrentgemma roofline
requires).

The decode cache is a *ring buffer* with per-slot absolute positions:
full-attention layers use capacity = max context, sliding-window layers use
capacity = W (so a gemma3 local layer at 500k context holds 1024 slots, not
500k — the cache-memory optimization that makes `long_500k` feasible).
One implementation serves both (window = capacity ⇒ full attention).

Every serving-time attention read — chunk prefill and single-token decode
(its L = 1 case) — goes through one backend-dispatched op,
``repro.kernels.chunk_attention``: online softmax against (pre-write ring ∪
in-chunk keys), the int8 ring dequantized tile-by-tile at the compute unit,
never as a whole. ``cfg.attn_backend`` selects the implementation (Pallas
on TPU, the streaming tile-loop fallback elsewhere, or the materialized
baseline); the visible-set rule is identical across backends.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.chunk_attention.ops import (chunk_attention,
                                               chunk_attention_paged)
from repro.models.common import apply_rope, dense, dense_init, norm_init, rms_norm

NEG_INF = -1e30


def attn_init(key, cfg, dtype) -> Dict[str, Any]:
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias,
                         dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype=dtype),
    }


def _qkv(params, cfg, x, positions):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B, Sq, KV, G, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk) f32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: (B, KV, G, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, KV, G, hd)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(p.dtype))


def _pick_chunk(s: int, target: int) -> int:
    for c in range(min(target, s), 0, -1):
        if s % c == 0:
            return c
    return s


def attention_forward(
    params: Dict[str, Any],
    cfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    return_kv: bool = False,
):
    """Full-sequence causal (optionally sliding-window) attention.

    Args:
      x: (B, S, D); positions: (S,) absolute positions (training: arange).
      window: sliding-window size; None = full causal.
      return_kv: also return the rotary-applied (k, v) for cache prefill.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    scale = hd ** -0.5

    q, k, v = _qkv(params, cfg, x, positions[None, :])
    q = q.reshape(b, s, kv, g, hd)

    c = _pick_chunk(s, q_chunk)
    n_chunks = s // c

    if window is None or window >= s:
        # full causal: each q chunk scores against all keys
        def body(_, i):
            q_i = jax.lax.dynamic_slice(q, (0, i * c, 0, 0, 0),
                                        (b, c, kv, g, hd))
            qpos = jax.lax.dynamic_slice(positions, (i * c,), (c,))
            logits = _gqa_scores(q_i, k) * scale  # (B,KV,G,c,S)
            mask = positions[None, :] <= qpos[:, None]  # (c, S)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            return None, _gqa_out(p, v)

        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    else:
        w = window
        n_prev = -(-w // c)  # chunks of history needed left of the q chunk
        span = (n_prev + 1) * c

        def body(_, i):
            q_i = jax.lax.dynamic_slice(q, (0, i * c, 0, 0, 0),
                                        (b, c, kv, g, hd))
            start = jnp.maximum(i * c - n_prev * c, 0)
            k_i = jax.lax.dynamic_slice(k, (0, start, 0, 0), (b, min(span, s), kv, hd))
            v_i = jax.lax.dynamic_slice(v, (0, start, 0, 0), (b, min(span, s), kv, hd))
            qpos = jax.lax.dynamic_slice(positions, (i * c,), (c,))
            kpos = jax.lax.dynamic_slice(positions, (start,), (min(span, s),))
            logits = _gqa_scores(q_i, k_i) * scale
            mask = (kpos[None, :] <= qpos[:, None]) & (
                qpos[:, None] - kpos[None, :] < w
            )
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            return None, _gqa_out(p, v_i)

        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))

    # outs: (n_chunks, B, c, KV, G, hd) -> (B, S, H*hd)
    y = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.n_heads * hd)
    y = dense(params["wo"], y.astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# decode: ring-buffer KV cache
# ---------------------------------------------------------------------------

def cache_init(cfg, batch: int, capacity: int, window: Optional[int],
               dtype, *, kv_spec: Optional[Dict[str, int]] = None
               ) -> Dict[str, Any]:
    """Ring cache. capacity = min(window, max_context) for local layers.

    kv_cache_dtype="int8" (§Perf it. 5, beyond-paper): k/v stored int8 with
    per-(slot, kv-head) absmax scales — halves cache HBM capacity AND the
    decode-read traffic that dominates the decode_32k memory term.

    ``kv_spec = {"page_size": ps, "max_pages": n}`` selects the *paged*
    layout instead (see :func:`paged_cache_init`).
    """
    if kv_spec is not None:
        return paged_cache_init(cfg, batch, capacity, window, dtype,
                                page_size=kv_spec["page_size"],
                                max_pages=kv_spec["max_pages"])
    cap = min(window, capacity) if window else capacity
    hd = cfg.head_dim
    cache = {"pos": jnp.full((batch, cap), -1, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros((batch, cap, cfg.n_kv_heads, hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, cap, cfg.n_kv_heads, hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype)
    return cache


def paged_cache_init(cfg, batch: int, capacity: int, window: Optional[int],
                     dtype, *, page_size: int, max_pages: int
                     ) -> Dict[str, Any]:
    """Paged KV cache: one batch-global pool of fixed-size pages plus a
    per-row page table (``repro.kernels.chunk_attention`` paged contract).

    Pool leaves are named ``pages_*`` — they are *physical* storage owned
    by the allocator, not per-row state, and the engine's row reset skips
    them. ``table`` (B, n_pages) int32 is per-row; entry 0 points at the
    reserved null page (``pages_pos[0] ≡ -1``, never written), so an
    unmapped logical page reads as empty. The pool holds ``max_pages``
    allocatable pages + the null page.

    Sliding-window layers are rejected: paging virtualizes one uniform
    logical capacity per row, and a window < capacity layer would need its
    own shorter ring (use ``kv_layout="ring"`` for such models).
    """
    if window is not None and window < capacity:
        raise ValueError(
            f"paged KV layout requires full-capacity attention layers "
            f"(window {window} < capacity {capacity}); use the ring layout "
            "for sliding-window models")
    if capacity % page_size:
        raise ValueError(f"page_size {page_size} must divide "
                         f"capacity {capacity}")
    hd = cfg.head_dim
    n_pages = capacity // page_size
    P = max_pages + 1  # + the reserved null page 0
    cache = {
        "pages_pos": jnp.full((P, page_size), -1, jnp.int32),
        "table": jnp.zeros((batch, n_pages), jnp.int32),
    }
    if cfg.kv_cache_dtype == "int8":
        cache["pages_k"] = jnp.zeros((P, page_size, cfg.n_kv_heads, hd),
                                     jnp.int8)
        cache["pages_v"] = jnp.zeros((P, page_size, cfg.n_kv_heads, hd),
                                     jnp.int8)
        cache["pages_ks"] = jnp.zeros((P, page_size, cfg.n_kv_heads),
                                      jnp.float32)
        cache["pages_vs"] = jnp.zeros((P, page_size, cfg.n_kv_heads),
                                      jnp.float32)
    else:
        cache["pages_k"] = jnp.zeros((P, page_size, cfg.n_kv_heads, hd),
                                     dtype)
        cache["pages_v"] = jnp.zeros((P, page_size, cfg.n_kv_heads, hd),
                                     dtype)
    return cache


def _q8(x):
    """absmax int8 quantization over the trailing (head) dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-10)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def cache_prefill(cfg, cache: Dict[str, Any], k, v, positions) -> Dict[str, Any]:
    """Write a full prefill sequence into the ring (keeps the last `cap`).

    k/v: (B, S, KV, hd); positions: (B, S) absolute.
    """
    b, s, kv, hd = k.shape
    cap = cache["k"].shape[1]
    if s <= cap:
        ktail, vtail, ptail = k, v, positions
    else:
        ktail, vtail, ptail = k[:, -cap:], v[:, -cap:], positions[:, -cap:]
    slots = ptail % cap
    out = {"pos": _scatter_slots(cache["pos"], slots,
                                 ptail.astype(jnp.int32))}
    if "k_scale" in cache:  # int8 cache
        kq, ks = _q8(ktail)
        vq, vs = _q8(vtail)
        out["k"] = _scatter_slots(cache["k"], slots, kq)
        out["v"] = _scatter_slots(cache["v"], slots, vq)
        out["k_scale"] = _scatter_slots(cache["k_scale"], slots, ks)
        out["v_scale"] = _scatter_slots(cache["v_scale"], slots, vs)
    else:
        out["k"] = _scatter_slots(cache["k"], slots, ktail)
        out["v"] = _scatter_slots(cache["v"], slots, vtail)
    return out


def _scatter_slots(buf, slots, vals):
    """buf: (B, cap, ...), slots: (B, S), vals: (B, S, ...).

    Slot index == cap (one past the ring) means "drop this entry" — used by
    the chunked-prefill path to skip right-padding and stale wrap-around
    writes without a select over the whole cache.
    """
    def per_batch(bf, sl, vl):
        return bf.at[sl].set(vl, mode="drop")

    return jax.vmap(per_batch)(buf, slots, vals)


def _scatter_pages(pool, table, slots, vals):
    """Paged analogue of ``_scatter_slots``: write logical ring slots
    through the page table into the physical pool.

    pool: (P, ps, ...); table: (B, n_pages) int32; slots: (B, S) *logical*
    slot ids where slot == n_pages·ps means "drop" (same sentinel rule as
    the contiguous path); vals: (B, S, ...).

    Writes resolving to the null page (table entry 0 — an unmapped logical
    page) are dropped too: the null page's pos ≡ -1 invariant is what makes
    unmapped gathers safe, so nothing may ever dirty it. Distinct rows
    never map a writable logical page to the same physical page (the
    allocator copy-on-write-forks shared pages before any dispatch that
    writes them), so the flattened scatter has no cross-row collisions.
    """
    P, ps = pool.shape[0], pool.shape[1]
    n_pages = table.shape[1]
    page = jnp.clip(slots // ps, 0, n_pages - 1)
    phys = jnp.take_along_axis(table, page, axis=1)          # (B, S)
    flat = phys * ps + slots % ps
    drop = (slots >= n_pages * ps) | (phys == 0)
    flat = jnp.where(drop, P * ps, flat)                     # out of range
    fp = pool.reshape((P * ps,) + pool.shape[2:])
    fp = fp.at[flat.reshape(-1)].set(
        vals.reshape((-1,) + vals.shape[2:]).astype(pool.dtype), mode="drop")
    return fp.reshape(pool.shape)


def attention_prefill_chunk(
    params: Dict[str, Any],
    cfg,
    cache: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    lengths: jax.Array,
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Padded-batch chunk prefill: attend to (ring cache ∪ chunk), then write.

    Args:
      x: (B, L, D) right-padded chunk; positions: (B, L) absolute positions
      (row r valid through positions[r, lengths[r]-1]); lengths: (B,) valid
      token counts — 0 makes the row a complete no-op (its cache survives
      untouched, so decoding/free rows can ride along in a fixed-shape
      dispatch).

    The chunk queries score against the *pre-write* ring (history from
    earlier chunks — for sliding-window layers the ring holds exactly the
    last `cap` positions, which covers every in-chunk query's window) and
    against the in-chunk keys, in one online softmax via
    ``repro.kernels.chunk_attention`` — the (L, cap+L) score block is never
    materialized and the int8 ring is dequantized per streamed tile, not as
    a whole (``cfg.attn_backend`` picks the implementation). Afterwards the
    chunk k/v are scattered into the ring; padding and entries a row's own
    chunk tail would immediately overwrite (length > cap) are dropped.
    """
    b, L, _ = x.shape
    hd = cfg.head_dim
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    paged = "table" in cache
    cap = (cache["table"].shape[1] * cache["pages_k"].shape[1] if paged
           else cache["k"].shape[1])

    q, k, v = _qkv(params, cfg, x, positions)
    qh = q.reshape(b, L, kv, g, hd)

    valid = jnp.arange(L)[None, :] < lengths[:, None]        # (B, L)
    if paged:
        y = chunk_attention_paged(
            qh, k, v, cache["pages_k"], cache.get("pages_ks"),
            cache["pages_v"], cache.get("pages_vs"), cache["pages_pos"],
            cache["table"], positions, lengths.astype(jnp.int32),
            window=window, backend=cfg.attn_backend)
    else:
        y = chunk_attention(
            qh, k, v, cache["k"], cache.get("k_scale"), cache["v"],
            cache.get("v_scale"), cache["pos"], positions,
            lengths.astype(jnp.int32), window=window,
            backend=cfg.attn_backend)
    y = y.reshape(b, L, cfg.n_heads * hd).astype(x.dtype)
    y = dense(params["wo"], y)

    # write the chunk into the ring (drop padding + beyond-ring tail)
    row_end = positions[:, :1] + lengths[:, None]            # (B, 1)
    keep = valid & (positions >= row_end - cap)
    slots = jnp.where(keep, positions % cap, cap).astype(jnp.int32)
    if paged:
        return y, _write_pages(cache, slots, k, v, positions)
    out = {"pos": _scatter_slots(cache["pos"], slots,
                                 positions.astype(jnp.int32))}
    if "k_scale" in cache:
        kq, ks = _q8(k)
        vq, vs = _q8(v)
        out["k"] = _scatter_slots(cache["k"], slots, kq)
        out["v"] = _scatter_slots(cache["v"], slots, vq)
        out["k_scale"] = _scatter_slots(cache["k_scale"], slots, ks)
        out["v_scale"] = _scatter_slots(cache["v_scale"], slots, vs)
    else:
        out["k"] = _scatter_slots(cache["k"], slots, k.astype(cache["k"].dtype))
        out["v"] = _scatter_slots(cache["v"], slots, v.astype(cache["v"].dtype))
    return y, out


def _write_pages(cache, slots, k, v, positions):
    """Scatter chunk k/v (B, S, KV, hd) at logical ``slots`` (sentinel
    n_pages·ps = drop) through the page table; shared by the chunked
    prefill and decode (S = 1) write paths."""
    table = cache["table"]
    out = {"table": table,
           "pages_pos": _scatter_pages(cache["pages_pos"], table, slots,
                                       positions.astype(jnp.int32))}
    if "pages_ks" in cache:  # int8 pages: quantize the written entries
        kq, ks = _q8(k)
        vq, vs = _q8(v)
        out["pages_k"] = _scatter_pages(cache["pages_k"], table, slots, kq)
        out["pages_v"] = _scatter_pages(cache["pages_v"], table, slots, vq)
        out["pages_ks"] = _scatter_pages(cache["pages_ks"], table, slots, ks)
        out["pages_vs"] = _scatter_pages(cache["pages_vs"], table, slots, vs)
    else:
        out["pages_k"] = _scatter_pages(cache["pages_k"], table, slots, k)
        out["pages_v"] = _scatter_pages(cache["pages_v"], table, slots, v)
    return out


def attention_decode(
    params: Dict[str, Any],
    cfg,
    cache: Dict[str, Any],
    x_t: jax.Array,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. x_t: (B, D); pos: (B,) absolute position of x_t.

    active (B,) bool: rows with active=False leave the ring untouched (their
    write is dropped) — required when decode shares the batch state with
    rows that are still mid-prefill (their caches must not be corrupted).

    Routed through ``repro.kernels.chunk_attention`` as the L = 1 case:
    the token scores against (pre-write ring ∪ itself) under the shared
    mask rule — the op's ``reach`` cap masks the slot this token's own
    write evicts, which is exactly the write-then-attend semantics — so
    decode, chunked prefill, and the serial path share one masking
    implementation. active=False rows pass length 0 (no self key, no
    write), mirroring their dropped write.
    """
    b, _ = x_t.shape
    hd = cfg.head_dim
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    paged = "table" in cache
    cap = (cache["table"].shape[1] * cache["pages_k"].shape[1] if paged
           else cache["k"].shape[1])

    q = dense(params["wq"], x_t).reshape(b, cfg.n_heads, hd)
    k_t = dense(params["wk"], x_t).reshape(b, kv, hd)
    v_t = dense(params["wv"], x_t).reshape(b, kv, hd)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_t = apply_rope(k_t, pos, cfg.rope_theta)

    qh = q.reshape(b, 1, kv, g, hd)
    lengths = (active.astype(jnp.int32) if active is not None
               else jnp.ones((b,), jnp.int32))
    if paged:
        y = chunk_attention_paged(
            qh, k_t[:, None], v_t[:, None], cache["pages_k"],
            cache.get("pages_ks"), cache["pages_v"], cache.get("pages_vs"),
            cache["pages_pos"], cache["table"],
            pos[:, None].astype(jnp.int32), lengths, window=window,
            backend=cfg.attn_backend)
    else:
        y = chunk_attention(
            qh, k_t[:, None], v_t[:, None], cache["k"], cache.get("k_scale"),
            cache["v"], cache.get("v_scale"), cache["pos"],
            pos[:, None].astype(jnp.int32), lengths, window=window,
            backend=cfg.attn_backend)
    y = y.reshape(b, cfg.n_heads * hd).astype(x_t.dtype)
    y = dense(params["wo"], y)

    slot = (pos % cap).astype(jnp.int32)  # (B,)
    if active is not None:
        slot = jnp.where(active, slot, cap)  # cap = out of ring → dropped
    if paged:
        return y, _write_pages(cache, slot[:, None], k_t[:, None],
                               v_t[:, None], pos[:, None])
    upd = lambda bf, s_, v_: bf.at[s_].set(v_, mode="drop")
    pc = jax.vmap(upd)(cache["pos"], slot, pos.astype(jnp.int32))
    new_cache = {"pos": pc}
    if "k_scale" in cache:  # int8 cache: quantize the new token's write
        kq, ks = _q8(k_t)
        vq, vs = _q8(v_t)
        new_cache.update(
            k=jax.vmap(upd)(cache["k"], slot, kq),
            v=jax.vmap(upd)(cache["v"], slot, vq),
            k_scale=jax.vmap(upd)(cache["k_scale"], slot, ks),
            v_scale=jax.vmap(upd)(cache["v_scale"], slot, vs))
    else:
        new_cache.update(k=jax.vmap(upd)(cache["k"], slot,
                                         k_t.astype(cache["k"].dtype)),
                         v=jax.vmap(upd)(cache["v"], slot,
                                         v_t.astype(cache["v"].dtype)))
    return y, new_cache
