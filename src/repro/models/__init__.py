"""Model zoo: one generic decoder covering all 10 assigned architectures."""

from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    prefill,
    prefill_chunk,
)
from repro.models.common import use_matmul_backend

__all__ = [
    "init_params", "forward", "loss_fn", "prefill", "prefill_chunk",
    "decode_step", "init_decode_state", "use_matmul_backend",
]
