"""Feed-forward variants: SwiGLU (llama/qwen/phi), GeGLU (gemma), GELU (musicgen)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init


def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "wg": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype=dtype),
        }
    if mlp_type == "gelu":
        return {
            "wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype=dtype),
        }
    raise ValueError(mlp_type)


def mlp_forward(params: Dict[str, Any], x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * dense(params["wi"], x)
    elif mlp_type == "geglu":
        h = jax.nn.gelu(dense(params["wg"], x)) * dense(params["wi"], x)
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dense(params["wi"], x))
    else:
        raise ValueError(mlp_type)
    return dense(params["wo"], h)
