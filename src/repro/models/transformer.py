"""Generic decoder LM covering all assigned architectures.

Layer stacking: prefix blocks (unscanned) + `lax.scan` over the repeating
block-pattern period (compile time O(1) in depth; params stacked with a
leading n_periods axis) + automatic remainder blocks. Remat wraps the scan
body. Decode threads per-layer caches through the same scan as (xs → ys).

Model API (all pure functions):
  init_params(cfg, key)                        → params
  forward(params, cfg, batch)                  → logits (B, S, V)
  loss_fn(params, cfg, batch)                  → scalar xent (+ MoE aux)
  prefill(params, cfg, batch, capacity)        → (last_logits, state)
  decode_step(params, cfg, state, tokens)      → (logits, state)
  init_decode_state(cfg, batch, capacity)      → state   (zeros; for dry-run
                                                  use jax.eval_shape)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import dense, dense_init, dtype_of, norm_init, rms_norm
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# block init / forward / prefill / decode dispatch
# ---------------------------------------------------------------------------

def _block_init(kind: str, cfg, key, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "rwkv":
        return {
            "time_norm": norm_init(d, dtype),
            "time": rwkv_mod.rwkv_time_init(ks[0], d, cfg.rwkv_head_dim, dtype),
            "chan_norm": norm_init(d, dtype),
            "chan": rwkv_mod.rwkv_channel_init(ks[1], d, cfg.d_ff, dtype),
        }
    if kind.startswith("rglru"):
        r = cfg.rglru_width or d
        nb = cfg.rglru_blocks or cfg.n_heads
        return {
            "rec_norm": norm_init(d, dtype),
            "rec": rglru_mod.rglru_init(ks[0], d, r, nb, cfg.conv_width, dtype),
            "mlp_norm": norm_init(d, dtype),
            "mlp": mlp_mod.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype),
        }
    mixer, ffn = kind.split("+")
    p = {
        "attn_norm": norm_init(d, dtype),
        "attn": attn.attn_init(ks[0], cfg, dtype),
        "mlp_norm": norm_init(d, dtype),
    }
    if ffn == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe, cfg.mlp_type, dtype)
    else:
        p["mlp"] = mlp_mod.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _mixer_window(kind: str, cfg) -> Optional[int]:
    return cfg.window if kind.startswith("local") else None


def _block_forward(kind: str, p, cfg, x, positions):
    if kind == "rwkv":
        y, _ = rwkv_mod.rwkv_time_forward(
            p["time"], rms_norm(p["time_norm"], x, cfg.norm_eps),
            cfg.rwkv_head_dim)
        x = x + y
        y, _ = rwkv_mod.rwkv_channel_forward(
            p["chan"], rms_norm(p["chan_norm"], x, cfg.norm_eps))
        return x + y
    if kind.startswith("rglru"):
        y, _ = rglru_mod.rglru_forward(
            p["rec"], rms_norm(p["rec_norm"], x, cfg.norm_eps),
            cfg.rglru_blocks or cfg.n_heads)
        x = x + y
        y = mlp_mod.mlp_forward(p["mlp"],
                                rms_norm(p["mlp_norm"], x, cfg.norm_eps),
                                cfg.mlp_type)
        return x + y
    # attention blocks
    y = attn.attention_forward(
        p["attn"], cfg, rms_norm(p["attn_norm"], x, cfg.norm_eps), positions,
        window=_mixer_window(kind, cfg), q_chunk=cfg.q_chunk)
    x = x + y
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if "moe" in p:
        y = moe_mod.moe_forward(p["moe"], h, cfg.moe, cfg.mlp_type)
    else:
        y = mlp_mod.mlp_forward(p["mlp"], h, cfg.mlp_type)
    return x + y


def _block_cache_init(kind: str, cfg, batch: int, capacity: int, dtype,
                      kv_spec=None):
    if kind == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_dim
        return {
            "x_time": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32),
            "x_chan": jnp.zeros((batch, cfg.d_model), dtype),
        }
    if kind.startswith("rglru"):
        r = cfg.rglru_width or cfg.d_model
        return {
            "h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
        }
    # recurrent states above are per-row and tiny — kv_spec (the paged KV
    # layout) applies to the attention ring caches only
    return attn.cache_init(cfg, batch, capacity, _mixer_window(kind, cfg),
                           dtype, kv_spec=kv_spec)


def _block_prefill(kind: str, p, cfg, x, positions, cache):
    """Full-seq forward that also fills the decode cache."""
    if kind == "rwkv":
        h = rms_norm(p["time_norm"], x, cfg.norm_eps)
        y, (x_last, wkv) = rwkv_mod.rwkv_time_forward(p["time"], h,
                                                      cfg.rwkv_head_dim)
        x = x + y
        h = rms_norm(p["chan_norm"], x, cfg.norm_eps)
        y, xc_last = rwkv_mod.rwkv_channel_forward(p["chan"], h)
        return x + y, {"x_time": x_last, "wkv": wkv, "x_chan": xc_last}
    if kind.startswith("rglru"):
        h = rms_norm(p["rec_norm"], x, cfg.norm_eps)
        y, (h_last, conv_state) = rglru_mod.rglru_forward(
            p["rec"], h, cfg.rglru_blocks or cfg.n_heads)
        x = x + y
        y = mlp_mod.mlp_forward(p["mlp"],
                                rms_norm(p["mlp_norm"], x, cfg.norm_eps),
                                cfg.mlp_type)
        return x + y, {"h": h_last, "conv": conv_state}
    # attention blocks
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    window = _mixer_window(kind, cfg)
    y, (k, v) = attn.attention_forward(
        p["attn"], cfg, h, positions, window=window, q_chunk=cfg.q_chunk,
        return_kv=True)
    b, s, _ = x.shape
    pos_bs = jnp.broadcast_to(positions[None, :], (b, s))
    cache = attn.cache_prefill(cfg, cache, k, v, pos_bs)
    x = x + y
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if "moe" in p:
        y = moe_mod.moe_forward(p["moe"], h, cfg.moe, cfg.mlp_type)
    else:
        y = mlp_mod.mlp_forward(p["mlp"], h, cfg.mlp_type)
    return x + y, cache


def _freeze_rows(active, new, old):
    """Per-row select: rows with active=False keep their old cache leaves."""
    if active is None:
        return new
    sel = lambda n, o: jnp.where(
        active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
    return jax.tree.map(sel, new, old)


def _block_decode(kind: str, p, cfg, cache, x_t, pos, active=None):
    if kind == "rwkv":
        h = rms_norm(p["time_norm"], x_t, cfg.norm_eps)
        y, (x_last, wkv) = rwkv_mod.rwkv_time_decode(
            p["time"], h, cfg.rwkv_head_dim, (cache["x_time"], cache["wkv"]))
        x_t = x_t + y
        h = rms_norm(p["chan_norm"], x_t, cfg.norm_eps)
        y, xc_last = rwkv_mod.rwkv_channel_decode(p["chan"], h, cache["x_chan"])
        new = {"x_time": x_last, "wkv": wkv, "x_chan": xc_last}
        return x_t + y, _freeze_rows(active, new, cache)
    if kind.startswith("rglru"):
        h = rms_norm(p["rec_norm"], x_t, cfg.norm_eps)
        y, (h_last, conv_state) = rglru_mod.rglru_decode(
            p["rec"], h, cfg.rglru_blocks or cfg.n_heads,
            (cache["h"], cache["conv"]))
        x_t = x_t + y
        y = mlp_mod.mlp_forward(p["mlp"],
                                rms_norm(p["mlp_norm"], x_t, cfg.norm_eps),
                                cfg.mlp_type)
        new = {"h": h_last, "conv": conv_state}
        return x_t + y, _freeze_rows(active, new, cache)
    h = rms_norm(p["attn_norm"], x_t, cfg.norm_eps)
    y, cache = attn.attention_decode(p["attn"], cfg, cache, h, pos,
                                     window=_mixer_window(kind, cfg),
                                     active=active)
    x_t = x_t + y
    h = rms_norm(p["mlp_norm"], x_t, cfg.norm_eps)
    if "moe" in p:
        y = moe_mod.moe_forward(p["moe"], h[:, None], cfg.moe, cfg.mlp_type)[:, 0]
    else:
        y = mlp_mod.mlp_forward(p["mlp"], h, cfg.mlp_type)
    return x_t + y, cache


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    n_extra = 3 + len(cfg.prefix_pattern) + len(cfg.remainder_pattern)
    keys = jax.random.split(key, cfg.n_periods * cfg.period + n_extra)
    ki = iter(range(len(keys)))

    params: Dict[str, Any] = {}
    if cfg.embed_inputs:
        params["embed"] = {
            "embedding": (jax.random.normal(keys[next(ki)],
                                            (cfg.vocab_size, cfg.d_model))
                          * 0.02).astype(dtype)
        }
    params["prefix"] = {
        f"p{i}": _block_init(kind, cfg, keys[next(ki)], dtype)
        for i, kind in enumerate(cfg.prefix_pattern)
    }
    # stacked period blocks: one stack per period position
    blocks = {}
    for pidx, kind in enumerate(cfg.block_pattern):
        per = [_block_init(kind, cfg, keys[next(ki)], dtype)
               for _ in range(cfg.n_periods)]
        blocks[f"b{pidx}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params["blocks"] = blocks
    params["suffix"] = {
        f"s{i}": _block_init(kind, cfg, keys[next(ki)], dtype)
        for i, kind in enumerate(cfg.remainder_pattern)
    }
    params["final_norm"] = norm_init(cfg.d_model, dtype)
    params["lm_head"] = dense_init(keys[next(ki)], cfg.d_model, cfg.vocab_size,
                                   dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed(params, cfg, batch):
    adt = dtype_of(cfg.activation_dtype)
    if cfg.embed_inputs:
        x = jnp.take(params["embed"]["embedding"], batch["tokens"], axis=0)
    else:
        x = batch["embeddings"]
    return constrain(x.astype(adt), "hidden")


def forward(params, cfg, batch) -> jax.Array:
    """Training/eval forward. batch: {"tokens"|"embeddings": (B, S[, D])}."""
    x = _embed(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s)

    for i, kind in enumerate(cfg.prefix_pattern):
        x = _block_forward(kind, params["prefix"][f"p{i}"], cfg, x, positions)

    if cfg.n_periods:
        def period_fn(x, period_params):
            for pidx, kind in enumerate(cfg.block_pattern):
                x = _block_forward(kind, period_params[f"b{pidx}"], cfg, x,
                                   positions)
            return constrain(x, "hidden"), None

        if cfg.remat == "full":
            period_fn = jax.checkpoint(period_fn)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(period_fn, x, params["blocks"])
        else:  # unrolled (exact per-layer HLO costs; dry-run cost variants)
            for i in range(cfg.n_periods):
                x, _ = period_fn(x, jax.tree.map(lambda a: a[i],
                                                 params["blocks"]))

    for i, kind in enumerate(cfg.remainder_pattern):
        x = _block_forward(kind, params["suffix"][f"s{i}"], cfg, x, positions)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = dense(params["lm_head"], x)
    return constrain(logits, "logits")


def loss_fn(params, cfg, batch) -> jax.Array:
    """Mean next-token cross-entropy (+ MoE aux loss if applicable)."""
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss


# ---------------------------------------------------------------------------
# decode state / prefill / decode_step
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, capacity: int, *,
                      kv_spec=None) -> Dict[str, Any]:
    """Zeroed decode state. ``kv_spec = {"page_size": ps, "max_pages": n}``
    selects the paged KV layout for every attention layer (pool leaves
    ``pages_*`` + per-row ``table``); None keeps the per-row ring."""
    adt = dtype_of(cfg.activation_dtype)

    def stack_cache(kind):
        one = _block_cache_init(kind, cfg, batch, capacity, adt, kv_spec)
        # broadcast (not zeros!) so sentinel values (e.g. pos = -1) survive
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape)
            .copy(), one)

    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "prefix": {f"p{i}": _block_cache_init(kind, cfg, batch, capacity,
                                              adt, kv_spec)
                   for i, kind in enumerate(cfg.prefix_pattern)},
        "blocks": {f"b{pidx}": stack_cache(kind)
                   for pidx, kind in enumerate(cfg.block_pattern)},
        "suffix": {f"s{i}": _block_cache_init(kind, cfg, batch, capacity,
                                              adt, kv_spec)
                   for i, kind in enumerate(cfg.remainder_pattern)},
    }


def prefill(params, cfg, batch, capacity: int) -> Tuple[jax.Array, Dict]:
    """Process a full prompt; return (last-position logits, decode state)."""
    x = _embed(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    adt = dtype_of(cfg.activation_dtype)
    state = init_decode_state(cfg, b, capacity)

    for i, kind in enumerate(cfg.prefix_pattern):
        x, state["prefix"][f"p{i}"] = _block_prefill(
            kind, params["prefix"][f"p{i}"], cfg, x, positions,
            state["prefix"][f"p{i}"])

    if cfg.n_periods:
        def period_fn(x, xs):
            period_params, cache_p = xs
            new_caches = {}
            for pidx, kind in enumerate(cfg.block_pattern):
                x, new_caches[f"b{pidx}"] = _block_prefill(
                    kind, period_params[f"b{pidx}"], cfg, x, positions,
                    cache_p[f"b{pidx}"])
            return constrain(x, "hidden"), new_caches

        if cfg.remat == "full":
            period_fn = jax.checkpoint(period_fn)
        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(period_fn, x,
                                         (params["blocks"], state["blocks"]))
        else:
            outs = []
            for i in range(cfg.n_periods):
                sl = lambda a: a[i]
                x, nc = period_fn(x, (jax.tree.map(sl, params["blocks"]),
                                      jax.tree.map(sl, state["blocks"])))
                outs.append(nc)
            new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        state["blocks"] = new_blocks

    for i, kind in enumerate(cfg.remainder_pattern):
        x, state["suffix"][f"s{i}"] = _block_prefill(
            kind, params["suffix"][f"s{i}"], cfg, x, positions,
            state["suffix"][f"s{i}"])

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = dense(params["lm_head"], x[:, -1])
    state["pos"] = jnp.full((b,), s, jnp.int32)
    return constrain(logits, "decode_logits"), state


def _block_prefill_chunk(kind: str, p, cfg, x, positions, lengths, valid,
                         cache):
    """Chunk forward that continues from and updates an existing cache.

    x: (B, L, D) right-padded; positions: (B, L) absolute per row;
    lengths: (B,) valid counts (0 = no-op row); valid: (B, L) bool.
    """
    if kind == "rwkv":
        h = rms_norm(p["time_norm"], x, cfg.norm_eps)
        y, (x_last, wkv) = rwkv_mod.rwkv_time_forward(
            p["time"], h, cfg.rwkv_head_dim,
            state=(cache["x_time"], cache["wkv"]), mask=valid)
        x = x + y
        h = rms_norm(p["chan_norm"], x, cfg.norm_eps)
        y, xc_last = rwkv_mod.rwkv_channel_forward(
            p["chan"], h, state=cache["x_chan"], mask=valid)
        return x + y, {"x_time": x_last, "wkv": wkv, "x_chan": xc_last}
    if kind.startswith("rglru"):
        h = rms_norm(p["rec_norm"], x, cfg.norm_eps)
        y, (h_last, conv_state) = rglru_mod.rglru_forward(
            p["rec"], h, cfg.rglru_blocks or cfg.n_heads,
            state=(cache["h"], cache["conv"]), mask=valid)
        x = x + y
        y = mlp_mod.mlp_forward(p["mlp"],
                                rms_norm(p["mlp_norm"], x, cfg.norm_eps),
                                cfg.mlp_type)
        return x + y, {"h": h_last, "conv": conv_state}
    # attention blocks
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    y, cache = attn.attention_prefill_chunk(
        p["attn"], cfg, cache, h, positions, lengths,
        window=_mixer_window(kind, cfg))
    x = x + y
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if "moe" in p:
        y = moe_mod.moe_forward(p["moe"], h, cfg.moe, cfg.mlp_type,
                                valid=valid)
    else:
        y = mlp_mod.mlp_forward(p["mlp"], h, cfg.mlp_type)
    return x + y, cache


def prefill_chunk(params, cfg, state, batch, lengths) -> Tuple[jax.Array, Dict]:
    """Padded-batch / chunked prefill through one fixed-shape compiled fn.

    batch: {"tokens": (B, L)} right-padded to the bucket length L;
    lengths: (B,) int32 — row r consumes positions ``state['pos'][r] ..
    state['pos'][r]+lengths[r]-1`` of its prompt (lengths[r]=0 makes the row
    a complete no-op, so free/decoding rows ride along untouched).

    One compiled function serves every (admission batch, chunk offset) at a
    given bucket L — the serving engine's prefill compile cache becomes
    O(log capacity) instead of one entry per distinct prompt length. A long
    prompt is fed through repeated calls (cache write offset = state pos),
    interleaving with decode chunks instead of blocking them.

    Returns (logits at each row's last valid token (B, V), updated state).
    Logits of rows with lengths[r] == 0 are garbage — callers ignore them.
    """
    x = _embed(params, cfg, batch)
    b, L, _ = x.shape
    pos0 = state["pos"]
    positions = pos0[:, None] + jnp.arange(L)[None, :]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    new_state = {"pos": pos0 + lengths.astype(jnp.int32),
                 "prefix": {}, "blocks": None, "suffix": {}}

    for i, kind in enumerate(cfg.prefix_pattern):
        x, new_state["prefix"][f"p{i}"] = _block_prefill_chunk(
            kind, params["prefix"][f"p{i}"], cfg, x, positions, lengths,
            valid, state["prefix"][f"p{i}"])

    if cfg.n_periods:
        def period_fn(x, xs):
            period_params, cache_p = xs
            new_caches = {}
            for pidx, kind in enumerate(cfg.block_pattern):
                x, new_caches[f"b{pidx}"] = _block_prefill_chunk(
                    kind, period_params[f"b{pidx}"], cfg, x, positions,
                    lengths, valid, cache_p[f"b{pidx}"])
            return constrain(x, "hidden"), new_caches

        if cfg.remat == "full":
            period_fn = jax.checkpoint(period_fn)
        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(period_fn, x,
                                         (params["blocks"], state["blocks"]))
        else:
            outs = []
            for i in range(cfg.n_periods):
                sl = lambda a: a[i]
                x, nc = period_fn(x, (jax.tree.map(sl, params["blocks"]),
                                      jax.tree.map(sl, state["blocks"])))
                outs.append(nc)
            new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_state["blocks"] = new_blocks

    for i, kind in enumerate(cfg.remainder_pattern):
        x, new_state["suffix"][f"s{i}"] = _block_prefill_chunk(
            kind, params["suffix"][f"s{i}"], cfg, x, positions, lengths,
            valid, state["suffix"][f"s{i}"])

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    idx = jnp.maximum(lengths - 1, 0).astype(jnp.int32)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = dense(params["lm_head"], x_last)
    return constrain(logits, "decode_logits"), new_state


def decode_step(params, cfg, state, tokens, active=None) -> Tuple[jax.Array, Dict]:
    """One decode step. tokens: (B,) int32 (or (B, D) embeddings if stub).

    active (B,) bool: rows with active=False are frozen — position and every
    cache leaf pass through unchanged, so a decode dispatch can share the
    batch state with rows that are mid-(chunked-)prefill or already free.
    """
    adt = dtype_of(cfg.activation_dtype)
    if cfg.embed_inputs:
        x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    else:
        x = tokens
    x = x.astype(adt)
    pos = state["pos"]
    new_pos = pos + (active.astype(jnp.int32) if active is not None else 1)
    new_state = {"pos": new_pos, "prefix": {}, "blocks": None, "suffix": {}}

    for i, kind in enumerate(cfg.prefix_pattern):
        x, new_state["prefix"][f"p{i}"] = _block_decode(
            kind, params["prefix"][f"p{i}"], cfg, state["prefix"][f"p{i}"],
            x, pos, active)

    if cfg.n_periods:
        def period_fn(x, xs):
            period_params, cache_p = xs
            new_caches = {}
            for pidx, kind in enumerate(cfg.block_pattern):
                x, new_caches[f"b{pidx}"] = _block_decode(
                    kind, period_params[f"b{pidx}"], cfg, cache_p[f"b{pidx}"],
                    x, pos, active)
            return x, new_caches

        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(period_fn, x,
                                         (params["blocks"], state["blocks"]))
        else:
            outs = []
            for i in range(cfg.n_periods):
                sl = lambda a: a[i]
                x, nc = period_fn(x, (jax.tree.map(sl, params["blocks"]),
                                      jax.tree.map(sl, state["blocks"])))
                outs.append(nc)
            new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_state["blocks"] = new_blocks

    for i, kind in enumerate(cfg.remainder_pattern):
        x, new_state["suffix"][f"s{i}"] = _block_decode(
            kind, params["suffix"][f"s{i}"], cfg, state["suffix"][f"s{i}"],
            x, pos, active)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = dense(params["lm_head"], x)
    return constrain(logits, "decode_logits"), new_state
