"""Prefill/admission benchmark: TTFT + mixed throughput on a bursty trace,
plus the long-context attention sweep (PR 5).

Compares the two admission schedulers end to end on the same arrival traces
(fp32 and PTQTP params), checking outputs stay bit-identical at temp 0:

  * **serial** — the seeded PR-1 path (`SerialAdmitEngine`): each arriving
    request is prefilled alone through a jit cached per *exact* prompt
    length, then merged into its slot; the decode fleet stalls while a
    burst's prompts are consumed one by one.
  * **bucketed** — the chunked scheduler (`ServingEngine`): every step all
    free slots admit at once, all mid-prompt rows advance one power-of-two
    prefill chunk in a single fixed-shape dispatch, and long prompts
    interleave with (shortened) decode chunks instead of blocking them.
    Prefill compiles are O(log prefill_chunk), recorded via
    `compile_stats()`.

Both engines get the same `warmup()` before measurement. The headline trace
is **bursty with novel prompt lengths** (every wave's lengths are lengths
neither engine has served before — the realistic regime, since production
prompt lengths are effectively arbitrary): the serial engine's per-length
jit cache forces an XLA compile on the admission path, which is precisely
the TTFT pathology length-bucketing removes. A **steady** pass (identical
trace replayed, so even the serial engine's cache is hot) is also reported:
at smoke-model scale, where a whole prefill costs less than one dispatch,
serial admission stays competitive there — the honest baseline; the
bucketed win in steady state is the O(log) compile bound plus batched
admission, not raw dispatch latency.

TTFT = submit() → first generated token, per request; mixed tok/s counts
every generated token over the wall clock of the whole trace.

The **long-context sweep** (``longctx*`` keys) measures the regime the
flash chunk-attention kernel exists for: capacity ≫ prefill_chunk, where
the per-chunk (L, cap + L) score block and the full-ring int8→f32 dequant
dominate the materialized path. Same engine, same int8 ring, same trace —
only ``attn_backend`` differs (``stream`` = online-softmax tiles vs
``materialized`` = the pre-PR-5 block), recording TTFT, mixed tok/s, the
analytic peak attention score-block bytes per dispatch
(``tracked_block_bytes``), and total resident serving state
(``ServingEngine.memory_stats``, pre-unpacked decode planes included).

``PYTHONPATH=src python benchmarks/bench_prefill.py [--quick]``

Writes benchmarks/results/BENCH_prefill.json and mirrors it to
BENCH_prefill.json at the repo root (the trajectory point ROADMAP.md quotes).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import save_result
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import (EngineConfig, SamplingParams, SerialAdmitEngine,
                           ServingEngine)

ROOT = Path(__file__).resolve().parents[1]


def _trace(quick: bool, shift: int):
    """Bursty arrival trace: [(engine_step, prompt), ...].

    Waves land while the previous wave is still decoding; each wave mixes
    short prompts with one long prompt (longer than prefill_chunk, so the
    bucketed engine must chunk it across steps). `shift` offsets every
    length so each rep presents prompt lengths no engine has seen before
    (the bands are spaced so shifted reps never collide).
    """
    rng = np.random.default_rng(shift)
    mk = lambda n: rng.integers(1, 500, size=n).tolist()
    if quick:
        waves = [(0, [3, 5, 4]), (2, [40, 6, 7]), (4, [30, 9])]
    else:
        waves = [(0, [3, 5, 4, 11]), (3, [90, 6, 7, 9]),
                 (6, [48, 10, 12]), (9, [8, 70, 13, 14])]
    return [(step, mk(n + shift)) for step, lens in waves for n in lens]


def _drive(eng, trace, max_new):
    """Submit per the trace's step schedule, step until drained."""
    arrivals = list(trace)
    done, it, uid = [], 0, 0
    t0 = time.perf_counter()
    while arrivals or eng.queue or any(s is not None for s in eng.slots):
        while arrivals and arrivals[0][0] <= it:
            _, prompt = arrivals.pop(0)
            eng.submit(prompt, SamplingParams(max_new_tokens=max_new),
                       uid=uid)
            uid += 1
        done.extend(eng.step())
        it += 1
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.output) for r in done)
    ttft = [r.t_first - r.t_submit for r in done]
    outs = {r.uid: tuple(r.output) for r in done}
    return {"tokps": n_tok / wall, "ttft_mean_ms": 1e3 * float(np.mean(ttft)),
            "ttft_p90_ms": 1e3 * float(np.quantile(ttft, 0.9)),
            "outputs": outs}


def _bench(rows, log, quick):
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    max_new = 12 if quick else 24
    reps = 2 if quick else 3
    ecfg = EngineConfig(max_slots=4, capacity=128, decode_chunk=8,
                        prefill_chunk=16)
    variants = (("serial", SerialAdmitEngine), ("bucketed", ServingEngine))

    for tag, p in (("fp32", params), ("ptqtp", qparams)):
        engines = {name: cls(p, cfg, ecfg) for name, cls in variants}
        for eng in engines.values():
            eng.warmup()
        # --- bursty, novel lengths (headline): serial compiles on admission
        cold = {name: [] for name, _ in variants}
        identical = True
        for rep in range(reps):
            trace = _trace(quick, shift=17 * rep)
            rep_out = {}
            for name, _ in variants:
                r = _drive(engines[name], trace, max_new)
                cold[name].append(r)
                rep_out[name] = r.pop("outputs")
            identical &= rep_out["serial"] == rep_out["bucketed"]
        for name, _ in variants:
            rows[f"{tag}_ttft_mean_ms_{name}"] = float(
                np.mean([r["ttft_mean_ms"] for r in cold[name]]))
            rows[f"{tag}_ttft_p90_ms_{name}"] = float(
                np.mean([r["ttft_p90_ms"] for r in cold[name]]))
            rows[f"{tag}_mixed_tokps_{name}"] = float(
                np.mean([r["tokps"] for r in cold[name]]))
            log(f"bench_prefill,{tag}_ttft_mean_ms_{name},"
                f"{rows[f'{tag}_ttft_mean_ms_{name}']:.2f}")
        rows[f"{tag}_ttft_speedup"] = (rows[f"{tag}_ttft_mean_ms_serial"]
                                       / rows[f"{tag}_ttft_mean_ms_bucketed"])
        rows[f"{tag}_mixed_tokps_speedup"] = (
            rows[f"{tag}_mixed_tokps_bucketed"]
            / rows[f"{tag}_mixed_tokps_serial"])
        rows[f"{tag}_outputs_identical"] = identical
        log(f"bench_prefill,{tag}_ttft_speedup,"
            f"{rows[f'{tag}_ttft_speedup']:.2f}")
        # --- steady state: replay a now-hot trace (serial cache warmed too)
        steady_trace = _trace(quick, shift=0)
        for name, _ in variants:
            _drive(engines[name], steady_trace, max_new)  # heat
            r = _drive(engines[name], steady_trace, max_new)
            rows[f"{tag}_steady_ttft_mean_ms_{name}"] = r["ttft_mean_ms"]
            rows[f"{tag}_steady_tokps_{name}"] = r["tokps"]
        rows[f"{tag}_steady_ttft_ratio"] = (
            rows[f"{tag}_steady_ttft_mean_ms_serial"]
            / rows[f"{tag}_steady_ttft_mean_ms_bucketed"])
        # --- compile accounting
        for name, _ in variants:
            stats = engines[name].compile_stats()
            rows[f"{tag}_prefill_compiles_{name}"] = stats["n_prefill_compiles"]
            log(f"bench_prefill,{tag}_prefill_compiles_{name},"
                f"{stats['n_prefill_compiles']}")
        rows[f"{tag}_prefill_bucket_bound"] = (
            engines["bucketed"].compile_stats()["prefill_bucket_bound"])
    rows["n_requests_per_trace"] = len(_trace(quick, 0))
    rows["reps"] = reps
    rows["max_new_tokens"] = max_new
    rows["prefill_chunk"] = ecfg.prefill_chunk
    rows["capacity"] = ecfg.capacity


def _bench_longctx(rows, log, quick):
    """capacity ≫ prefill_chunk: stream vs materialized attention backend."""
    from repro.kernels.chunk_attention.ops import (_select_tile,
                                                   tracked_block_bytes)

    base = configs.get_smoke_config("qwen2-1.5b").scaled(
        kv_cache_dtype="int8")
    params = init_params(base, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))
    caps = (2048, 8192) if quick else (2048, 8192, 16384)
    slots, L, max_new = 4, 16, 4
    prompt_len = 64 if quick else 128
    # one wave = one request per slot: TTFT is pure prefill time, no
    # queue-wait term common to both backends diluting the ratio
    n_req = slots
    kv, g = base.n_kv_heads, base.n_heads // base.n_kv_heads
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 500, size=prompt_len).tolist()
               for _ in range(n_req)]

    backends = ("materialized", "stream")
    reps = 3 if quick else 5
    for cap in caps:
        assert cap >= 8 * L  # the acceptance regime: capacity >= 8x chunk
        trace = [(0, p) for p in prompts]
        engines = {}
        for backend in backends:
            engines[backend] = ServingEngine(qparams, base, EngineConfig(
                max_slots=slots, capacity=cap, prefill_chunk=L,
                decode_chunk=4, attn_backend=backend))
            _drive(engines[backend], trace, max_new)  # heat: compile the path
        # interleave backends per rep and keep each backend's best rep —
        # ambient load on a shared container dwarfs the effect otherwise
        runs = {b: [] for b in backends}
        outs = {}
        for _ in range(reps):
            for backend in backends:
                r = _drive(engines[backend], trace, max_new)
                outs[backend] = r.pop("outputs")
                runs[backend].append(r)
        for backend in backends:
            rows[f"longctx{cap}_ttft_mean_ms_{backend}"] = min(
                r["ttft_mean_ms"] for r in runs[backend])
            rows[f"longctx{cap}_tokps_{backend}"] = max(
                r["tokps"] for r in runs[backend])
            rows[f"longctx{cap}_attn_block_bytes_{backend}"] = (
                tracked_block_bytes(slots, kv, g, L, cap, backend=backend))
            log(f"bench_prefill,longctx{cap}_ttft_mean_ms_{backend},"
                f"{rows[f'longctx{cap}_ttft_mean_ms_{backend}']:.2f}")
        mem = engines["stream"].memory_stats()    # shape-only: same per cap
        rows[f"longctx{cap}_resident_state_mb"] = (
            mem["resident_total_bytes"] / 1e6)
        rows[f"longctx{cap}_ttft_speedup"] = (
            rows[f"longctx{cap}_ttft_mean_ms_materialized"]
            / rows[f"longctx{cap}_ttft_mean_ms_stream"])
        rows[f"longctx{cap}_tokps_speedup"] = (
            rows[f"longctx{cap}_tokps_stream"]
            / rows[f"longctx{cap}_tokps_materialized"])
        rows[f"longctx{cap}_attn_bytes_ratio"] = (
            rows[f"longctx{cap}_attn_block_bytes_materialized"]
            / rows[f"longctx{cap}_attn_block_bytes_stream"])
        rows[f"longctx{cap}_outputs_identical"] = (
            outs["materialized"] == outs["stream"])
        log(f"bench_prefill,longctx{cap}_ttft_speedup,"
            f"{rows[f'longctx{cap}_ttft_speedup']:.2f}")
    top = max(caps)
    rows["longctx_capacities"] = list(caps)
    rows["longctx_prefill_chunk"] = L
    rows["longctx_tile"] = _select_tile(top, L)
    rows["headline_longctx_ttft_speedup"] = rows[f"longctx{top}_ttft_speedup"]


def _bench_crossover(rows, log, quick):
    """The smoke-scale chunked-vs-serial crossover, recorded as a number.

    Steady state (both engines hot for the exact length), one request at a
    time: the serial engine prefills the whole prompt in one exact-length
    dispatch, the bucketed engine walks it in prefill_chunk pieces.
    ``crossover_prompt_len`` is the prompt length where their TTFTs cross:
    the zero of a least-squares line through (length, serial - bucketed)
    — single-point sign changes are dispatch noise on a shared box, the
    fitted trend is not — clamped to -1 when the fit puts the crossing
    outside the sweep (one engine wins the whole regime).
    ``crossover_direction`` says who takes over past it. At smoke scale
    the measured shape is: chunked wins short prompts (the serial path's
    per-request admission overhead dominates) and serial overtakes once
    its single large dispatch amortizes that against many chunk
    dispatches — the PR-2 steady-state regression, now a number. On real
    hardware, where compute dwarfs dispatch, the direction inverts.
    """
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    lens = (8, 24, 48, 96) if quick else (8, 16, 32, 48, 64, 96, 128)
    reps = 3 if quick else 5
    max_new = 4
    ecfg = EngineConfig(max_slots=1, capacity=256, decode_chunk=4,
                        prefill_chunk=16)
    rng = np.random.default_rng(13)
    engines = {"serial": SerialAdmitEngine(params, cfg, ecfg),
               "bucketed": ServingEngine(params, cfg, ecfg)}
    diffs = []
    for n in lens:
        trace = [(0, rng.integers(1, 500, size=n).tolist())]
        t = {}
        for name, eng in engines.items():
            _drive(eng, trace, max_new)  # heat: compile this exact length
            _drive(eng, trace, max_new)
            t[name] = min(_drive(eng, trace, max_new)["ttft_mean_ms"]
                          for _ in range(reps))
            rows[f"crossover_ttft_ms_{name}_len{n}"] = t[name]
        diffs.append((n, t["serial"] - t["bucketed"]))
        log(f"bench_prefill,crossover_len{n}_serial_minus_bucketed_ms,"
            f"{diffs[-1][1]:.3f}")
    xs = np.array([n for n, _ in diffs], np.float64)
    ds = np.array([d for _, d in diffs], np.float64)
    slope, intercept = np.polyfit(xs, ds, 1)
    cross, direction = -1.0, "none"
    if slope != 0.0:
        zero = -intercept / slope
        if lens[0] <= zero <= lens[-1]:
            cross = float(zero)
            direction = ("chunked_then_serial" if slope < 0
                         else "serial_then_chunked")
    rows["crossover_direction"] = direction
    rows["crossover_chunked_wins_shortest"] = bool(diffs[0][1] >= 0)
    rows["crossover_fit_slope_ms_per_tok"] = float(slope)
    rows["crossover_sweep_lens"] = list(lens)
    rows["crossover_sweep_max"] = lens[-1]
    rows["crossover_prefill_chunk"] = ecfg.prefill_chunk
    rows["crossover_prompt_len"] = float(cross)
    log(f"bench_prefill,crossover_prompt_len,{cross:.1f}")


def run(log=print, quick=False):
    rows = {}
    _bench(rows, log, quick)
    _bench_longctx(rows, log, quick)
    _bench_crossover(rows, log, quick)
    # headline = the deployment config (PTQTP serving is the repo's story)
    rows["headline_ttft_speedup"] = rows["ptqtp_ttft_speedup"]
    rows["headline_mixed_tokps_speedup"] = rows["ptqtp_mixed_tokps_speedup"]
    log(f"bench_prefill,headline_ttft_speedup,"
        f"{rows['headline_ttft_speedup']:.2f}")
    save_result("BENCH_prefill", rows)
    (ROOT / "BENCH_prefill.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
