"""HTTP serving frontend benchmark: wire-level bit-identity, sustained
req/s + TTFT under the seeded Poisson trace vs the cooperative driver,
and two-tenant DRR fairness under a greedy flood.

Three sections, one JSON, all over real loopback sockets:

  * **identity** — the same seeded prompt set served three ways at
    temperature 0: cooperative in-process ``submit()`` (the oracle), HTTP
    non-streaming, HTTP SSE streaming. Tokens must be bit-identical
    (asserted): the driver thread, the fair scheduler, and the HTTP/SSE
    layers may change *when* a request runs, never *what* it generates.
  * **throughput** — the shared seeded Poisson trace
    (``benchmarks.common``) replayed over HTTP by concurrent client
    threads (one connection per request, SSE consumption, wall-clock
    TTFT measured at the client) vs the identical trace driven
    cooperatively in-process: sustained req/s, p50/p99 TTFT, and the
    HTTP-over-cooperative ratios. The wire path pays sockets + JSON +
    thread hops; this section is what keeps that tax measured.
  * **fairness** — a greedy tenant floods a burst while a polite tenant
    trickles in behind it, run twice: per-tenant DRR (quantum small
    enough to interleave) vs everything in one FIFO queue. Records the
    polite tenant's p99 TTFT both ways and asserts DRR keeps it below
    the FIFO value — starvation-freedom: a flood bounds only its own
    latency.

``PYTHONPATH=src python benchmarks/bench_http.py [--quick]``

Writes benchmarks/results/BENCH_http.json and mirrors it to
BENCH_http.json at the repo root.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.request
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import (drive_poisson, poisson_schedule, save_result,
                               trace_prompts)
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import (EngineConfig, SamplingParams, ServingEngine)
from repro.serving.frontend import (EngineDriver, FairScheduler,
                                    ThreadedHttpServer)

ROOT = Path(__file__).resolve().parents[1]

ECFG = dict(max_slots=2, capacity=64, decode_chunk=4, prefill_chunk=16)


# ---------------------------------------------------------------------------
# minimal stdlib HTTP client (what the bench "users" run)
# ---------------------------------------------------------------------------

def _request(base, prompt, *, max_new, seed, tenant="", stream=True,
             timeout=300.0):
    """One completion over the wire. Returns a dict with the token ids,
    the terminal result, and client-side wall timings (t0 → first token
    = the TTFT a real user would see, including connect + serialize)."""
    body = json.dumps({
        "prompt": list(prompt), "stream": stream, "max_new_tokens": max_new,
        "seed": seed, "tenant": tenant}).encode()
    req = urllib.request.Request(
        base + "/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    tokens, result, t_first = [], None, None
    t0 = time.perf_counter()
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            if stream:
                for raw in resp:
                    line = raw.decode("utf-8").strip()
                    if not line.startswith("data: ") \
                            or line == "data: [DONE]":
                        continue
                    ev = json.loads(line[len("data: "):])
                    if "token" in ev:
                        if t_first is None:
                            t_first = time.perf_counter()
                        tokens.append(ev["token"])
                    else:
                        result = ev
            else:
                result = json.loads(resp.read())
                tokens = result["tokens"]
                t_first = time.perf_counter()
            status = resp.status
    except urllib.error.HTTPError as e:  # 429/504/500 mapped outcomes
        result = json.loads(e.read())
        status = e.code
    t_done = time.perf_counter()
    return {
        "tokens": tuple(tokens), "result": result, "status": status,
        "ttft_s": (t_first - t0) if t_first is not None else 0.0,
        "wall_s": t_done - t0,
    }


def _serve(eng, **fair_kw):
    """Fresh driver + HTTP server over a (pre-warmed) engine — one per
    section, so scheduler state never leaks between measurements while
    the engine's compile caches stay hot across them."""
    driver = EngineDriver(eng, fairness=FairScheduler(**fair_kw)).start()
    srv = ThreadedHttpServer(driver).start()
    return driver, srv, f"http://{srv.host}:{srv.port}"


def _shutdown(driver, srv):
    srv.stop()
    assert driver.drain(timeout=300.0), "driver failed to drain"
    driver.close()


# ---------------------------------------------------------------------------
# identity: wire == in-process, bit for bit
# ---------------------------------------------------------------------------

def _bench_identity(rows, log, ref_eng, http_eng, quick):
    n_req = 4 if quick else 8
    max_new = 4 if quick else 8
    prompts = trace_prompts(n_req, quick, seed=13)

    refs = [ref_eng.submit(p, SamplingParams(max_new_tokens=max_new, seed=i))
            for i, p in enumerate(prompts)]
    ref_eng.run()
    ref_tokens = [tuple(h.output) for h in refs]

    driver, srv, base = _serve(http_eng)
    unary = [_request(base, p, max_new=max_new, seed=i, stream=False)
             for i, p in enumerate(prompts)]
    sse = [_request(base, p, max_new=max_new, seed=i, stream=True)
           for i, p in enumerate(prompts)]
    _shutdown(driver, srv)

    unary_ok = all(r["tokens"] == t for r, t in zip(unary, ref_tokens))
    sse_ok = all(r["tokens"] == t for r, t in zip(sse, ref_tokens))
    assert unary_ok and sse_ok, "HTTP tokens diverge from in-process submit"
    rows["identity_n_requests"] = n_req
    rows["identity_unary_bit_identical"] = unary_ok
    rows["identity_sse_bit_identical"] = sse_ok
    for k in ("identity_unary_bit_identical", "identity_sse_bit_identical"):
        log(f"bench_http,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# throughput: the seeded Poisson trace over sockets vs in-process
# ---------------------------------------------------------------------------

def _bench_throughput(rows, log, ref_eng, http_eng, quick):
    n_req = 10 if quick else 32
    max_new = 4 if quick else 8
    lam = 3.0
    tick_s = 0.05
    prompts = trace_prompts(n_req, quick, seed=7)

    # cooperative baseline: same prompts, same Poisson seed, driven
    # in-process (engine-clock TTFTs)
    t0 = time.perf_counter()
    handles, _depth = drive_poisson(ref_eng, prompts, max_new, lam, seed=11)
    coop_wall = time.perf_counter() - t0
    coop_ttft = [h.t_first - h.t_submit for h in handles if h.t_first]

    # HTTP replay: the same arrival counts, one wall tick per engine step
    # slot, each request on its own thread + connection, SSE-consumed
    driver, srv, base = _serve(http_eng)
    outs = [None] * n_req
    threads = []

    def fire(i):
        outs[i] = _request(base, prompts[i], max_new=max_new, seed=i)

    t0 = time.perf_counter()
    i = 0
    for tick, count in enumerate(poisson_schedule(n_req, lam, seed=11)):
        lag = t0 + tick * tick_s - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        for _ in range(count):
            th = threading.Thread(target=fire, args=(i,))
            th.start()
            threads.append(th)
            i += 1
    for th in threads:
        th.join(timeout=600.0)
    http_wall = time.perf_counter() - t0
    _shutdown(driver, srv)

    assert all(o is not None for o in outs), "HTTP client thread hung"
    done = [o for o in outs if o["result"] is not None
            and o["result"].get("finish_reason") == "length"]
    assert len(done) == n_req, [o["result"] for o in outs]
    http_ttft = [o["ttft_s"] for o in outs if o["ttft_s"] > 0]

    def pct(xs, q):
        return 1e3 * float(np.percentile(xs, q)) if xs else 0.0

    rows["throughput_n_requests"] = n_req
    rows["throughput_lam_per_tick"] = lam
    rows["throughput_tick_s"] = tick_s
    rows["http_req_per_s"] = n_req / http_wall
    rows["coop_req_per_s"] = n_req / coop_wall
    rows["http_p50_ttft_ms"] = pct(http_ttft, 50)
    rows["http_p99_ttft_ms"] = pct(http_ttft, 99)
    rows["coop_p50_ttft_ms"] = pct(coop_ttft, 50)
    rows["coop_p99_ttft_ms"] = pct(coop_ttft, 99)
    rows["http_over_coop_p99_ttft"] = (rows["http_p99_ttft_ms"]
                                       / max(rows["coop_p99_ttft_ms"], 1e-9))
    for k in ("http_req_per_s", "coop_req_per_s", "http_p50_ttft_ms",
              "http_p99_ttft_ms", "coop_p50_ttft_ms", "coop_p99_ttft_ms"):
        log(f"bench_http,{k},{rows[k]:.3f}")


# ---------------------------------------------------------------------------
# fairness: greedy flood vs polite trickle, DRR vs one FIFO queue
# ---------------------------------------------------------------------------

def _run_flood(http_eng, quick, *, fair):
    n_flood = 8 if quick else 16
    n_polite = 3 if quick else 4
    max_new = 24 if quick else 48
    rng = np.random.default_rng(23)
    flood_prompts = [rng.integers(1, 500, size=8).tolist()
                     for _ in range(n_flood)]
    polite_prompts = [rng.integers(1, 500, size=8).tolist()
                      for _ in range(n_polite)]
    # under `fair` the two tenants get separate DRR queues; the baseline
    # collapses everyone into the anonymous tenant = one FIFO queue
    g_tenant, p_tenant = ("greedy", "polite") if fair else ("", "")
    driver, srv, base = _serve(http_eng, quantum=64)

    outs_flood = [None] * n_flood
    outs_polite = [None] * n_polite
    threads = []

    def fire(outs, i, prompt, tenant, seed):
        outs[i] = _request(base, prompt, max_new=max_new, seed=seed,
                           tenant=tenant)

    # the greedy tenant dumps its whole burst first ...
    for i, p in enumerate(flood_prompts):
        th = threading.Thread(target=fire,
                              args=(outs_flood, i, p, g_tenant, i))
        th.start()
        threads.append(th)
    time.sleep(0.3)  # ... the polite tenant arrives strictly behind it
    for i, p in enumerate(polite_prompts):
        th = threading.Thread(target=fire,
                              args=(outs_polite, i, p, p_tenant, 100 + i))
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=600.0)
    _shutdown(driver, srv)
    assert all(o is not None for o in outs_flood + outs_polite)
    assert all(o["result"].get("finish_reason") == "length"
               for o in outs_flood + outs_polite)
    return {
        "polite_ttft_ms": [1e3 * o["ttft_s"] for o in outs_polite],
        "flood_ttft_ms": [1e3 * o["ttft_s"] for o in outs_flood],
        "n_flood": n_flood, "n_polite": n_polite,
    }


def _bench_fairness(rows, log, http_eng, quick):
    drr = _run_flood(http_eng, quick, fair=True)
    fifo = _run_flood(http_eng, quick, fair=False)
    p99 = lambda xs: float(np.percentile(xs, 99))
    rows["fairness_n_flood"] = drr["n_flood"]
    rows["fairness_n_polite"] = drr["n_polite"]
    rows["fairness_polite_p99_ttft_ms_drr"] = p99(drr["polite_ttft_ms"])
    rows["fairness_polite_p99_ttft_ms_fifo"] = p99(fifo["polite_ttft_ms"])
    rows["fairness_flood_p99_ttft_ms_drr"] = p99(drr["flood_ttft_ms"])
    rows["fairness_flood_p99_ttft_ms_fifo"] = p99(fifo["flood_ttft_ms"])
    rows["fairness_polite_speedup"] = (
        rows["fairness_polite_p99_ttft_ms_fifo"]
        / max(rows["fairness_polite_p99_ttft_ms_drr"], 1e-9))
    # starvation-freedom: behind a flood, DRR must serve the polite tenant
    # no later than the single FIFO queue would (in practice: much earlier,
    # because it only waits out the flood's in-flight slots, not its queue)
    assert rows["fairness_polite_p99_ttft_ms_drr"] \
        <= rows["fairness_polite_p99_ttft_ms_fifo"], \
        "DRR starved the polite tenant worse than FIFO"
    for k in ("fairness_polite_p99_ttft_ms_drr",
              "fairness_polite_p99_ttft_ms_fifo",
              "fairness_polite_speedup"):
        log(f"bench_http,{k},{rows[k]:.3f}")


def run(log=print, quick=False):
    rows = {}
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    # two engines, warmed once: compile caches are per-engine, so every
    # measured section reuses these (fresh drivers per section) and no
    # TTFT pays jit compile time
    ref_eng = ServingEngine(qparams, cfg, EngineConfig(**ECFG))
    ref_eng.warmup()
    http_eng = ServingEngine(qparams, cfg, EngineConfig(**ECFG))
    http_eng.warmup()

    _bench_identity(rows, log, ref_eng, http_eng, quick)
    _bench_throughput(rows, log, ref_eng, http_eng, quick)
    _bench_fairness(rows, log, http_eng, quick)
    rows["headline_http_req_per_s"] = rows["http_req_per_s"]
    rows["headline_fairness_polite_speedup"] = rows["fairness_polite_speedup"]
    save_result("BENCH_http", rows)
    (ROOT / "BENCH_http.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
