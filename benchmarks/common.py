"""Shared benchmark utilities: tiny-LM training, PPL evaluation, timers."""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig

RESULTS = Path(__file__).resolve().parent / "results"


def save_result(name: str, payload: Dict[str, Any]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / f"{name}.json", "w") as f:
        json.dump(payload, f, indent=1, default=float)


def load_result(name: str) -> Optional[Dict[str, Any]]:
    p = RESULTS / f"{name}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


# ---------------------------------------------------------------------------
# the in-miniature evaluation model (DESIGN.md §8.2): a byte LM trained on the
# synthetic corpus; PPL before/after quantization is the Table-1 analogue.
# ---------------------------------------------------------------------------

def eval_model_config(d_model=256, n_layers=4, d_ff=1024, vocab=259):
    base = configs.get_config("qwen2-1.5b")
    return base.scaled(
        name="bench-lm", n_layers=n_layers, d_model=d_model, n_heads=4,
        n_kv_heads=2, d_ff=d_ff, vocab_size=vocab,
        param_dtype="float32", activation_dtype="float32", remat="none",
        q_chunk=64,
    )


def train_eval_model(steps=300, seq_len=128, batch=16, seed=0,
                     cfg=None, log=lambda *_: None):
    cfg = cfg or eval_model_config()
    t = Trainer(cfg, AdamW(lr=cosine_schedule(3e-3, warmup=30, total=steps)),
                DataConfig(seq_len=seq_len, global_batch=batch, seed=seed),
                TrainerConfig(total_steps=steps, log_interval=100),
                log_fn=log)
    state = t.fit()
    return cfg, state["params"], t.history


_PPL_CACHE: Dict[str, Any] = {}


def trained_eval_model(steps=300):
    """Trained tiny LM shared across benchmarks — memoized in-process AND
    on disk (benchmarks/results/eval_model/), so each bench process pays
    zero training cost after the first."""
    from repro.runtime.checkpoint import (load_checkpoint, save_checkpoint)

    key = f"steps{steps}"
    if key in _PPL_CACHE:
        return _PPL_CACHE[key]
    cfg = eval_model_config()
    ckpt_dir = RESULTS / "eval_model" / key
    try:
        _, tree, _ = load_checkpoint(ckpt_dir)
        params = jax.tree.map(jnp.asarray, tree["params"])
        hist = tree.get("history", None)
    except (FileNotFoundError, KeyError):
        cfg, params, history = train_eval_model(steps=steps, cfg=cfg)
        hist = {"loss": np.asarray([h["loss"] for h in history],
                                   np.float32)}
        save_checkpoint(ckpt_dir, steps, {"params": params,
                                          "history": hist})
    _PPL_CACHE[key] = (cfg, params, hist)
    return _PPL_CACHE[key]


def perplexity(params, cfg, *, seq_len=128, n_batches=8, batch=16,
               seed=123) -> float:
    """Byte-level perplexity on held-out synthetic text."""
    from repro.models import loss_fn

    dcfg = DataConfig(seq_len=seq_len, global_batch=batch, seed=seed)
    from repro.data.pipeline import ShardedLoader

    loader = ShardedLoader(dcfg)
    loss_j = jax.jit(lambda p, b: loss_fn(p, cfg, b))
    losses = []
    for step in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        losses.append(float(loss_j(params, b)))
    return float(np.exp(np.mean(losses)))


def quantize_params_with(params, method: Callable[[jax.Array], jax.Array]):
    """Apply a (w)->w_hat matrix quantizer to every linear kernel (dense
    fake-quant path used for baseline comparisons)."""

    def walk(node, path=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if (path.endswith("kernel") and getattr(node, "ndim", 0) == 2
                and "router" not in path and "norm" not in path):
            return method(node).astype(node.dtype)
        return node

    return walk(params)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0


# ---------------------------------------------------------------------------
# seeded load traces (shared by bench_serving_api and bench_http so the
# in-process and over-the-wire runs replay the *same* offered workload)
# ---------------------------------------------------------------------------

def trace_prompts(n, quick, seed=0):
    """Seeded synthetic prompt set: n token-id lists with novel lengths
    (2..40, or 2..12 under --quick) drawn from a 500-token vocabulary."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, 12 if quick else 40, size=n)
    return [rng.integers(1, 500, size=int(l)).tolist() for l in lens]


def poisson_schedule(n, lam, seed):
    """Poisson arrival counts per tick: how many of the n requests to
    submit at each engine step (or wall tick, over HTTP). Sums to n."""
    rng = np.random.default_rng(seed)
    counts, left = [], n
    while left > 0:
        k = min(int(rng.poisson(lam)), left)
        counts.append(k)
        left -= k
    return counts


def drive_poisson(eng, prompts, max_new, lam, seed, params_fn=None):
    """Offer ``prompts`` to an engine as a Poisson arrival trace (~``lam``
    submits per engine step) and drive to drain. Returns (handles, max
    queue depth). ``params_fn(i)`` overrides the per-request
    SamplingParams (default: greedy, max_new, seed=i)."""
    from repro.serving import SamplingParams

    rng = np.random.default_rng(seed)
    handles, i, max_depth = [], 0, 0
    while i < len(prompts) or eng.queue \
            or any(s is not None for s in eng.slots):
        for _ in range(int(rng.poisson(lam))):
            if i >= len(prompts):
                break
            sp = params_fn(i) if params_fn is not None else SamplingParams(
                max_new_tokens=max_new, seed=i)
            handles.append(eng.submit(prompts[i], sp))
            i += 1
        eng.step()
        max_depth = max(max_depth, len(eng.queue))
    assert all(h.done for h in handles)  # nothing dangles under load
    return handles, max_depth
