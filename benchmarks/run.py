"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Prints ``bench,key,value`` CSV lines; each bench also persists JSON to
benchmarks/results/<name>.json (consumed by EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_artifacts, bench_condition, bench_decode,
                        bench_groupwise, bench_http, bench_iterations,
                        bench_latency, bench_memory, bench_observability,
                        bench_paged_kv, bench_perplexity, bench_prefill,
                        bench_recovery, bench_roofline, bench_runtime,
                        bench_serving_api, bench_tolerance)
from benchmarks.common import RESULTS

SUITES = {
    "perplexity": bench_perplexity.run,    # Table 1/2/9
    "runtime": bench_runtime.run,          # Fig. 1(b), App. A.2
    "memory": bench_memory.run,            # Table 4, Eq. 9-13
    "latency": bench_latency.run,          # Tables 5/6
    "decode": bench_decode.run,            # decode fast path (tok/s trajectory)
    "prefill": bench_prefill.run,          # bucketed/chunked admission (TTFT)
    "artifacts": bench_artifacts.run,      # quantize-once/serve-many boot
    "serving_api": bench_serving_api.run,  # v1 streaming TTFT + cancel churn
    "paged_kv": bench_paged_kv.run,        # paged pool + COW prefix reuse
    "observability": bench_observability.run,  # v1.3 tracing overhead gate
    "http": bench_http.run,                # v1.4 wire identity + DRR fairness
    "recovery": bench_recovery.run,        # v1.5 MTTR/availability/replay

    "iterations": bench_iterations.run,    # Fig. 3
    "tolerance": bench_tolerance.run,      # Fig. 4
    "condition": bench_condition.run,      # Table 7
    "groupwise": bench_groupwise.run,      # Table 8
    "roofline": bench_roofline.run,        # §Roofline deliverable
}


def _headline_metrics(payload) -> list:
    """(key, value) pairs worth surfacing for one bench's JSON payload.

    Preference order: explicit ``headline_*`` keys, then ``*speedup*`` keys,
    then the first scalar — so every bench shows *something* without each
    having to opt in.
    """
    if not isinstance(payload, dict):
        return []
    scalars = {k: v for k, v in payload.items()
               if isinstance(v, (int, float)) and not isinstance(v, bool)}
    for picker in (lambda k: k.startswith("headline_"),
                   lambda k: "speedup" in k):
        picked = [(k, v) for k, v in scalars.items() if picker(k)]
        if picked:
            return picked[:3]
    return list(scalars.items())[:1]


def print_summary(out=print) -> None:
    """One table over every benchmarks/results/*.json produced so far."""
    import json

    rows = []
    for p in sorted(RESULTS.glob("*.json"), key=lambda p: p.name.lower()):
        try:
            payload = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        for key, val in _headline_metrics(payload):
            rows.append((p.stem, key, val))
    if not rows:
        out("(no benchmark results under benchmarks/results/)")
        return
    wn = max(len(r[0]) for r in rows)
    wk = max(len(r[1]) for r in rows)
    out(f"{'bench':<{wn}}  {'metric':<{wk}}  value")
    out("-" * (wn + wk + 12))
    for name, key, val in rows:
        sval = f"{val:.3f}" if isinstance(val, float) else str(val)
        out(f"{name:<{wn}}  {key:<{wk}}  {sval}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=tuple(SUITES), default=None)
    args = ap.parse_args(argv)
    todo = {args.only: SUITES[args.only]} if args.only else SUITES

    failed = []
    for name, fn in todo.items():
        print(f"=== bench:{name} ===", flush=True)
        t0 = time.time()
        try:
            fn(log=lambda s: print(s, flush=True))
            print(f"=== bench:{name} done in {time.time() - t0:.1f}s ===",
                  flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    print("=== summary (all recorded results) ===", flush=True)
    print_summary()
    if failed:
        print(f"FAILED: {failed}")
        sys.exit(1)
    print("ALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
