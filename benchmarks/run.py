"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Prints ``bench,key,value`` CSV lines; each bench also persists JSON to
benchmarks/results/<name>.json (consumed by EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_condition, bench_decode, bench_groupwise,
                        bench_iterations, bench_latency, bench_memory,
                        bench_perplexity, bench_roofline, bench_runtime,
                        bench_tolerance)

SUITES = {
    "perplexity": bench_perplexity.run,    # Table 1/2/9
    "runtime": bench_runtime.run,          # Fig. 1(b), App. A.2
    "memory": bench_memory.run,            # Table 4, Eq. 9-13
    "latency": bench_latency.run,          # Tables 5/6
    "decode": bench_decode.run,            # decode fast path (tok/s trajectory)
    "iterations": bench_iterations.run,    # Fig. 3
    "tolerance": bench_tolerance.run,      # Fig. 4
    "condition": bench_condition.run,      # Table 7
    "groupwise": bench_groupwise.run,      # Table 8
    "roofline": bench_roofline.run,        # §Roofline deliverable
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=tuple(SUITES), default=None)
    args = ap.parse_args(argv)
    todo = {args.only: SUITES[args.only]} if args.only else SUITES

    failed = []
    for name, fn in todo.items():
        print(f"=== bench:{name} ===", flush=True)
        t0 = time.time()
        try:
            fn(log=lambda s: print(s, flush=True))
            print(f"=== bench:{name} done in {time.time() - t0:.1f}s ===",
                  flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}")
        sys.exit(1)
    print("ALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
