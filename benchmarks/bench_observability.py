"""Observability overhead + trace validity: the zero-perturbation contract,
measured (serving contract v1.3).

Two sections, one JSON:

  * **overhead** — the bursty mixed-length trace through two long-lived
    engines, one with the default bundle (registry only, tracing off) and
    one with ``Observability(trace=True)``, interleaved rep-for-rep on the
    same warmed jit caches so compile time and drift cancel. Asserts the
    traced fleet's tokens are **bit-identical** to the untraced fleet's
    (the zero-perturbation guarantee) and that the best-of tok/s delta is
    under 3% (``headline_tracing_overhead_pct``). Compile counts are
    asserted equal too — instrumentation must not add a compile-cache
    axis.
  * **validity** — a traced run under each scheduler (bucketed and
    serial): every per-request span in the exported Chrome/Perfetto
    ``trace.json`` must reconcile *exactly* with the ``RequestResult``
    timestamps (``t_submit``/``t_first``/``t_done`` — the spans are built
    from those same floats, so equality is exact, not approximate), and
    the TTFT histogram percentiles must equal numpy percentiles of the
    per-request TTFTs. Writes the trace and the Prometheus snapshot next
    to the JSON (CI uploads them as artifacts).

``PYTHONPATH=src python benchmarks/bench_observability.py [--quick]``

Writes benchmarks/results/BENCH_observability.json (mirrored to the repo
root) plus results/trace_observability.json and
results/metrics_observability.prom.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import RESULTS, save_result
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import (EngineConfig, Observability, SamplingParams,
                           SerialAdmitEngine, ServingEngine)

ROOT = Path(__file__).resolve().parents[1]

BASE = dict(max_slots=4, capacity=64, prefill_chunk=16, decode_chunk=4)

#: bursty mixed-length arrival trace: waves of prompts whose lengths span
#: several prefill buckets, submitted between engine steps (the
#: bench_serving_api / bench_prefill traffic shape)
WAVE_LENGTHS = (3, 7, 12, 21, 5, 17)


def _bursty(n_waves: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    waves = []
    for w in range(n_waves):
        waves.append([rng.integers(1, 500, size=L).tolist()
                      for L in WAVE_LENGTHS[: 3 + (w % 3)]])
    return waves


def _run_fleet(eng, waves, max_new):
    """Submit the bursty waves (a couple of steps apart), drain, and return
    (outputs, wall_seconds, tokens)."""
    handles = []
    t0 = time.perf_counter()
    for wave in waves:
        for j, p in enumerate(wave):
            handles.append(eng.submit(p, SamplingParams(
                max_new_tokens=max_new, temperature=0.8,
                seed=1000 + len(handles))))
        eng.step()
        eng.step()
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
    wall = time.perf_counter() - t0
    assert all(h.done for h in handles)
    outputs = [tuple(h.output) for h in handles]
    return outputs, wall, sum(len(o) for o in outputs)


# ---------------------------------------------------------------------------
# overhead: tracing on vs instrumentation-default, interleaved best-of
# ---------------------------------------------------------------------------

def _bench_overhead(rows, log, params, cfg, quick):
    # each rep must be long enough that OS jitter amortizes, and best-of
    # needs several reps to converge — an undersized rep makes the 3% gate
    # measure the scheduler, not the instrumentation
    n_waves = 6 if quick else 8
    max_new = 16 if quick else 24
    reps = 5 if quick else 7
    waves = _bursty(n_waves)

    plain = ServingEngine(params, cfg, EngineConfig(**BASE))
    traced = ServingEngine(params, cfg, EngineConfig(**BASE),
                           observability=Observability(trace=True))
    # prime both engines on the full trace once: every prefill bucket and
    # decode chunk compiles here, outside the measured reps
    _run_fleet(plain, waves, max_new)
    _run_fleet(traced, waves, max_new)

    outs = {}
    attempt_overheads = []
    walls = {}
    # noise on a shared CPU container only ever *inflates* the apparent
    # overhead (a descheduled traced rep looks like instrumentation cost),
    # so the minimum over attempts is the tightest upper bound on the true
    # overhead — gate on that, with each attempt a median of paired ratios
    # (back-to-back runs cancel drift; the median rejects outlier reps)
    all_walls = {"plain": [], "traced": []}
    for attempt in range(3):
        walls = {"plain": [], "traced": []}
        for _ in range(reps):  # interleaved so drift hits both modes equally
            for name, eng in (("plain", plain), ("traced", traced)):
                o, w, n_tok = _run_fleet(eng, waves, max_new)
                walls[name].append(w)
                all_walls[name].append(w)
                assert outs.setdefault(name, o) == o  # deterministic per rep
        ratios = [t / p for p, t in zip(walls["plain"], walls["traced"])]
        attempt_overheads.append((float(np.median(ratios)) - 1.0) * 100.0)
        if attempt_overheads[-1] < 3.0:
            break
    overhead = min(attempt_overheads)
    # the keystone: bit-identical tokens with tracing on vs off
    assert outs["plain"] == outs["traced"]
    # and no new compile-cache axis from instrumentation
    for key in ("n_prefill_compiles", "n_decode_compiles"):
        assert plain.compile_stats()[key] == traced.compile_stats()[key]

    n_tok = sum(len(o) for o in outs["plain"])
    best_plain = min(all_walls["plain"])
    best_traced = min(all_walls["traced"])
    rows.update({
        "overhead_outputs_identical": True,
        "overhead_n_requests": len(outs["plain"]),
        "overhead_tokens_per_rep": n_tok,
        "overhead_reps": reps,
        "overhead_wall_best_plain_s": best_plain,
        "overhead_wall_best_traced_s": best_traced,
        "overhead_toks_best_plain": n_tok / best_plain,
        "overhead_toks_best_traced": n_tok / best_traced,
        "overhead_trace_events": len(traced.obs.trace),
        "overhead_attempts_pct": attempt_overheads,
        "tracing_overhead_pct": overhead,
    })
    log(f"bench_observability,tracing_overhead_pct,{overhead:.3f}")
    log(f"bench_observability,overhead_toks_best_plain,"
        f"{rows['overhead_toks_best_plain']:.1f}")
    # the acceptance gate: host-side bookkeeping must stay in the noise
    # next to jit dispatch
    assert overhead < 3.0, f"tracing overhead {overhead:.2f}% >= 3%"


# ---------------------------------------------------------------------------
# validity: spans reconcile exactly with RequestResult timestamps
# ---------------------------------------------------------------------------

def _bench_validity(rows, log, params, cfg, quick):
    max_new = 4 if quick else 8
    waves = _bursty(2)
    for sched, cls in (("bucketed", ServingEngine),
                       ("serial", SerialAdmitEngine)):
        obs = Observability(trace=True)
        eng = cls(params, cfg, EngineConfig(**BASE), observability=obs)
        handles = []
        for wave in waves:
            for p in wave:
                handles.append(eng.submit(p, SamplingParams(
                    max_new_tokens=max_new, temperature=0.8,
                    seed=1000 + len(handles))))
            eng.step()
        while eng.queue or any(s is not None for s in eng.slots):
            eng.step()
        results = [h.result() for h in handles]

        evs = obs.trace.events()
        checked = 0
        for h, r in zip(handles, results):
            spans = {e.name: e for e in evs
                     if e.track == ("requests", h.uid)}
            req = spans["request"]
            # exact equality: the span is built from the same floats the
            # result carries
            assert req.ts == r.t_submit and req.ts + req.dur == r.t_done
            assert req.args["finish_reason"] == r.finish_reason
            assert req.args["tokens"] == len(r.tokens)
            assert spans["first_token"].ts == r.t_first
            d = spans["decode"]
            assert d.ts == r.t_first and d.ts + d.dur == r.t_done
            checked += 1
        ttfts = np.asarray([r.ttft for r in results])
        h_ttft = obs.registry.get_histogram("serving_ttft_seconds")
        for q in (50, 90, 99):
            assert h_ttft.percentile(q) == float(np.percentile(ttfts, q))
        assert obs.registry.value("serving_tokens_generated_total") \
            == sum(len(r.tokens) for r in results)

        # the exported document is valid Chrome/Perfetto JSON
        doc = obs.trace.chrome_trace()
        assert all({"name", "ph", "pid", "tid", "ts"} <= set(e)
                   for e in doc["traceEvents"] if e["ph"] != "M")
        assert any(e["ph"] == "M" for e in doc["traceEvents"])
        rows[f"validity_requests_checked_{sched}"] = checked
        rows[f"validity_trace_events_{sched}"] = len(obs.trace)
        rows[f"validity_ttft_p99_ms_{sched}"] = 1e3 * h_ttft.percentile(99)
        log(f"bench_observability,validity_requests_checked_{sched},"
            f"{checked}")

        if sched == "bucketed":  # artifacts CI uploads
            obs.trace.write(RESULTS / "trace_observability.json")
            (RESULTS / "metrics_observability.prom").write_text(
                obs.registry.render_prometheus())
    rows["validity_spans_reconcile"] = True


def run(log=print, quick=False):
    rows = {}
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    _bench_overhead(rows, log, qparams, cfg, quick)
    _bench_validity(rows, log, qparams, cfg, quick)
    rows["headline_tracing_overhead_pct"] = rows["tracing_overhead_pct"]
    save_result("BENCH_observability", rows)
    (ROOT / "BENCH_observability.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
