"""Decode fast-path benchmark: engine tok/s + ternary-matmul decode latency.

Two sections, one JSON:

  * **engine** — end-to-end serving throughput (tok/s) of the per-step
    engine (``decode_chunk=1``, the seed behavior: one host round-trip per
    token) vs the fused multi-step decode loop (``decode_chunk=K``: one
    jitted ``lax.scan`` of K decode_step + on-device sampling per
    round-trip), for both FP32 and PTQTP-quantized params.  Outputs are
    checked bit-identical at temperature 0 — the fused loop is a pure
    scheduling optimization.
  * **matmul** — decode-shape (small m) latency of the quantized matmul
    backends: dense FP32, XLA grouped, and the Pallas small-m kernel.  On
    CPU the Pallas numbers run through the interpreter (``pallas_interpret``
    is recorded) — they validate the fast path, not its speed; the compiled
    kernel is the TPU story.

``PYTHONPATH=src python benchmarks/bench_decode.py [--quick]``

Writes benchmarks/results/BENCH_decode.json and mirrors it to
BENCH_decode.json at the repo root (the trajectory point ROADMAP.md quotes).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import save_result
from repro import configs
from repro.core.packing import pack_trits
from repro.core.ptqtp import PTQTPConfig, ptqtp_quantize
from repro.core.quantize_model import quantize_tree
from repro.kernels.ternary_matmul.ops import ternary_matmul
from repro.models import decode_step, init_params
from repro.serving import SamplingParams
from repro.serving.engine import (EngineConfig, SerialAdmitEngine,
                                  ServingEngine, _merge_slot_impl)
from repro.serving.sampling import sample_token

ROOT = Path(__file__).resolve().parents[1]


class SeedPerStepEngine(SerialAdmitEngine):
    """The seed engine, kept verbatim as the benchmark baseline: serial
    per-length prefill + merge admission, one jitted decode_step per token,
    sampling on host with a single engine-wide temperature (max over slots),
    one host round-trip per token, eager leaf-by-leaf slot merge, packed
    planes re-unpacked at every dispatch."""

    def __init__(self, params, model_cfg, engine_cfg):
        super().__init__(params, model_cfg, engine_cfg)
        import functools

        self._serve_params = self.params  # seed had no pre-unpack anywhere
        self._decode = jax.jit(functools.partial(decode_step, cfg=self.cfg))
        # the seed engine's single engine-wide RNG (v1 engines derive all
        # draws from each request's SamplingParams.seed instead)
        self.key = jax.random.PRNGKey(0)

    def _merge(self, batch_state, one_state, slot):
        # seed behavior: the eager tree walk, one device op per state leaf
        return _merge_slot_impl(batch_state, one_state, slot)

    def step(self):
        self._admit()
        done_now, self._admit_finished = self._admit_finished, []
        if all(s is None for s in self.slots):
            return done_now
        tokens = jnp.asarray(self.last_tokens)
        logits, self.state = self._decode(
            params=self.params, state=self.state, tokens=tokens)
        self.key, sub = jax.random.split(self.key)
        temps = [s.params.temperature if s else 0.0 for s in self.slots]
        temp = max(temps)  # per-engine temperature (slots share a sampler)
        next_tok = np.asarray(sample_token(logits, sub, temperature=temp))
        self.steps += 1
        return done_now + self._collect(next_tok[None, :])


def _time(fn, reps=5):
    fn()  # compile / warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


# ---------------------------------------------------------------------------
# engine throughput: per-step vs fused chunk
# ---------------------------------------------------------------------------

def _timed_wave(eng, prompts, max_new):
    """Submit one wave of requests, time run(); returns (tok/s, outputs)."""
    handles = [eng.submit(p, SamplingParams(max_new_tokens=max_new), uid=i)
               for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(h.output) for h in handles)
    return n_tok / dt, {h.uid: tuple(h.output) for h in handles}


def _bench_engine(rows, log, quick, chunk):
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    n_req = 4 if quick else 8
    max_new = 24 if quick else 48
    reps = 4
    prompts = [[1 + i, 2, 3 + i] for i in range(n_req)]

    variants = (("seed", SeedPerStepEngine, 1), ("perstep", ServingEngine, 1),
                ("fused", ServingEngine, chunk))
    for tag, p in (("fp32", params), ("ptqtp", qparams)):
        engines = {}
        for name, cls, c in variants:
            eng = cls(p, cfg, EngineConfig(max_slots=4, capacity=128,
                                           decode_chunk=c))
            # warm-up drains compilation (prefill buckets + decode loop)
            eng.submit(prompts[0], SamplingParams(max_new_tokens=max_new),
                       uid=-1)
            eng.run()
            engines[name] = eng
        tokps = {name: 0.0 for name, _, _ in variants}
        outs = {}
        # Interleave variants within each rep and take per-variant best:
        # a load spike on this shared box then degrades one rep of every
        # variant instead of silently sinking a single variant's number.
        for _ in range(reps):
            for name, _, _ in variants:
                t, o = _timed_wave(engines[name], prompts, max_new)
                tokps[name] = max(tokps[name], t)
                outs[name] = o
        for name, _, _ in variants:
            rows[f"engine_{tag}_tokps_{name}"] = tokps[name]
            log(f"bench_decode,engine_{tag}_tokps_{name},{tokps[name]:.1f}")
        rows[f"engine_{tag}_fused_speedup"] = tokps["fused"] / tokps["seed"]
        rows[f"engine_{tag}_outputs_identical"] = (
            outs["seed"] == outs["perstep"] == outs["fused"])
        log(f"bench_decode,engine_{tag}_fused_speedup,"
            f"{tokps['fused'] / tokps['seed']:.2f}")
    rows["engine_decode_chunk"] = chunk
    rows["engine_max_new_tokens"] = max_new
    rows["engine_n_requests"] = n_req


# ---------------------------------------------------------------------------
# matmul backends at decode shapes
# ---------------------------------------------------------------------------

def _bench_matmul(rows, log, quick):
    d_in, d_out = (512, 512) if quick else (1024, 2048)
    w = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((d_out, d_in), dtype=np.float32) * 0.02)
    q = ptqtp_quantize(w, PTQTPConfig(t_max=5))
    t1p, t2p = pack_trits(q.t1), pack_trits(q.t2)
    wd = w.T
    on_tpu = jax.default_backend() == "tpu"
    rows["matmul_shape"] = [d_out, d_in]
    rows["pallas_interpret"] = not on_tpu

    for m in ((1, 4) if quick else (1, 4, 8)):
        x = jnp.asarray(np.random.default_rng(m)
                        .standard_normal((m, d_in), dtype=np.float32))
        f_dense = jax.jit(lambda x: x @ wd)
        f_grouped = jax.jit(lambda x: ternary_matmul(
            x, t1p, t2p, q.alpha, group_size=128, backend="grouped"))
        f_pallas = jax.jit(lambda x: ternary_matmul(
            x, t1p, t2p, q.alpha, group_size=128, backend="pallas"))
        for name, fn in (("dense", f_dense), ("grouped", f_grouped),
                         ("pallas", f_pallas)):
            reps = 2 if (name == "pallas" and not on_tpu) else 5
            t = _time(lambda: fn(x), reps=reps)
            rows[f"matmul_{name}_us_m{m}"] = t * 1e6
            rows[f"matmul_{name}_tokps_m{m}"] = m / t
            log(f"bench_decode,matmul_{name}_us_m{m},{t * 1e6:.1f}")


def run(log=print, quick=False, chunk=16):
    rows = {}
    _bench_engine(rows, log, quick, chunk)
    _bench_matmul(rows, log, quick)
    # headline = the deployment config (PTQTP serving is the repo's story);
    # the fp32 ratio tracks ambient dispatch overhead and is context.
    rows["headline_fused_speedup"] = rows["engine_ptqtp_fused_speedup"]
    log(f"bench_decode,headline_fused_speedup,"
        f"{rows['headline_fused_speedup']:.2f}")
    save_result("BENCH_decode", rows)
    (ROOT / "BENCH_decode.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="fused decode chunk length K")
    args = ap.parse_args()
    run(quick=args.quick, chunk=args.chunk)
