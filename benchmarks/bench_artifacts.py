"""Artifact-store benchmark: the quantize-once / serve-many economics.

Three sections, one JSON:

  * **write** — streaming artifact write vs in-memory ``quantize_tree`` over
    the same synthetic weight tree: wall-clock throughput and peak RSS
    growth (``ru_maxrss`` delta across the measured phase). Each path runs
    in a fresh subprocess (``--_child``) so one path's peak cannot shadow
    the other's. The streaming path is measured both with fsync group
    commit (``stream``, the default: fsync every N tensors, manifest only
    advancing after the fsync) and with PR-3's per-tensor fsync
    (``stream_fsync1``) — the delta is the write path's durability
    overhead, which group commit amortizes.
  * **boot** — server time-to-first-token booting the same smoke model two
    ways: quantize-at-boot (the pre-PR-3 ``launch/serve.py`` pipeline) vs
    memory-mapped artifact boot (``--artifact``). The artifact is prepared
    outside the timed region — that is the whole point: quantization cost is
    paid once, not per server process.
  * **disk** — on-disk bytes/weight vs the paper's 0.53125 theoretical
    (Eq. 13, G=128, fp16 scales). The artifact stores fp32 scales so
    artifact boot is bit-identical to in-process quantization; the fp16
    theoretical at the same G is recorded next to it.

``PYTHONPATH=src python benchmarks/bench_artifacts.py [--quick]``

Writes benchmarks/results/BENCH_artifacts.json and mirrors it to
BENCH_artifacts.json at the repo root (the trajectory point ROADMAP.md
quotes).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import save_result
from repro.core.ptqtp import PTQTPConfig

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# RSS helper: each write path runs in its own subprocess, so the process-wide
# ru_maxrss delta across the measured phase isolates that path's peak growth
# ---------------------------------------------------------------------------

def _max_rss_kb() -> int:
    import resource

    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss


# ---------------------------------------------------------------------------
# child process: one write path, clean RSS
# ---------------------------------------------------------------------------

def _synthetic_tree(n_kernels: int, d: int):
    rng = np.random.default_rng(0)
    return {"layers": {f"l{i}": {"kernel": rng.standard_normal(
        (d, d)).astype(np.float32) * 0.02} for i in range(n_kernels)},
        "final_norm": {"scale": np.ones((d,), np.float32)}}


def _child(mode: str, n_kernels: int, d: int, out_json: str):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.artifacts import write_artifact
    from repro.core.quantize_model import quantize_kernel, quantize_tree

    tree = _synthetic_tree(n_kernels, d)
    pcfg = PTQTPConfig(group_size=128, t_max=5)
    # warm the quantizer jit (same shape for every kernel) so the measured
    # phase is throughput, not compilation
    jax.block_until_ready(quantize_kernel(
        jnp.asarray(tree["layers"]["l0"]["kernel"]), pcfg).alpha)

    rss0 = _max_rss_kb()
    t0 = time.perf_counter()
    if mode == "inmem":
        qp, report = quantize_tree(tree, pcfg)
        jax.block_until_ready([l for l in jax.tree.leaves(qp)])
        n_q = report["__total__"]["n_quantized"]
        # what a quantize-at-boot server must hold live: the whole packed
        # tree at once — O(model)
        resident_mb = report["__total__"]["after_bytes"] / 1e6
    else:
        # "stream" = default fsync group commit; "stream_fsync1" = PR-3's
        # per-tensor durability
        commit_every = 1 if mode == "stream_fsync1" else None
        with tempfile.TemporaryDirectory() as td:
            out = write_artifact(
                Path(td) / "art", arch="qwen2-1.5b",
                model_cfg=configs.get_smoke_config("qwen2-1.5b"),
                ptqtp_cfg=pcfg, params=tree, compute_error=False,
                commit_every=commit_every)
            m = json.loads((out / "manifest.json").read_text())
            n_q = m["stats"]["n_quantized"]
            # what the streaming writer holds live: one tensor's buffers at
            # a time — O(largest kernel)
            resident_mb = max(
                sum(b["nbytes"] for b in rec["buffers"].values())
                for rec in m["tensors"].values()) / 1e6
    dt = time.perf_counter() - t0
    payload = {
        "seconds": dt,
        "n_quantized": n_q,
        "weight_mb": n_kernels * d * d * 4 / 1e6,
        "peak_rss_growth_mb": (_max_rss_kb() - rss0) / 1024.0,
        "resident_quantized_mb": resident_mb,
    }
    Path(out_json).write_text(json.dumps(payload))


def _bench_write(rows, log, quick):
    from repro.artifacts.writer import ArtifactWriter

    n_kernels, d = (6, 256) if quick else (16, 1024)
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for mode in ("inmem", "stream", "stream_fsync1"):
        with tempfile.NamedTemporaryFile(suffix=".json") as f:
            subprocess.run(
                [sys.executable, str(Path(__file__).resolve()), "--_child",
                 mode, "--_n", str(n_kernels), "--_d", str(d),
                 "--_out", f.name],
                check=True, env=env, cwd=ROOT)
            r = json.loads(Path(f.name).read_text())
        rows[f"write_{mode}_s"] = r["seconds"]
        rows[f"write_{mode}_mb_per_s"] = r["weight_mb"] / r["seconds"]
        rows[f"write_{mode}_peak_rss_growth_mb"] = r["peak_rss_growth_mb"]
        rows[f"write_{mode}_resident_quantized_mb"] = \
            r["resident_quantized_mb"]
        log(f"bench_artifacts,write_{mode}_s,{r['seconds']:.2f}")
        log(f"bench_artifacts,write_{mode}_peak_rss_growth_mb,"
            f"{r['peak_rss_growth_mb']}")
        log(f"bench_artifacts,write_{mode}_resident_quantized_mb,"
            f"{r['resident_quantized_mb']:.2f}")
    rows["write_weight_mb"] = n_kernels * d * d * 4 / 1e6
    # the structural claim: in-memory holds the whole packed tree (O(model)),
    # streaming holds one tensor (O(largest kernel)); raw RSS deltas ride
    # along but are allocator-noise-dominated at smoke scale
    rows["write_resident_ratio"] = (
        rows["write_inmem_resident_quantized_mb"]
        / max(rows["write_stream_resident_quantized_mb"], 1e-9))
    # fsync group commit: the durability overhead it amortizes, and whether
    # streaming now beats the in-memory walk outright
    rows["write_group_commit_every"] = ArtifactWriter.DEFAULT_COMMIT_EVERY
    rows["write_fsync_batching_speedup"] = (
        rows["write_stream_fsync1_s"] / max(rows["write_stream_s"], 1e-9))
    rows["write_stream_vs_inmem_speedup"] = (
        rows["write_inmem_s"] / max(rows["write_stream_s"], 1e-9))
    log(f"bench_artifacts,write_fsync_batching_speedup,"
        f"{rows['write_fsync_batching_speedup']:.2f}")
    log(f"bench_artifacts,write_stream_vs_inmem_speedup,"
        f"{rows['write_stream_vs_inmem_speedup']:.2f}")


# ---------------------------------------------------------------------------
# boot TTFT: quantize-at-boot vs artifact memmap boot
# ---------------------------------------------------------------------------

def _boot_ttft(params_fn, prompt, max_new):
    from repro import configs
    from repro.serving import (EngineConfig, SamplingParams, ServingEngine)

    cfg = configs.get_smoke_config("qwen2-1.5b")
    t0 = time.perf_counter()
    params = params_fn()
    eng = ServingEngine(params, cfg, EngineConfig(max_slots=4, capacity=128,
                                                  seed=0))
    h = eng.submit(prompt, SamplingParams(max_new_tokens=max_new))
    res = h.result()
    return res.t_first - t0, res.tokens


def _bench_boot(rows, log, quick, tmp_dir):
    import jax

    from repro import configs
    from repro.artifacts import load_artifact, write_artifact
    from repro.core.quantize_model import quantize_tree
    from repro.models import init_params

    cfg = configs.get_smoke_config("qwen2-1.5b")
    pcfg = PTQTPConfig(group_size=32, t_max=5)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt, max_new = [1, 2, 3, 4], 8 if quick else 16

    # quantize once (untimed): the artifact every subsequent server boots from
    art = Path(tmp_dir) / "boot_artifact"
    write_artifact(art, arch="qwen2-1.5b", model_cfg=cfg, ptqtp_cfg=pcfg,
                   params=params, overwrite=True)
    # warm ambient XLA state with a throwaway FP engine so neither timed
    # path gets the cold-runtime penalty
    _boot_ttft(lambda: params, prompt, 2)

    ttft_q, out_q = _boot_ttft(
        lambda: quantize_tree(params, pcfg)[0], prompt, max_new)
    ttft_a, out_a = _boot_ttft(
        lambda: load_artifact(art)[0], prompt, max_new)

    rows["boot_quantize_ttft_s"] = ttft_q
    rows["boot_artifact_ttft_s"] = ttft_a
    rows["boot_outputs_identical"] = out_q == out_a
    rows["boot_ttft_speedup"] = ttft_q / ttft_a
    rows["artifact_boot_faster"] = ttft_a < ttft_q
    log(f"bench_artifacts,boot_quantize_ttft_s,{ttft_q:.2f}")
    log(f"bench_artifacts,boot_artifact_ttft_s,{ttft_a:.2f}")
    log(f"bench_artifacts,boot_ttft_speedup,{ttft_q / ttft_a:.2f}")
    return art


def _bench_disk(rows, log, art):
    from repro.artifacts import read_manifest

    m = read_manifest(art)
    stats = m["stats"]
    g = m["ptqtp_config"]["group_size"]
    rows["disk_bytes_per_weight"] = stats["bytes_per_weight"]
    # fp32 scales keep artifact boot bit-identical to in-process quantize;
    # Eq. 13's fp16-scale figure at the same G, and the paper's G=128
    # constant, sit alongside for the gap analysis
    rows["disk_bytes_per_weight_fp16_scales"] = 0.5 + 2 * 2 / g
    rows["disk_paper_theoretical_g128"] = 0.53125
    rows["disk_group_size"] = g
    rows["disk_total_mb"] = stats["total_bytes"] / 1e6
    rows["disk_vs_fp16_compression"] = (stats["source_fp16_bytes"]
                                        / stats["quantized_bytes"])
    for k in ("disk_bytes_per_weight", "disk_bytes_per_weight_fp16_scales",
              "disk_vs_fp16_compression"):
        log(f"bench_artifacts,{k},{rows[k]:.4f}")


def run(log=print, quick=False):
    rows = {}
    with tempfile.TemporaryDirectory() as td:
        _bench_write(rows, log, quick)
        art = _bench_boot(rows, log, quick, td)
        _bench_disk(rows, log, art)
        rows["headline_boot_ttft_speedup"] = rows["boot_ttft_speedup"]
        log(f"bench_artifacts,headline_boot_ttft_speedup,"
            f"{rows['headline_boot_ttft_speedup']:.2f}")
        save_result("BENCH_artifacts", rows)
        (ROOT / "BENCH_artifacts.json").write_text(
            json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--_child", choices=("inmem", "stream", "stream_fsync1"),
                    default=None, help=argparse.SUPPRESS)
    ap.add_argument("--_n", type=int, default=8, help=argparse.SUPPRESS)
    ap.add_argument("--_d", type=int, default=1024, help=argparse.SUPPRESS)
    ap.add_argument("--_out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args._child:
        _child(args._child, args._n, args._d, args._out)
    else:
        run(quick=args.quick)
