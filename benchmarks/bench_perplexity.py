"""Table 1/9 analogue: PPL across quantization methods on the in-repo LM.

Trains a byte LM on the synthetic corpus, then measures held-out perplexity
for FP32, PTQTP (1.58b), and the baselines at 2/3/4 bits. The reproduced
claim is the ORDERING: PTQTP ≺ binary-PTQ and 2-bit, ≈ grouped 3-bit,
and close to FP (paper Tables 1/2/9).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from benchmarks.common import (perplexity, quantize_params_with, save_result,
                               trained_eval_model)
from repro.core.baselines.billm import billm_quantize
from repro.core.baselines.gptq import gptq_quantize
from repro.core.baselines.rtn import rtn_quantize
from repro.core.ptqtp import (PTQTPConfig, ptqtp_dequantize, ptqtp_quantize)


# All fake-quant helpers mirror the deployment path's orientation: quantize
# Wᵀ (rows = outputs) with groups along the contraction dim d_in, like
# repro.core.quantize_model does — so vocab-sized output dims never need to
# divide the group size.

def _ptqtp_fake_quant(w, t_max=30):
    q = ptqtp_quantize(w.T, PTQTPConfig(group_size=128, t_max=t_max))
    return ptqtp_dequantize(q, w.dtype).T


def _rtn(bits):
    return lambda w: rtn_quantize(w.T, bits=bits, group_size=128)[0].T


def _gptq(bits):
    return lambda w: gptq_quantize(w.T, None, bits=bits, group_size=128)[0].T


METHODS = {
    "fp32": None,
    "ptqtp_b1.58": _ptqtp_fake_quant,
    "rtn_b4_g128": _rtn(4),
    "rtn_b3_g128": _rtn(3),
    "rtn_b2_g128": _rtn(2),
    "gptq_b3_g128": _gptq(3),
    "gptq_b2_g128": _gptq(2),
    "billm_b1": lambda w: billm_quantize(w.T)[0].T,
}


def run(log=print):
    cfg, params, _ = trained_eval_model()
    rows = {}
    for name, method in METHODS.items():
        p = params if method is None else quantize_params_with(params, method)
        ppl = perplexity(p, cfg)
        rows[name] = ppl
        log(f"bench_perplexity,{name},{ppl:.4f}")
    # the paper-claim assertions (soft: recorded, not raised)
    checks = {
        "ptqtp_lt_binary": rows["ptqtp_b1.58"] < rows["billm_b1"],
        "ptqtp_lt_rtn2": rows["ptqtp_b1.58"] < rows["rtn_b2_g128"],
        "ptqtp_lt_gptq2": rows["ptqtp_b1.58"] < rows["gptq_b2_g128"],
        "ptqtp_within_2x_of_fp": rows["ptqtp_b1.58"] < 2 * rows["fp32"],
    }
    save_result("bench_perplexity", {"ppl": rows, "checks": checks})
    log(f"bench_perplexity,checks,{checks}")
    return rows


if __name__ == "__main__":
    run()
