"""Table 8: group-wise (G=128) vs row-wise (no grouping) across methods.

On trained LLM weights (heterogeneous scales), grouping should improve every
method; PTQTP-with-groups should be competitive with 3-bit-grouped RTN.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (perplexity, quantize_params_with, save_result,
                               trained_eval_model)
from repro.core.baselines.rtn import rtn_quantize
from repro.core.ptqtp import PTQTPConfig, ptqtp_dequantize, ptqtp_quantize


def run(log=print):
    cfg, params, _ = trained_eval_model()

    # NOTE: quantizer groups along the contraction dim after transpose; G<=d_in
    def ptqtp_method(group):
        def f(w):
            d_in = w.shape[0]
            gs = group if group > 0 else d_in
            q = ptqtp_quantize(w.T, PTQTPConfig(group_size=min(gs, d_in),
                                                t_max=30))
            return ptqtp_dequantize(q, w.dtype).T
        return f

    def rtn_method(bits, group):
        def f(w):
            g = group if group > 0 else w.shape[0]
            return rtn_quantize(w.T, bits=bits,
                                group_size=min(g, w.shape[0])).__getitem__(0).T
        return f

    rows = {}
    for name, method in {
        "ptqtp_g128": ptqtp_method(128),
        "ptqtp_nogroup": ptqtp_method(0),
        "rtn3_g128": rtn_method(3, 128),
        "rtn3_nogroup": rtn_method(3, 0),
        "rtn2_g128": rtn_method(2, 128),
        "rtn2_nogroup": rtn_method(2, 0),
    }.items():
        qp = quantize_params_with(params, method)
        ppl = perplexity(qp, cfg, n_batches=4)
        rows[name] = ppl
        log(f"bench_groupwise,{name},{ppl:.4f}")

    rows["grouping_helps_ptqtp"] = rows["ptqtp_g128"] <= rows["ptqtp_nogroup"]
    rows["grouping_helps_rtn3"] = rows["rtn3_g128"] <= rows["rtn3_nogroup"]
    save_result("bench_groupwise", rows)
    return rows


if __name__ == "__main__":
    run()
