"""Fig. 1(b) + App. A.2: quantization runtime & O(T·n·d) scaling.

Measures wall-clock quantization time per matrix for PTQTP vs GPTQ/AWQ/
BiLLM-style baselines (relative speedups are the reproduced claim; absolute
numbers are CPU wall-clock, not A100), and verifies PTQTP runtime scales
LINEARLY in n·d (the paper's complexity claim; GPTQ is O(n·d²) for contrast).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, save_result
from repro.core.baselines.awq import awq_quantize
from repro.core.baselines.billm import billm_quantize
from repro.core.baselines.gptq import gptq_quantize
from repro.core.ptqtp import PTQTPConfig, ptqtp_quantize


def _w(n, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal((n, d), dtype=np.float32) * 0.02)


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(log=print):
    n, d = 512, 2048
    w = _w(n, d)
    x = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((128, d), dtype=np.float32))

    t_ptqtp = _time(lambda w: ptqtp_quantize(w, PTQTPConfig(t_max=50)), w)
    t_gptq = _time(lambda w: gptq_quantize(w, x, bits=3, group_size=128), w)
    t_awq = _time(lambda w: awq_quantize(w, x, bits=3, group_size=128), w)
    t_billm = _time(lambda w: billm_quantize(w, x), w)

    rows = {"ptqtp_s": t_ptqtp, "gptq_s": t_gptq, "awq_s": t_awq,
            "billm_s": t_billm,
            "speedup_vs_gptq": t_gptq / t_ptqtp,
            "speedup_vs_awq": t_awq / t_ptqtp,
            "speedup_vs_billm": t_billm / t_ptqtp}
    for k, v in rows.items():
        log(f"bench_runtime,{k},{v:.4f}")

    # O(n·d) scaling: time vs elements should be ~linear (r² of linear fit)
    sizes = [(128, 512), (256, 1024), (512, 2048), (1024, 2048)]
    elems, times = [], []
    for (ni, di) in sizes:
        wi = _w(ni, di, seed=ni)
        ti = _time(lambda w: ptqtp_quantize(w, PTQTPConfig(t_max=20)), wi,
                   reps=2)
        elems.append(ni * di)
        times.append(ti)
        log(f"bench_runtime,scaling_{ni}x{di},{ti:.4f}")
    e = np.asarray(elems, np.float64)
    t = np.asarray(times, np.float64)
    coef = np.polyfit(e, t, 1)
    pred = np.polyval(coef, e)
    r2 = 1 - np.sum((t - pred) ** 2) / np.sum((t - t.mean()) ** 2)
    rows["scaling_r2_linear"] = float(r2)
    log(f"bench_runtime,scaling_r2_linear,{r2:.4f}")
    save_result("bench_runtime", rows)
    return rows


if __name__ == "__main__":
    run()
