"""Fig. 3: progressive-search iterations vs error/PPL and quantization time.

Sweeps T_max and records (a) reconstruction error on real trained weights,
(b) held-out PPL of the quantized LM, (c) quantization wall-clock. Expected
shape: steep improvement then plateau ≈ 30 iterations (the paper's threshold).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (perplexity, quantize_params_with, save_result,
                               trained_eval_model)
from repro.core.ptqtp import (PTQTPConfig, ptqtp_dequantize, ptqtp_error,
                              ptqtp_quantize, quantize_with_history)

T_GRID = (1, 2, 5, 10, 20, 30, 50)


def run(log=print):
    cfg, params, _ = trained_eval_model()
    # a representative trained matrix for the error curve
    w = params["blocks"]["b0"]["attn"]["wq"]["kernel"][0].T.astype(jnp.float32)

    rows = {"t_max": list(T_GRID), "err": [], "time_s": [], "ppl": []}
    for t_max in T_GRID:
        pcfg = PTQTPConfig(group_size=128, t_max=t_max, eps=0.0)
        t0 = time.perf_counter()
        q = ptqtp_quantize(w, pcfg)
        jax.block_until_ready(q.alpha)
        dt = time.perf_counter() - t0
        err = float(ptqtp_error(w, q))

        qp = quantize_params_with(
            params, lambda m: ptqtp_dequantize(
                ptqtp_quantize(m.T, pcfg), m.dtype).T)
        ppl = perplexity(qp, cfg, n_batches=4)
        rows["err"].append(err)
        rows["time_s"].append(dt)
        rows["ppl"].append(ppl)
        log(f"bench_iterations,t_max={t_max},err={err:.5f},ppl={ppl:.3f},"
            f"time={dt:.3f}s")

    # convergence-history curve (the Fig. 3 middle/right sub-figures)
    _, hist = quantize_with_history(w, PTQTPConfig(t_max=50))
    rows["error_history"] = [float(h) for h in np.asarray(hist)]
    improves = rows["err"][0] - rows["err"][-1]
    tail = abs(rows["err"][4] - rows["err"][-1])  # t=20 vs t=50
    rows["plateau_after_20"] = bool(tail < 0.1 * max(improves, 1e-9))
    save_result("bench_iterations", rows)
    return rows


if __name__ == "__main__":
    run()
