"""§Roofline deliverable: aggregate the dry-run cache into the roofline table.

For every (arch × shape) cell on the single-pod mesh: the three roofline
terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS utilization, and the
PTQTP-vs-fp16 serving comparison where the quantized variant exists.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import save_result

DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells():
    cells = {}
    for p in sorted(DRYRUN.glob("*.json")):
        cells[p.stem] = json.loads(p.read_text())
    return cells


def table(mesh="single", quantized=False, cells=None):
    cells = cells or load_cells()
    rows = []
    suffix = f"__{mesh}" + ("__q" if quantized else "")
    for tag, c in cells.items():
        if not tag.endswith(suffix):
            continue
        r = c["roofline"]
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "chips": c["n_chips"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "roofline_fraction": r["roofline_fraction"],
            "memory_fused_s": r.get("memory_fused_s"),
            "step_fused_s": r.get("step_lower_bound_fused_s"),
            "useful_flops_ratio": c.get("useful_flops_ratio"),
            "bytes_per_chip": c.get("bytes_per_chip"),
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def run(log=print):
    cells = load_cells()
    base = table("single", False, cells)
    quant = table("single", True, cells)
    multi = table("multi", False, cells)

    log("bench_roofline,arch,shape,compute_s,memory_s,collective_s,"
        "dominant,fraction")
    for r in base:
        log(f"bench_roofline,{r['arch']},{r['shape']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
            f"{r['collective_s']:.3e},{r['dominant']},"
            f"{r['roofline_fraction']:.4f}")

    # PTQTP serving win: memory-term ratio fp16 vs quantized per cell
    wins = []
    qmap = {(r["arch"], r["shape"]): r for r in quant}
    for r in base:
        qr = qmap.get((r["arch"], r["shape"]))
        if qr is None:
            continue
        wins.append({
            "arch": r["arch"], "shape": r["shape"],
            "fp16_memory_s": r["memory_s"],
            "ptqtp_memory_s": qr["memory_s"],
            "memory_term_speedup": (r["memory_s"] / qr["memory_s"]
                                    if qr["memory_s"] else None),
            "fp16_step_s": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]),
            "ptqtp_step_s": max(qr["compute_s"], qr["memory_s"],
                                qr["collective_s"]),
        })
    for w in wins:
        log(f"bench_roofline_q,{w['arch']},{w['shape']},"
            f"mem_speedup={w['memory_term_speedup']:.2f}")

    out = {"single": base, "multi": multi, "quantized": quant,
           "ptqtp_serving_wins": wins,
           "n_cells": {"single": len(base), "multi": len(multi),
                       "quantized": len(quant)}}
    save_result("bench_roofline", out)
    return out


if __name__ == "__main__":
    run()
