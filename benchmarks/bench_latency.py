"""Tables 5/6 analogue: ternary-matmul kernel latency vs fp matmul.

On this CPU container, wall-clock compares the *grouped jnp* execution path
(what XLA actually runs) for PTQTP vs dense fp32, across the decode (B=1) and
prefill (B=128/2048) shapes of a LLaMA2-7B-like gate_proj (4096×11008), plus
roofline-*predicted* TPU latency from byte counts — the quantity the paper's
Table 5 measures on RTX 4090.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.packing import pack_trits, ptqtp_weight_bytes
from repro.core.ptqtp import PTQTPConfig, ptqtp_quantize
from repro.kernels.ternary_matmul.ops import ternary_matmul

HBM_BW = 819e9          # v5e bytes/s
PEAK_BF16 = 197e12      # v5e FLOP/s


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(log=print):
    d_in, d_out = 2048, 5504   # 1/2-scale gate_proj (CPU-tractable)
    w = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((d_out, d_in), dtype=np.float32) * 0.02)
    q = ptqtp_quantize(w.reshape(d_out, d_in), PTQTPConfig(t_max=5))
    t1p, t2p = pack_trits(q.t1), pack_trits(q.t2)
    wd = w.T  # dense (d_in, d_out)

    rows = {}
    for b in (1, 128, 2048):
        x = jnp.asarray(np.random.default_rng(b)
                        .standard_normal((b, d_in), dtype=np.float32))
        f_dense = jax.jit(lambda x: x @ wd)
        f_tern = jax.jit(lambda x: ternary_matmul(
            x, t1p, t2p, q.alpha, group_size=128, backend="grouped"))
        td = _time(lambda: f_dense(x))
        tt = _time(lambda: f_tern(x))
        rows[f"dense_ms_b{b}"] = td * 1e3
        rows[f"ptqtp_ms_b{b}"] = tt * 1e3
        log(f"bench_latency,dense_ms_b{b},{td * 1e3:.3f}")
        log(f"bench_latency,ptqtp_ms_b{b},{tt * 1e3:.3f}")

    # roofline-predicted decode latency on TPU v5e (B=1: HBM-bound)
    bytes_fp16 = 2 * d_in * d_out
    bytes_ptqtp = ptqtp_weight_bytes((d_out, d_in), 128)
    t_fp16 = bytes_fp16 / HBM_BW
    t_ptqtp = bytes_ptqtp / HBM_BW
    rows["tpu_pred_decode_us_fp16"] = t_fp16 * 1e6
    rows["tpu_pred_decode_us_ptqtp"] = t_ptqtp * 1e6
    rows["tpu_pred_decode_speedup"] = t_fp16 / t_ptqtp
    log(f"bench_latency,tpu_pred_decode_speedup,{t_fp16 / t_ptqtp:.2f}")
    save_result("bench_latency", rows)
    return rows


if __name__ == "__main__":
    run()
