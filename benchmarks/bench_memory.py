"""Table 4 / Eq. 9–13: memory footprint, analytic + measured packed bytes.

Reproduces the paper's memory model: PTQTP stores 2×2-bit planes + fp16 α per
128-group ≈ 0.531 B/weight (3.76× vs fp16), slightly above binary methods —
the paper's stated storage↔expressiveness trade-off. Measured bytes come from
the actual packed buffers of a quantized model tree.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save_result, trained_eval_model
from repro.core.packing import ptqtp_weight_bytes
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import QuantizedKernel, quantize_tree


def analytic_bytes_per_weight(group=128):
    """Eq. 13 normalized per weight, plus binary-method analogues
    (Eqs. 10–12 simplified to their dominant terms)."""
    ptqtp = 2 * 2 / 8 + 2 * 2 / group          # 2 planes @2b + 2 fp16 / G
    billm = 1 / 8 * 1.09 + 3 * 2 / group       # ~1.09 bit + 3 fp16 α / G
    arb = 1 / 8 * 1.09 + 2 * 2 / group
    fp16 = 2.0
    return {"fp16": fp16, "ptqtp": ptqtp, "billm_like": billm,
            "arb_like": arb}


def run(log=print):
    ana = analytic_bytes_per_weight()
    for k, v in ana.items():
        log(f"bench_memory,analytic_bytes_per_weight_{k},{v:.4f}")

    # measured on a real model tree
    cfg, params, _ = trained_eval_model()
    qparams, report = quantize_tree(params, PTQTPConfig(group_size=128,
                                                        t_max=5))
    tot = report["__total__"]
    meas = {}
    n_weights = q_bytes = q_bytes_eq13 = 0
    for path, info in report.items():
        if path == "__total__":
            continue
        n = int(np.prod(info["shape"]))
        n_weights += n
        q_bytes += info["after_bytes"]
        q_bytes_eq13 += info["after_bytes_eq13"]
    # Eq. 13 assumes fp16 scales — the paper's deployment number; the actual
    # packed tree ("measured") keeps fp32 scales for bit-exact serving.
    meas["measured_bytes_per_weight"] = q_bytes / n_weights
    meas["eq13_bytes_per_weight"] = q_bytes_eq13 / n_weights
    meas["measured_compression_vs_fp16"] = tot["compression"]
    meas["eq13_compression_vs_fp16"] = tot["compression_eq13"]
    meas["n_quantized_kernels"] = tot["n_quantized"]
    # exact packed-buffer accounting must match the report
    packed = sum(leaf.nbytes() for leaf in jax.tree.leaves(
        qparams, is_leaf=lambda x: isinstance(x, QuantizedKernel))
        if isinstance(leaf, QuantizedKernel))
    assert packed == tot["after_bytes"], (packed, tot["after_bytes"])
    for k, v in meas.items():
        log(f"bench_memory,{k},{v}")

    assert abs(meas["eq13_bytes_per_weight"] - ana["ptqtp"]) < 0.02, (
        meas, ana)
    ana_fp32_scales = 2 * 2 / 8 + 2 * 4 / 128  # fp32 α at G=128
    assert abs(meas["measured_bytes_per_weight"] - ana_fp32_scales) < 0.02, (
        meas, ana_fp32_scales)
    out = {"analytic": ana, **meas,
           "paper_ratio_check": 3.5 < meas["eq13_compression_vs_fp16"] < 4.0}
    save_result("bench_memory", out)
    return out


if __name__ == "__main__":
    run()
