"""Serving API v1 benchmark: streaming TTFT, cancellation churn, overload
shedding, and fault-injection chaos.

Five sections, one JSON:

  * **streaming** — requests consumed through ``RequestHandle.tokens()``
    under a bursty arrival trace: per-request stream TTFT (submit → first
    *yielded* token, measured at the consumer) against engine TTFT
    (``t_first``, stamped inside the engine step that finished prefill).
    The v1 contract says they coincide — ``stream_ttft_overhead_ms`` is
    the measured gap, which should be dispatch noise, not an extra drain.
  * **cancel** — slot-churn under a bursty trace where a fraction of
    requests is cancelled mid-flight (alternating mid-prefill and
    mid-decode): sustained tok/s of the survivors, slots freed and reused
    (every submitted request either finishes or cancels; admissions reuse
    cancelled slots), and survivor outputs checked bit-identical to the
    same trace run without any cancellations — cancellation must never
    perturb a neighbor.
  * **determinism** — one seeded sampled request replayed alone, co-batched
    and on the serial scheduler; records the bit-identity bool the API
    guarantees (also asserted, with more compositions, in
    tests/test_serving.py).
  * **overload** — a seeded Poisson arrival trace offered at ~2x the
    fleet's service capacity, run twice: with ``max_queue`` load-shedding
    (policy "reject") and without any cap. Records the shed rate, p99 TTFT
    of completed requests both ways, and the max queue depth (asserted
    under the cap when shedding) — the degradation story: bounded queues +
    fast rejections vs unbounded queue growth and TTFT blowup.
  * **chaos** — a seeded ``FaultPlan`` (NaN logits, attributed + vetoed
    dispatches, a clock stall that expires a deadline) against a bursty
    trace, diffed request-by-request against the identical fault-free run:
    survivors must be bit-identical (recorded + asserted); plus one
    corrupt-artifact-shard probe checking the reader's checksum report
    names the damaged buffer.

``PYTHONPATH=src python benchmarks/bench_serving_api.py [--quick]``

Writes benchmarks/results/BENCH_serving_api.json and mirrors it to
BENCH_serving_api.json at the repo root.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import drive_poisson, save_result, trace_prompts
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import (EngineConfig, SamplingParams, SerialAdmitEngine,
                           ServingEngine)

ROOT = Path(__file__).resolve().parents[1]

_prompts = trace_prompts  # shared seeded trace (benchmarks.common)


# ---------------------------------------------------------------------------
# streaming: consumer-side TTFT vs engine-side TTFT
# ---------------------------------------------------------------------------

def _bench_streaming(rows, log, eng, quick):
    n_req = 6 if quick else 16
    max_new = 8 if quick else 16
    handles = [eng.submit(p, SamplingParams(max_new_tokens=max_new, seed=i))
               for i, p in enumerate(_prompts(n_req, quick))]
    stream_ttft = {}
    t0 = time.perf_counter()
    # round-robin the generators: each next() drives the engine only when
    # its request has no buffered token, so the fleet advances together
    its = {h.uid: (h, h.tokens()) for h in handles}
    while its:
        for uid in list(its):
            h, it = its[uid]
            try:
                next(it)
                if uid not in stream_ttft:
                    stream_ttft[uid] = time.perf_counter() - h.t_submit
            except StopIteration:
                del its[uid]
    wall = time.perf_counter() - t0
    n_tok = sum(len(h.output) for h in handles)
    engine_ttft = [h.t_first - h.t_submit for h in handles]
    gap = [stream_ttft[h.uid] - (h.t_first - h.t_submit) for h in handles]
    rows["stream_n_requests"] = n_req
    rows["stream_tokps"] = n_tok / wall
    rows["stream_ttft_mean_ms"] = 1e3 * float(np.mean(list(
        stream_ttft.values())))
    rows["engine_ttft_mean_ms"] = 1e3 * float(np.mean(engine_ttft))
    rows["stream_ttft_overhead_ms"] = 1e3 * float(np.mean(gap))
    for k in ("stream_tokps", "stream_ttft_mean_ms",
              "stream_ttft_overhead_ms"):
        log(f"bench_serving_api,{k},{rows[k]:.3f}")


# ---------------------------------------------------------------------------
# cancellation churn
# ---------------------------------------------------------------------------

def _drive_with_cancels(eng, prompts, max_new, cancel_every):
    """Submit a bursty wave; cancel every ``cancel_every``-th request at its
    first resident observation — mid-prefill if it is still consuming its
    prompt, mid-decode once it holds tokens. Returns survivors' outputs +
    wall time + cancel bookkeeping. ``cancel_every=0`` disables (the
    reference pass)."""
    handles = [eng.submit(p, SamplingParams(max_new_tokens=max_new, seed=i))
               for i, p in enumerate(prompts)]
    victims = ({h.uid: h for i, h in enumerate(handles)
                if i % cancel_every == 1} if cancel_every else {})
    where = {"mid_prefill": 0, "mid_decode": 0}
    t0 = time.perf_counter()
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        for uid, v in list(victims.items()):
            if v.done:  # finished within its admission step — missed cue
                del victims[uid]
                continue
            if not any(s is v for s in eng.slots):
                continue
            where["mid_decode" if v.output else "mid_prefill"] += 1
            v.cancel()  # frees the slot right now; next step refills it
            del victims[uid]
    wall = time.perf_counter() - t0
    done = [h for h in handles if h.done and not h.cancelled]
    cancelled = [h for h in handles if h.cancelled]
    assert all(h.done for h in handles)  # nothing dangles
    return {
        "wall": wall,
        "n_tok": sum(len(h.output) for h in done),
        "outputs": {h.uid: tuple(h.output) for h in done},
        "n_cancelled": len(cancelled),
        "n_done": len(done),
        "where": where,
    }


def _bench_cancel(rows, log, params, cfg, quick):
    # churn-friendly shape: small decode chunks and a small prefill chunk so
    # victims are genuinely observable mid-prefill and mid-decode (with
    # decode_chunk >= max_new every request would finish inside its
    # admission step and there would be nothing to cancel)
    ecfg = EngineConfig(max_slots=4, capacity=64, decode_chunk=2,
                        prefill_chunk=8)
    mk = lambda: ServingEngine(params, cfg, ecfg)
    n_req = 8 if quick else 24
    max_new = 12 if quick else 16
    rng = np.random.default_rng(3)
    lens = rng.integers(2, 24 if quick else 48, size=n_req)
    prompts = [rng.integers(1, 500, size=int(l)).tolist() for l in lens]
    ref = _drive_with_cancels(mk(), prompts, max_new, cancel_every=0)
    churn = _drive_with_cancels(mk(), prompts, max_new, cancel_every=3)
    survivors_identical = all(
        churn["outputs"][uid] == ref["outputs"][uid]
        for uid in churn["outputs"])
    rows["cancel_n_requests"] = n_req
    rows["cancel_n_cancelled"] = churn["n_cancelled"]
    rows["cancel_n_mid_prefill"] = churn["where"]["mid_prefill"]
    rows["cancel_n_mid_decode"] = churn["where"]["mid_decode"]
    rows["cancel_n_completed"] = churn["n_done"]
    rows["cancel_tokps"] = churn["n_tok"] / churn["wall"]
    rows["nocancel_tokps"] = ref["n_tok"] / ref["wall"]
    rows["cancel_survivors_bit_identical"] = survivors_identical
    for k in ("cancel_tokps", "cancel_n_cancelled", "cancel_n_mid_prefill",
              "cancel_n_mid_decode", "cancel_survivors_bit_identical"):
        log(f"bench_serving_api,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# determinism: the API guarantee, recorded
# ---------------------------------------------------------------------------

def _bench_determinism(rows, log, params, cfg, quick):
    sp = SamplingParams(max_new_tokens=6 if quick else 12,
                        temperature=0.9, seed=1234)
    prompt = [5, 9, 17, 2, 33]
    alone = ServingEngine(params, cfg, EngineConfig(
        max_slots=1, capacity=64)).submit(prompt, sp).result().tokens
    eng = ServingEngine(params, cfg, EngineConfig(max_slots=4, capacity=64))
    h = eng.submit(prompt, sp)
    for i in range(3):
        eng.submit(_prompts(1, quick, seed=50 + i)[0],
                   SamplingParams(max_new_tokens=8, temperature=2.0, seed=i))
    cobatched = h.result().tokens
    serial = SerialAdmitEngine(params, cfg, EngineConfig(
        max_slots=2, capacity=64)).submit(prompt, sp).result().tokens
    rows["determinism_bit_identical"] = (alone == cobatched == serial)
    log(f"bench_serving_api,determinism_bit_identical,"
        f"{rows['determinism_bit_identical']}")


# ---------------------------------------------------------------------------
# overload: Poisson 2x over-capacity, shedding on vs off
# ---------------------------------------------------------------------------

def _drive_poisson(params, cfg, ecfg, prompts, max_new, lam, seed):
    """Build an engine and replay the shared seeded Poisson trace
    (``benchmarks.common.drive_poisson``) to drain."""
    eng = ServingEngine(params, cfg, ecfg)
    handles, max_depth = drive_poisson(eng, prompts, max_new, lam, seed)
    return eng, handles, max_depth


def _bench_overload(rows, log, params, cfg, quick):
    n_req = 16 if quick else 48
    max_new = 6 if quick else 10
    max_queue = 4
    prompts = _prompts(n_req, quick, seed=7)
    base = dict(max_slots=2, capacity=64, decode_chunk=4, prefill_chunk=16)
    # service ~= 1-2 requests per step at 2 slots; lam 3 offers ~2x that
    lam = 3.0

    def p99_ttft_ms(handles):
        ttfts = [1e3 * (h.t_first - h.t_submit) for h in handles
                 if h.t_first > 0]
        return float(np.percentile(ttfts, 99)) if ttfts else 0.0

    # warm the jit caches for this engine shape so neither measured run
    # pays compile time inside its TTFTs
    _drive_poisson(params, cfg, EngineConfig(**base), prompts[:4], max_new,
                   lam, seed=11)

    shed_eng, shed_h, shed_depth = _drive_poisson(
        params, cfg, EngineConfig(**base, max_queue=max_queue,
                                  admission_policy="reject"),
        prompts, max_new, lam, seed=11)
    open_eng, open_h, open_depth = _drive_poisson(
        params, cfg, EngineConfig(**base), prompts, max_new, lam, seed=11)

    assert shed_depth <= max_queue  # the cap held at every step
    rows["overload_n_requests"] = n_req
    rows["overload_offered_per_step"] = lam
    rows["overload_max_queue"] = max_queue
    rows["overload_shed_rate"] = shed_eng.sheds / n_req
    rows["overload_p99_ttft_ms_shedding"] = p99_ttft_ms(shed_h)
    rows["overload_p99_ttft_ms_unbounded"] = p99_ttft_ms(open_h)
    rows["overload_max_queue_depth_shedding"] = shed_depth
    rows["overload_max_queue_depth_unbounded"] = open_depth
    rows["overload_completed_shedding"] = sum(
        h.finish_reason == "length" for h in shed_h)
    for k in ("overload_shed_rate", "overload_p99_ttft_ms_shedding",
              "overload_p99_ttft_ms_unbounded",
              "overload_max_queue_depth_shedding",
              "overload_max_queue_depth_unbounded"):
        log(f"bench_serving_api,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# chaos: seeded fault plan; survivors diffed against the fault-free run
# ---------------------------------------------------------------------------

def _bench_chaos(rows, log, params, cfg, quick):
    from repro.serving import FaultInjector, FaultPlan, VirtualClock

    n_req = 8 if quick else 16
    prompts = _prompts(n_req, quick, seed=21)
    sps = [SamplingParams(max_new_tokens=4 + (i % 4),
                          temperature=0.0 if i % 2 else 0.9, seed=300 + i)
           for i in range(n_req)]
    sps[5] = SamplingParams(max_new_tokens=8, seed=305, deadline_s=30.0)
    ecfg = dict(max_slots=2, capacity=64, decode_chunk=2, prefill_chunk=16,
                max_queue=n_req, admission_policy="reject")

    def drive(plan):
        inj = FaultInjector(plan, clock=VirtualClock())
        eng = ServingEngine(params, cfg, EngineConfig(**ecfg), injector=inj)
        handles = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
        eng.run()
        return eng, inj, handles

    _, _, clean = drive(FaultPlan())
    plan = (FaultPlan(seed=5)
            .nan_logits(uid=1, gen_index=1)
            .dispatch_error("decode", 2, uid=3)
            .dispatch_error("prefill", 3)
            .stall_clock(at_step=4, advance_s=60.0))
    eng, inj, chaos = drive(plan)

    by_uid = {h.uid: h for h in clean}
    touched = {h.uid for h in chaos
               if h.finish_reason in ("error", "timeout", "rejected")}
    survivors = [h for h in chaos if h.uid not in touched]
    identical = all(h.output == by_uid[h.uid].output for h in survivors)
    assert identical  # the keystone guarantee, enforced not just recorded
    fired = sorted({k for k, _ in inj.log})
    reasons = {}
    for h in chaos:
        reasons[h.finish_reason] = reasons.get(h.finish_reason, 0) + 1
    rows["chaos_n_requests"] = n_req
    rows["chaos_plan"] = plan.describe()
    rows["chaos_faults_fired"] = fired
    rows["chaos_finish_reasons"] = reasons
    rows["chaos_n_survivors"] = len(survivors)
    rows["chaos_survivors_bit_identical"] = identical
    rows["chaos_errors_contained"] = eng.errors
    rows["chaos_timeouts"] = eng.timeouts
    rows["chaos_health"] = eng.health().summary()
    for k in ("chaos_n_survivors", "chaos_survivors_bit_identical",
              "chaos_errors_contained", "chaos_timeouts"):
        log(f"bench_serving_api,{k},{rows[k]}")

    # corrupt-shard probe: the reader's report must name the damaged buffer
    import tempfile

    from repro.artifacts import (ArtifactError, load_artifact,
                                 write_artifact)
    from repro.core.ptqtp import PTQTPConfig
    from repro.serving.faults import corrupt_artifact_shard

    with tempfile.TemporaryDirectory() as td:
        art = Path(td) / "artifact"
        small = {"layer": {"kernel": np.random.default_rng(0)
                           .standard_normal((64, 32)).astype(np.float32)}}
        write_artifact(art, arch="qwen2-1.5b", model_cfg=cfg,
                       ptqtp_cfg=PTQTPConfig(group_size=32, t_max=5),
                       params=small)
        load_artifact(art, verify="sizes")  # intact: fast mode passes
        dmg = corrupt_artifact_shard(art, seed=5)
        try:
            load_artifact(art, verify="full")
            caught = False
        except ArtifactError as e:
            caught = dmg["tensor"] in str(e) and dmg["shard"] in str(e)
    rows["chaos_corrupt_shard"] = {k: dmg[k] for k in
                                   ("tensor", "buffer", "shard")}
    rows["chaos_corrupt_shard_report_accurate"] = caught
    log(f"bench_serving_api,chaos_corrupt_shard_report_accurate,{caught}")


def run(log=print, quick=False):
    rows = {}
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    eng = ServingEngine(qparams, cfg,
                        EngineConfig(max_slots=4, capacity=64,
                                     decode_chunk=8, prefill_chunk=16))
    eng.warmup()
    _bench_streaming(rows, log, eng, quick)
    _bench_cancel(rows, log, qparams, cfg, quick)
    _bench_determinism(rows, log, qparams, cfg, quick)
    _bench_overload(rows, log, qparams, cfg, quick)
    _bench_chaos(rows, log, qparams, cfg, quick)
    rows["headline_stream_ttft_overhead_ms"] = rows["stream_ttft_overhead_ms"]
    save_result("BENCH_serving_api", rows)
    (ROOT / "BENCH_serving_api.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
