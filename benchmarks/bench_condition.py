"""Table 7: condition-bound (κ threshold) sweep.

Sweeps the κ threshold of the adaptive-λ rule (Eq. 3) from 10⁰ to 10¹⁸ and
records reconstruction error + PPL. Expected: improvement up to ~10²,
saturation beyond (the paper's monotone-then-flat pattern).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (perplexity, quantize_params_with, save_result,
                               trained_eval_model)
from repro.core.ptqtp import (PTQTPConfig, ptqtp_dequantize, ptqtp_error,
                              ptqtp_quantize)

COND_GRID = (1e0, 1e1, 1e2, 1e4, 1e8, 1e12, 1e18)


def run(log=print):
    cfg, params, _ = trained_eval_model()
    w = params["blocks"]["b0"]["attn"]["wq"]["kernel"][0].T.astype(jnp.float32)

    rows = {"cond": list(COND_GRID), "err": [], "ppl": []}
    for cond in COND_GRID:
        pcfg = PTQTPConfig(group_size=128, t_max=30, cond_bound=cond)
        q = ptqtp_quantize(w, pcfg)
        err = float(ptqtp_error(w, q))
        qp = quantize_params_with(
            params, lambda m: ptqtp_dequantize(ptqtp_quantize(m.T, pcfg),
                                               m.dtype).T)
        ppl = perplexity(qp, cfg, n_batches=4)
        rows["err"].append(err)
        rows["ppl"].append(ppl)
        log(f"bench_condition,cond=1e{int(jnp.log10(cond))},err={err:.5f},"
            f"ppl={ppl:.3f}")

    # saturation check: the 1e8..1e18 tail is flat
    tail = rows["ppl"][-3:]
    rows["saturates"] = bool(max(tail) - min(tail) < 0.05 * min(tail))
    save_result("bench_condition", rows)
    return rows


if __name__ == "__main__":
    run()
