"""Supervised recovery benchmark: MTTR, availability, and replay
bit-identity under a seeded engine-crash schedule.

Two runs over the same seeded prompt set at temperature 0:

  * **oracle** — crash-free cooperative run (``engine.submit`` +
    ``run()``): the reference token streams.
  * **chaos** — the same requests served over real loopback SSE by an
    ``EngineSupervisor`` whose factory arms a seeded ``engine_crash``
    fault in each of the first K generations (mid-decode, ambiguous
    multi-row attribution so nobody is blacklisted). Every crash tears
    the engine down, the factory rebuilds it, and every in-flight
    request replays from token 0 while the SSE streams continue.

Gates (hard asserts):

  * every scheduled crash happened and was recovered (generation == K),
  * every recovery stamped a first replayed token — MTTR
    (crash-detect → first post-crash token on a survivor's stream) is
    finite and recorded per recovery,
  * zero errored requests: all K crashes were ambiguous, so every
    request replays and finishes,
  * **bit-identity**: every SSE stream — spliced across K engine
    generations by the ``_delivered`` dedup cursor — equals the
    crash-free oracle exactly (no duplicate, no gap, no divergence).

Availability is reported as the fraction of the serving window not
spent inside a recovery (detect → survivors requeued).

``PYTHONPATH=src python benchmarks/bench_recovery.py [--quick]``

Writes benchmarks/results/BENCH_recovery.json and mirrors it to
BENCH_recovery.json at the repo root.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.request
from pathlib import Path

import jax

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import save_result, trace_prompts
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                           SamplingParams, ServingEngine)
from repro.serving.frontend import EngineSupervisor, ThreadedHttpServer

ROOT = Path(__file__).resolve().parents[1]

ECFG = dict(max_slots=2, capacity=64, decode_chunk=4, prefill_chunk=16)


def _sse(base, prompt, *, max_new, seed, timeout=300.0):
    """One streamed completion; returns the spliced token tuple and the
    terminal result event (what a real client sees across restarts)."""
    body = json.dumps({"prompt": list(prompt), "stream": True,
                       "max_new_tokens": max_new, "seed": seed}).encode()
    req = urllib.request.Request(
        base + "/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    tokens, result = [], None
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            for raw in resp:
                line = raw.decode("utf-8").strip()
                if not line.startswith("data: ") or line == "data: [DONE]":
                    continue
                ev = json.loads(line[len("data: "):])
                if "token" in ev:
                    tokens.append(ev["token"])
                else:
                    result = ev
    except urllib.error.HTTPError as e:  # shed/degraded outcomes
        result = json.loads(e.read())
    return {"tokens": tuple(tokens), "result": result}


def run(log=print, quick=False):
    rows = {}
    n_req = 4 if quick else 8
    max_new = 8 if quick else 16
    # decode-dispatch index (cumulative, per engine generation) at which
    # each generation's engine dies; sized so both slots are resident at
    # the crash (ambiguous attribution → everybody replays)
    crash_at = [1, 2] if quick else [2, 4]
    prompts = trace_prompts(n_req, quick, seed=29)

    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    # ---- oracle: the crash-free streams ---------------------------------
    ref_eng = ServingEngine(qparams, cfg, EngineConfig(**ECFG))
    ref_eng.warmup()
    refs = [ref_eng.submit(p, SamplingParams(max_new_tokens=max_new, seed=i))
            for i, p in enumerate(prompts)]
    ref_eng.run()
    ref_tokens = [tuple(h.output) for h in refs]

    # ---- chaos: K seeded crashes under supervision ----------------------
    built = {"n": 0}

    def factory():
        g = built["n"]
        built["n"] += 1
        plan = FaultPlan(seed=g)
        if g < len(crash_at):
            plan.engine_crash("decode", crash_at[g])
        return ServingEngine(qparams, cfg, EngineConfig(**ECFG),
                             injector=FaultInjector(plan))

    sup = EngineSupervisor(
        factory,
        max_restarts=len(crash_at) + 2,   # the breaker must not trip here
        restart_backoff_s=0.05,
        blacklist_after=len(crash_at) + 1,  # ambiguous strikes never condemn
    ).start()
    srv = ThreadedHttpServer(sup).start()
    base = f"http://{srv.host}:{srv.port}"

    outs = [None] * n_req
    threads = []

    def fire(i):
        outs[i] = _sse(base, prompts[i], max_new=max_new, seed=i)

    t0 = time.perf_counter()
    for i in range(n_req):
        th = threading.Thread(target=fire, args=(i,))
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=600.0)
    wall = time.perf_counter() - t0

    srv.stop()
    assert sup.drain(timeout=300.0), "supervisor failed to drain"
    status = sup.supervisor_status()
    recoveries = list(sup.recoveries)
    sup.close()

    # ---- gates ----------------------------------------------------------
    assert all(o is not None for o in outs), "SSE client thread hung"
    assert status["generation"] == len(crash_at), \
        f"expected {len(crash_at)} recoveries, got {status}"
    assert not status["degraded"] and not status["dead"], status
    errored = [o for o in outs
               if o["result"] is None
               or o["result"].get("finish_reason") != "length"]
    assert not errored, [o["result"] for o in errored]
    identical = [o["tokens"] == t for o, t in zip(outs, ref_tokens)]
    assert all(identical), \
        "replayed SSE streams diverge from the crash-free oracle"

    mttr = []
    for rec in recoveries:
        assert rec["t_first_replayed_token"] is not None, \
            f"recovery never delivered a replayed token: {rec}"
        mttr.append(rec["t_first_replayed_token"] - rec["t_detect"])
    downtime = sum(rec["duration_s"] for rec in recoveries)

    rows["n_requests"] = n_req
    rows["max_new_tokens"] = max_new
    rows["n_crashes"] = len(crash_at)
    rows["crash_decode_indices"] = crash_at
    rows["restarts"] = status["restarts"]
    rows["replayed"] = status["replayed"]
    rows["survivors_bit_identical"] = all(identical)
    rows["errored_requests"] = len(errored)
    rows["wall_s"] = wall
    rows["mttr_s_per_recovery"] = mttr
    rows["mttr_s_max"] = max(mttr)
    rows["mttr_s_mean"] = sum(mttr) / len(mttr)
    rows["recovery_downtime_s"] = downtime
    rows["availability"] = 1.0 - downtime / max(wall, 1e-9)
    rows["headline_mttr_s_mean"] = rows["mttr_s_mean"]
    rows["headline_availability"] = rows["availability"]
    for k in ("restarts", "replayed", "mttr_s_mean", "mttr_s_max",
              "availability", "wall_s"):
        log(f"bench_recovery,{k},{rows[k]:.3f}")
    log(f"bench_recovery,survivors_bit_identical,"
        f"{rows['survivors_bit_identical']}")
    save_result("BENCH_recovery", rows)
    (ROOT / "BENCH_recovery.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
