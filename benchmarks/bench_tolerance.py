"""Fig. 4: tolerance ε vs quantization time and PPL.

Tighter ε → more iterations before the ||Δα|| early-exit → better PPL at
higher cost; inflection ≈ 1e-2 (the paper's recommended range [1e-3, 1e-2]).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (perplexity, quantize_params_with, save_result,
                               trained_eval_model)
from repro.core.ptqtp import PTQTPConfig, ptqtp_dequantize, ptqtp_quantize

EPS_GRID = (1e0, 1e-1, 1e-2, 1e-3, 1e-4)


def run(log=print):
    cfg, params, _ = trained_eval_model()
    w = params["blocks"]["b0"]["attn"]["wq"]["kernel"][0].T.astype(jnp.float32)

    rows = {"eps": list(EPS_GRID), "iters": [], "time_s": [], "ppl": []}
    for eps in EPS_GRID:
        pcfg = PTQTPConfig(group_size=128, t_max=50, eps=eps)
        t0 = time.perf_counter()
        q = ptqtp_quantize(w, pcfg)
        jax.block_until_ready(q.alpha)
        dt = time.perf_counter() - t0

        qp = quantize_params_with(
            params, lambda m: ptqtp_dequantize(ptqtp_quantize(m.T, pcfg),
                                               m.dtype).T)
        ppl = perplexity(qp, cfg, n_batches=4)
        rows["iters"].append(int(q.iters))
        rows["time_s"].append(dt)
        rows["ppl"].append(ppl)
        log(f"bench_tolerance,eps={eps:g},iters={int(q.iters)},"
            f"ppl={ppl:.3f},time={dt:.3f}s")

    rows["iters_monotone_in_tightness"] = bool(
        all(a <= b for a, b in zip(rows["iters"], rows["iters"][1:])))
    save_result("bench_tolerance", rows)
    return rows


if __name__ == "__main__":
    run()
