"""Paged KV cache benchmark: requests per GB of resident KV, TTFT on
90%-shared-prefix traffic, overcommitted pools, and eviction/COW churn —
``kv_layout="paged"`` against the contiguous ``"ring"`` baseline.

Four sections, one JSON:

  * **shared** — the headline trace: every prompt is a common 96-token
    prefix plus a short unique tail (the system-prompt fleet). One priming
    request publishes the prefix pages, then the fleet runs on ring,
    paged+prefix-cache, and paged-without-cache. Records per-request TTFT,
    prefill dispatch counts (the deterministic proxy for the TTFT win:
    warm requests skip the shared prefix in ``prefill_chunk`` units),
    peak resident KV bytes, and ``requests_per_gb`` both ways — the
    ``requests_per_gb_ratio`` is the acceptance number (>= 2x) and is
    asserted, since it is pure page accounting, not wall clock. Outputs
    are asserted identical across all three runs (greedy; the determinism
    guarantee: shared vs recomputed prefix must not change a token).
  * **overcommit** — the same fleet through a pool *half* the ring
    footprint (``max_pages = max_slots * capacity / page_size / 2``):
    page-budget admission makes the queue head wait instead of
    corrupting; everything completes, outputs stay identical, and the
    pool-bytes ratio (2x) is the served-requests-per-GB-of-*pool* story.
  * **churn** — many distinct-prefix prompts through a deliberately tiny
    pool: the prefix cache fills, LRU eviction recycles cache-only pages
    under allocation pressure, and the fleet still drains. Records
    evictions, hits, peak pages, and the allocator's invariant check.
  * **cow** — one cached prefix, then a generation long enough to wrap
    the ring over it: copy-on-write forks are counted and a third
    request re-reading the cache is asserted bit-equal to a cold engine
    (the fork protected the published pages).

``PYTHONPATH=src python benchmarks/bench_paged_kv.py [--quick]``

Writes benchmarks/results/BENCH_paged_kv.json and mirrors it to
BENCH_paged_kv.json at the repo root.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # script mode

from benchmarks.common import save_result
from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine

ROOT = Path(__file__).resolve().parents[1]

BASE = dict(max_slots=4, capacity=128, prefill_chunk=32, decode_chunk=8,
            page_size=16)


def _fleet(n, seed=7):
    """90%-shared prompts: one 96-token prefix + an 8-token unique tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 500, size=96).tolist()
    return prefix, [prefix + rng.integers(1, 500, size=8).tolist()
                    for _ in range(n)]


def _run_fleet(params, cfg, ecfg, prompts, max_new, *, prime=None):
    """Prime (optional), reset the page peak, then offer every prompt at
    t0 and drain. Returns the engine and per-request records."""
    import time

    eng = ServingEngine(params, cfg, ecfg)
    if prime is not None:
        eng.submit(prime, SamplingParams(max_new_tokens=4, temperature=0.0))
        eng.run()
        if eng.paged:  # steady-state accounting starts after the prime
            eng.alloc.peak_used = eng.alloc.used_pages()
        eng.prefill_steps = 0
    handles = [eng.submit(p, SamplingParams(max_new_tokens=max_new,
                                            temperature=0.0))
               for p in prompts]
    first_step = {}
    step = 0
    t0 = time.perf_counter()
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        step += 1
        for idx, h in enumerate(handles):
            if idx not in first_step and h.output:
                first_step[idx] = step
    wall = time.perf_counter() - t0
    assert all(h.done for h in handles)
    ttft = [h.t_first - h.t_submit for h in handles]
    return eng, {
        "outputs": [tuple(h.output) for h in handles],
        "ttft_mean_ms": 1e3 * float(np.mean(ttft)),
        "ttft_p90_ms": 1e3 * float(np.quantile(ttft, 0.9)),
        # engine steps submit -> first token: the deterministic,
        # machine-independent TTFT (a warm request skips its shared
        # prefix in whole prefill chunks, so it finishes prefill in
        # strictly fewer steps)
        "ttft_steps_mean": float(np.mean([first_step[i]
                                          for i in range(len(handles))])),
        "wall": wall,
    }


def _peak_resident_bytes(eng):
    """Resident KV bytes at the page-usage high-water mark (null page and
    table included — the honest footprint)."""
    ms = eng.memory_stats()
    if not eng.paged:
        return ms["kv_resident_bytes"]
    return (ms["kv_resident_bytes"]
            + ms["kv_page_bytes"] * (eng.alloc.peak_used
                                     - eng.alloc.used_pages()))


# ---------------------------------------------------------------------------
# shared-prefix fleet: requests/GB + TTFT, three ways
# ---------------------------------------------------------------------------

def _bench_shared(rows, log, params, cfg, quick):
    n_req = 5 if quick else 12
    max_new = 6 if quick else 12
    prefix, prompts = _fleet(n_req)
    variants = {
        "ring": EngineConfig(**BASE, kv_layout="ring"),
        "paged": EngineConfig(**BASE, kv_layout="paged"),
        "paged_nocache": EngineConfig(**BASE, kv_layout="paged",
                                      prefix_cache=False),
    }
    # heat each layout's jit paths so measured TTFTs hold no compiles
    for ecfg in variants.values():
        _run_fleet(params, cfg, ecfg, prompts[:2], max_new, prime=prompts[0])

    engines, runs = {}, {}
    for name, ecfg in variants.items():
        engines[name], runs[name] = _run_fleet(
            params, cfg, ecfg, prompts, max_new, prime=prompts[0])

    assert (runs["ring"]["outputs"] == runs["paged"]["outputs"]
            == runs["paged_nocache"]["outputs"])  # the keystone guarantee
    rows["shared_outputs_identical"] = True
    rows["shared_n_requests"] = n_req
    rows["shared_prefix_len"] = len(prefix)
    rows["shared_fraction"] = len(prefix) / len(prompts[0])

    for name in variants:
        eng, r = engines[name], runs[name]
        resident = _peak_resident_bytes(eng)
        rows[f"shared_ttft_mean_ms_{name}"] = r["ttft_mean_ms"]
        rows[f"shared_ttft_p90_ms_{name}"] = r["ttft_p90_ms"]
        rows[f"shared_ttft_steps_{name}"] = r["ttft_steps_mean"]
        rows[f"shared_prefill_dispatches_{name}"] = eng.prefill_steps
        rows[f"shared_peak_resident_kv_bytes_{name}"] = resident
        rows[f"shared_requests_per_gb_{name}"] = n_req / (resident / 1e9)
        log(f"bench_paged_kv,shared_ttft_mean_ms_{name},"
            f"{r['ttft_mean_ms']:.2f}")

    warm = engines["paged"]
    rows["shared_prefix_hits"] = warm.alloc.hits
    rows["shared_prefix_misses"] = warm.alloc.misses
    rows["shared_peak_pages_paged"] = warm.alloc.peak_used
    rows["shared_ttft_speedup_vs_ring"] = (
        rows["shared_ttft_mean_ms_ring"] / rows["shared_ttft_mean_ms_paged"])
    rows["shared_ttft_steps_speedup_vs_ring"] = (
        rows["shared_ttft_steps_ring"] / rows["shared_ttft_steps_paged"])
    rows["requests_per_gb_ratio"] = (
        rows["shared_requests_per_gb_paged"]
        / rows["shared_requests_per_gb_ring"])
    # page accounting is deterministic — the acceptance floor is asserted,
    # not just recorded; prefill-dispatch count is the deterministic proxy
    # for the TTFT win (wall clock stays recorded, not asserted)
    assert rows["requests_per_gb_ratio"] >= 2.0, rows["requests_per_gb_ratio"]
    assert warm.alloc.hits > 0
    assert (rows["shared_prefill_dispatches_paged"]
            < rows["shared_prefill_dispatches_ring"])
    assert (rows["shared_ttft_steps_paged"]
            < rows["shared_ttft_steps_ring"])
    warm.alloc.check()
    for k in ("requests_per_gb_ratio", "shared_ttft_speedup_vs_ring",
              "shared_ttft_steps_speedup_vs_ring",
              "shared_prefix_hits", "shared_peak_pages_paged"):
        log(f"bench_paged_kv,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# overcommit: the same fleet through half the ring's pool
# ---------------------------------------------------------------------------

def _bench_overcommit(rows, log, params, cfg, quick):
    n_req = 5 if quick else 12
    max_new = 6 if quick else 12
    _, prompts = _fleet(n_req)
    half = BASE["max_slots"] * BASE["capacity"] // BASE["page_size"] // 2
    ecfg = EngineConfig(**BASE, kv_layout="paged", max_pages=half)
    eng, r = _run_fleet(params, cfg, ecfg, prompts, max_new,
                        prime=prompts[0])
    ring = EngineConfig(**BASE, kv_layout="ring")
    ring_eng, ring_r = _run_fleet(params, cfg, ring, prompts, max_new,
                                  prime=prompts[0])
    assert r["outputs"] == ring_r["outputs"]  # waiting, not corrupting
    assert eng.sheds == 0
    eng.alloc.check()
    pool = eng.memory_stats()["kv_pool_bytes"]
    ring_pool = ring_eng.memory_stats()["kv_pool_bytes"]
    rows["overcommit_pool_pages"] = half
    rows["overcommit_pool_bytes"] = pool
    rows["overcommit_pool_ratio_vs_ring"] = ring_pool / pool
    rows["overcommit_completed"] = n_req
    rows["overcommit_outputs_identical"] = True
    rows["overcommit_peak_pages"] = eng.alloc.peak_used
    for k in ("overcommit_pool_ratio_vs_ring", "overcommit_peak_pages"):
        log(f"bench_paged_kv,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# churn: distinct prefixes through a tiny pool (forced LRU eviction)
# ---------------------------------------------------------------------------

def _bench_churn(rows, log, params, cfg, quick):
    n_req = 8 if quick else 20
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, 500, size=64).tolist() for _ in range(n_req)]
    ecfg = EngineConfig(**BASE, kv_layout="paged", max_pages=12)
    eng, r = _run_fleet(params, cfg, ecfg, prompts, 6)
    assert eng.alloc.evictions > 0  # the tiny pool must have recycled cache
    eng.alloc.check()
    rows["churn_n_requests"] = n_req
    rows["churn_pool_pages"] = 12
    rows["churn_evictions"] = eng.alloc.evictions
    rows["churn_prefix_hits"] = eng.alloc.hits
    rows["churn_peak_pages"] = eng.alloc.peak_used
    rows["churn_cached_pages_end"] = eng.alloc.cached_pages()
    rows["churn_tokps"] = n_req * 6 / r["wall"]
    for k in ("churn_evictions", "churn_peak_pages", "churn_tokps"):
        log(f"bench_paged_kv,{k},{rows[k]}")


# ---------------------------------------------------------------------------
# cow: wrap over a shared prefix; the cache must come out pristine
# ---------------------------------------------------------------------------

def _bench_cow(rows, log, params, cfg, quick):
    _, prompts = _fleet(1, seed=31)
    prompt = prompts[0]
    ecfg = EngineConfig(**{**BASE, "max_slots": 2}, kv_layout="paged")
    eng = ServingEngine(params, cfg, ecfg)
    eng.submit(prompt, SamplingParams(max_new_tokens=4, temperature=0.0))
    eng.run()  # publishes the prefix
    eng.submit(prompt, SamplingParams(max_new_tokens=40, temperature=0.0))
    eng.run()  # 104 + 40 > 128: wraps over the shared pages -> forks
    assert eng.alloc.forks > 0
    warm = eng.submit(prompt, SamplingParams(max_new_tokens=8,
                                             temperature=0.0))
    eng.run()
    cold_eng = ServingEngine(params, cfg, dataclasses.replace(
        ecfg, prefix_cache=False))
    cold = cold_eng.submit(prompt, SamplingParams(max_new_tokens=8,
                                                  temperature=0.0))
    cold_eng.run()
    assert warm.output == cold.output  # the fork protected the cache
    eng.alloc.check()
    rows["cow_forks"] = eng.alloc.forks
    rows["cow_cache_pristine_after_wrap"] = True
    log(f"bench_paged_kv,cow_forks,{rows['cow_forks']}")


def run(log=print, quick=False):
    rows = {}
    cfg = dataclasses.replace(configs.get_smoke_config("qwen2-1.5b"),
                              kv_cache_dtype="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))

    _bench_shared(rows, log, qparams, cfg, quick)
    _bench_overcommit(rows, log, qparams, cfg, quick)
    _bench_churn(rows, log, qparams, cfg, quick)
    _bench_cow(rows, log, qparams, cfg, quick)
    rows["headline_requests_per_gb_ratio"] = rows["requests_per_gb_ratio"]
    # headline TTFT is the step-count ratio: machine-independent, and the
    # effect paging actually delivers (whole prefill chunks skipped). Wall
    # TTFT stays recorded per variant — at smoke scale on CPU it is
    # dispatch-overhead-dominated, which is not the deployment regime.
    rows["headline_shared_ttft_speedup"] = (
        rows["shared_ttft_steps_speedup_vs_ring"])
    save_result("BENCH_paged_kv", rows)
    (ROOT / "BENCH_paged_kv.json").write_text(
        json.dumps(rows, indent=1, default=float))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    args = ap.parse_args()
    run(quick=args.quick)
