"""Artifact store: format round-trip, resumable streaming writes, memmap boot.

The acceptance bar (ISSUE 3): artifact-booted engines are bit-identical to
quantize-at-boot engines at temperature 0, the streaming writer's peak
incremental host allocation is O(largest kernel), interrupted writes resume,
and corruption is detected with a clear error.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.artifacts import (ArtifactError, load_artifact, load_model_config,
                             read_manifest, verify_artifact, write_artifact)
from repro.artifacts.format import (decode_quantized_kernel,
                                    encode_quantized_kernel)
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import (QuantizedKernel, quantize_kernel,
                                       quantize_tree)
from repro.models import init_params
from repro.serving import SamplingParams
from repro.serving.engine import (EngineConfig, SerialAdmitEngine,
                                  ServingEngine)

PCFG = PTQTPConfig(group_size=32, t_max=3)
ARCH = "qwen2-1.5b"


def _flatten(tree):
    out = {}

    def walk(node, path=""):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}/{k}")
        else:
            out[path] = node

    walk(tree)
    return out


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke_config(ARCH)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qtree(model):
    cfg, params = model
    qp, _ = quantize_tree(params, PCFG)
    return qp


@pytest.fixture(scope="module")
def artifact(model, tmp_path_factory):
    cfg, params = model
    out = tmp_path_factory.mktemp("artifacts") / "model"
    write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                   params=params)
    return out


class TestFormat:
    def test_tree_roundtrip_bit_identical(self, model, qtree, artifact):
        """Streaming write + memmap load == in-memory quantize_tree, bitwise
        (same quantizer on the same weights → same trits and scales)."""
        tree, _ = load_artifact(artifact)
        a, b = _flatten(qtree), _flatten(tree)
        assert set(a) == set(b)
        for path in a:
            if isinstance(a[path], QuantizedKernel):
                assert isinstance(b[path], QuantizedKernel), path
                for f in ("t1p", "t2p", "alpha"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(a[path], f)),
                        np.asarray(getattr(b[path], f)), err_msg=path)
                assert (a[path].d_in, a[path].d_out, a[path].group_size) == \
                    (b[path].d_in, b[path].d_out, b[path].group_size)
            else:
                np.testing.assert_array_equal(
                    np.asarray(a[path]), np.asarray(b[path]), err_msg=path)

    def test_manifest_contract(self, artifact, model):
        """The schema documented in repro.artifacts.__doc__ is present."""
        m = read_manifest(artifact)
        assert m["format"] == "ptqtp-artifact" and m["format_version"] == 1
        assert m["complete"] and m["arch"] == ARCH
        assert m["ptqtp_config"]["group_size"] == PCFG.group_size
        q = [r for r in m["tensors"].values() if r["kind"] == "ptqtp"]
        assert q and all(set(r["buffers"]) == {"t1p", "t2p", "alpha"}
                         for r in q)
        # per-kernel approximation error from the progressive search
        assert all(0.0 < r["error"]["rel_fro_error"] < 1.0 for r in q)
        assert all({"shard", "offset", "nbytes", "shape", "dtype", "crc32"}
                   <= set(b) for r in m["tensors"].values()
                   for b in r["buffers"].values())
        # stats add up to the shard bytes actually referenced
        stats = m["stats"]
        assert stats["total_bytes"] == sum(
            b["nbytes"] for r in m["tensors"].values()
            for b in r["buffers"].values())
        # the smoke config quantizes with G=32 + fp32 scales: 0.5 B/w planes
        # + 2*4/32 B/w scales
        assert stats["bytes_per_weight"] == pytest.approx(0.75)
        # the reconstructed ModelConfig round-trips exactly
        assert load_model_config(m) == model[0]

    def test_memmap_zero_copy_leaves(self, artifact):
        """Every loaded buffer is a view into the shard mmap — no second
        host copy is materialized at load time."""

        def mmap_backed(arr):
            while arr is not None:
                if isinstance(arr, np.memmap):
                    return True
                arr = arr.base
            return False

        tree, _ = load_artifact(artifact)
        flat = _flatten(tree)
        qks = [v for v in flat.values() if isinstance(v, QuantizedKernel)]
        fps = [v for v in flat.values() if not isinstance(v, QuantizedKernel)]
        assert qks and fps
        for leaf in fps + [qks[0].t1p, qks[0].t2p, qks[0].alpha]:
            assert mmap_backed(leaf), type(leaf)

    def test_bfloat16_leaves_roundtrip(self, tmp_path):
        """Non-smoke configs carry bfloat16 params; ml_dtypes buffers must
        write, checksum, and memmap back intact (regression: memoryview
        .cast('B') rejects bfloat16)."""
        tree = {"layer": {"kernel": jnp.asarray(
            np.random.default_rng(5).standard_normal((64, 32)),
            jnp.bfloat16)},
            "norm": {"scale": jnp.ones((32,), jnp.bfloat16)}}
        cfg = configs.get_smoke_config(ARCH)
        out = tmp_path / "bf16"
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=tree)
        loaded, _ = load_artifact(out, verify=True)
        assert str(loaded["norm"]["scale"].dtype) == "bfloat16"
        np.testing.assert_array_equal(
            np.asarray(tree["norm"]["scale"]).view(np.uint16),
            np.asarray(loaded["norm"]["scale"]).view(np.uint16))
        qk = loaded["layer"]["kernel"]
        assert isinstance(qk, QuantizedKernel)
        qk_direct = quantize_kernel(tree["layer"]["kernel"], PCFG)
        np.testing.assert_array_equal(np.asarray(qk_direct.t1p),
                                      np.asarray(qk.t1p))

    def test_existing_artifact_needs_overwrite(self, artifact, model):
        cfg, params = model
        with pytest.raises(ArtifactError, match="already exists"):
            write_artifact(artifact, arch=ARCH, model_cfg=cfg,
                           ptqtp_cfg=PCFG, params=params)

    def test_codec_shared_with_checkpoint(self, tmp_path):
        """Satellite: checkpoint npz and artifact store one codec — a kernel
        saved through either comes back bit-identical through both."""
        from repro.runtime.checkpoint import load_checkpoint, save_checkpoint

        w = jnp.asarray(np.random.default_rng(7)
                        .standard_normal((128, 64), np.float32))
        qk = quantize_kernel(w, PCFG)
        # direct codec round-trip
        rt = decode_quantized_kernel(encode_quantized_kernel(qk))
        # checkpoint round-trip (routed through the same codec)
        save_checkpoint(tmp_path / "ckpt", 1, {"layer": {"kernel": qk}})
        _, loaded, _ = load_checkpoint(tmp_path / "ckpt")
        ck = loaded["layer"]["kernel"]
        for other in (rt, ck):
            assert isinstance(other, QuantizedKernel)
            assert (other.d_in, other.d_out, other.group_size) == (128, 64, 32)
            for f in ("t1p", "t2p", "alpha"):
                np.testing.assert_array_equal(np.asarray(getattr(qk, f)),
                                              np.asarray(getattr(other, f)))


class TestEngineBoot:
    @pytest.mark.parametrize("engine_cls", [ServingEngine, SerialAdmitEngine],
                             ids=["bucketed", "serial"])
    def test_artifact_boot_bit_identical(self, model, qtree, artifact,
                                         engine_cls):
        """ServingEngine booted from the artifact == quantize-at-boot, token
        for token at temperature 0 (both schedulers)."""
        cfg, _ = model
        art_params, _ = load_artifact(artifact)
        reqs = [([5, 9, 17, 2], 6), ([1, 2, 3], 5), ([7], 4), ([4, 4], 5)]
        outs = {}
        for tag, p in (("boot-quantize", qtree), ("artifact", art_params)):
            eng = engine_cls(p, cfg, EngineConfig(max_slots=2, capacity=32))
            for i, (prompt, mnt) in enumerate(reqs):
                eng.submit(prompt, SamplingParams(max_new_tokens=mnt), uid=i)
            outs[tag] = {r.uid: r.output for r in eng.run()}
        assert outs["boot-quantize"] == outs["artifact"]


class TestResume:
    def test_resume_after_interrupt(self, model, qtree, tmp_path):
        """Kill mid-write → staging survives; the re-run skips committed
        tensors, truncates the torn tail, and finalizes a complete artifact
        identical to a single-shot write. (commit_every=1: per-tensor
        durability, so every tensor written before the kill is committed —
        the finest-grained resume the writer offers.)"""
        cfg, params = model
        out = tmp_path / "art"

        class Interrupt(Exception):
            pass

        seen = {"quantized": 0}

        def interrupter(ev):
            if ev["action"] == "quantize":
                seen["quantized"] += 1
                if seen["quantized"] == 3:
                    raise Interrupt

        with pytest.raises(Interrupt):
            write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                           params=params, progress=interrupter,
                           commit_every=1)
        assert not out.exists()  # nothing published before finalize
        staging = out.with_name(out.name + ".staging")
        partial = json.loads((staging / "manifest.json").read_text())
        assert not partial.get("complete")
        n_committed = len(partial["tensors"])
        assert n_committed >= 3
        # simulate the torn tail of a mid-append crash
        with open(staging / partial["shards"][-1]["file"], "ab") as f:
            f.write(b"\xde\xad\xbe\xef")

        events = []
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=params, progress=events.append)
        assert len([e for e in events if e["action"] == "skip"]) == n_committed
        assert not staging.exists()
        tree, manifest = load_artifact(out, verify=True)  # checksums intact
        assert manifest["complete"]
        # the resumed artifact is bit-identical to in-memory quantization
        a, b = _flatten(qtree), _flatten(tree)
        assert set(a) == set(b)
        some_qk = next(p for p in a if isinstance(a[p], QuantizedKernel))
        np.testing.assert_array_equal(np.asarray(a[some_qk].t1p),
                                      np.asarray(b[some_qk].t1p))

    def test_group_commit_resume(self, model, qtree, tmp_path):
        """fsync group commit: the on-disk manifest only advances at group
        boundaries (after the data fsync), so a crash mid-group loses only
        the uncommitted tail — resume truncates it, re-quantizes just that
        group, and the final artifact is bit-identical to in-memory
        quantization."""
        cfg, params = model
        out = tmp_path / "art"
        every, kill_at = 4, 6

        class Interrupt(Exception):
            pass

        def interrupter(ev):
            if ev["index"] + 1 == kill_at:
                raise Interrupt

        with pytest.raises(Interrupt):
            write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                           params=params, progress=interrupter,
                           commit_every=every)
        staging = out.with_name(out.name + ".staging")
        partial = json.loads((staging / "manifest.json").read_text())
        # exactly one full group is durable; the mid-group tail is not
        assert len(partial["tensors"]) == (kill_at // every) * every
        # the uncommitted appends are a tail past the committed shard length
        shard = partial["shards"][-1]
        assert (staging / shard["file"]).stat().st_size > shard["nbytes"]

        events = []
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=params, progress=events.append,
                       commit_every=every)
        skipped = [e for e in events if e["action"] == "skip"]
        assert len(skipped) == (kill_at // every) * every
        tree, manifest = load_artifact(out, verify=True)
        assert manifest["complete"]
        a, b = _flatten(qtree), _flatten(tree)
        assert set(a) == set(b)
        for path in a:
            if isinstance(a[path], QuantizedKernel):
                np.testing.assert_array_equal(np.asarray(a[path].t1p),
                                              np.asarray(b[path].t1p))
                np.testing.assert_array_equal(np.asarray(a[path].alpha),
                                              np.asarray(b[path].alpha))

    def test_resume_config_mismatch_rejected(self, model, tmp_path):
        cfg, params = model
        out = tmp_path / "art"

        class Interrupt(Exception):
            pass

        def interrupter(ev):
            if ev["index"] == 2:
                raise Interrupt

        with pytest.raises(Interrupt):
            write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                           params=params, progress=interrupter)
        with pytest.raises(ArtifactError, match="different"):
            write_artifact(out, arch=ARCH, model_cfg=cfg,
                           ptqtp_cfg=PTQTPConfig(group_size=16, t_max=3),
                           params=params)


class TestIntegrity:
    def _small_artifact(self, tmp_path):
        tree = {"layer": {"kernel": jnp.asarray(
            np.random.default_rng(3).standard_normal((64, 32), np.float32))},
            "norm": {"scale": np.ones((32,), np.float32)}}
        cfg = configs.get_smoke_config(ARCH)
        out = tmp_path / "small"
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=tree)
        return out

    def test_checksum_corruption_detected(self, tmp_path):
        out = self._small_artifact(tmp_path)
        m = read_manifest(out)
        buf = m["tensors"]["/layer/kernel"]["buffers"]["t1p"]
        p = out / buf["shard"]
        raw = bytearray(p.read_bytes())
        raw[buf["offset"]] ^= 0xFF
        p.write_bytes(raw)
        load_artifact(out)  # lazy load does not touch pages
        with pytest.raises(ArtifactError, match=r"checksum mismatch.*t1p"):
            load_artifact(out, verify=True)
        with pytest.raises(ArtifactError):
            verify_artifact(out)

    def test_checksum_error_pinpoints_damage(self, tmp_path):
        """The failure report names the shard file, the buffer's byte
        range, and both the expected and actual crc32 — enough to locate
        the corruption without a bisection hunt."""
        out = self._small_artifact(tmp_path)
        m = read_manifest(out)
        buf = m["tensors"]["/layer/kernel"]["buffers"]["alpha"]
        p = out / buf["shard"]
        raw = bytearray(p.read_bytes())
        raw[buf["offset"] + 2] ^= 0x01
        p.write_bytes(raw)
        with pytest.raises(ArtifactError) as ei:
            load_artifact(out, verify="full")
        msg = str(ei.value)
        assert buf["shard"] in msg
        assert f"[{buf['offset']}, {buf['offset'] + buf['nbytes']})" in msg
        assert f"{buf['crc32']:#010x}" in msg and "got 0x" in msg

    def test_verify_sizes_mode(self, tmp_path):
        """verify="sizes" stat-checks shard lengths against the manifest:
        exact-length artifacts pass without reading tensor bytes; torn or
        padded shards fail; bit-flips (sizes intact) pass — that is the
        documented trade vs "full"."""
        out = self._small_artifact(tmp_path)
        tree, _ = load_artifact(out, verify="sizes")
        assert "/layer/kernel".split("/")[1] in tree  # loaded fine
        m = read_manifest(out)
        p = out / m["shards"][0]["file"]
        with open(p, "ab") as f:  # trailing garbage: size != committed
            f.write(b"\0" * 3)
        with pytest.raises(ArtifactError, match="oversized"):
            load_artifact(out, verify="sizes")
        with open(p, "r+b") as f:
            f.truncate(m["shards"][0]["nbytes"] - 4)
        with pytest.raises(ArtifactError, match="truncated"):
            load_artifact(out, verify="sizes")
        with pytest.raises(ValueError, match="verify"):
            load_artifact(out, verify="checksums-please")

    def test_overwrite_keeps_old_artifact_until_finalize(self, tmp_path):
        """A crashed --overwrite re-quantize must not destroy the last good
        artifact: the old directory is only replaced at finalize()."""
        out = self._small_artifact(tmp_path)
        cfg = configs.get_smoke_config(ARCH)
        tree = {"layer": {"kernel": jnp.asarray(np.random.default_rng(4)
                          .standard_normal((64, 32), np.float32))}}

        class Interrupt(Exception):
            pass

        def interrupter(ev):
            raise Interrupt

        with pytest.raises(Interrupt):
            write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                           params=tree, overwrite=True, progress=interrupter)
        load_artifact(out, verify=True)  # old artifact still intact
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=tree, overwrite=True)
        new_tree, _ = load_artifact(out, verify=True)
        assert "norm" not in new_tree  # now the replacement is live

    def test_incomplete_artifact_rejected(self, tmp_path):
        out = self._small_artifact(tmp_path)
        m = json.loads((out / "manifest.json").read_text())
        m["complete"] = False
        (out / "manifest.json").write_text(json.dumps(m))
        with pytest.raises(ArtifactError, match="incomplete"):
            load_artifact(out)

    def test_truncated_shard_rejected(self, tmp_path):
        out = self._small_artifact(tmp_path)
        m = read_manifest(out)
        p = out / m["shards"][0]["file"]
        with open(p, "r+b") as f:
            f.truncate(m["shards"][0]["nbytes"] - 8)
        with pytest.raises(ArtifactError, match="missing or truncated"):
            load_artifact(out)

    def test_wrong_version_rejected(self, tmp_path):
        out = self._small_artifact(tmp_path)
        m = json.loads((out / "manifest.json").read_text())
        m["format_version"] = 999
        (out / "manifest.json").write_text(json.dumps(m))
        with pytest.raises(ArtifactError, match="format_version"):
            read_manifest(out)


class TestStreamingMemory:
    def test_peak_incremental_host_alloc_is_o_largest_kernel(self, tmp_path):
        """Acceptance: the writer's tracemalloc peak stays O(largest kernel)
        while the tree it writes is many kernels large."""
        import tracemalloc

        rng = np.random.default_rng(0)
        kernel_bytes = 512 * 512 * 4  # 1 MiB each
        n_kernels = 8
        tree = {"layers": {
            f"l{i}": {"kernel": rng.standard_normal(
                (512, 512)).astype(np.float32)} for i in range(n_kernels)},
            "final_norm": {"scale": np.ones((512,), np.float32)}}
        cfg = configs.get_smoke_config(ARCH)
        pcfg = PTQTPConfig(group_size=128, t_max=2)
        # warm the jit caches (compilation allocates unboundedly many Python
        # objects and would swamp the measurement)
        write_artifact(tmp_path / "warm", arch=ARCH, model_cfg=cfg,
                       ptqtp_cfg=pcfg, params=tree)
        tracemalloc.start()
        write_artifact(tmp_path / "cold", arch=ARCH, model_cfg=cfg,
                       ptqtp_cfg=pcfg, params=tree)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        total = n_kernels * kernel_bytes
        assert peak < 3 * kernel_bytes, (peak, kernel_bytes)
        assert peak < total / 2, (peak, total)  # decisively not O(model)


class TestCheckpointSource:
    def test_quantize_streams_from_checkpoint(self, tmp_path):
        """--from-checkpoint path: leaves stream lazily out of the npz and
        quantize bit-identically to the in-memory walk."""
        from repro.artifacts import iter_checkpoint_leaves
        from repro.runtime.checkpoint import save_checkpoint

        rng = np.random.default_rng(11)
        params = {"layer": {"kernel": rng.standard_normal(
            (64, 32)).astype(np.float32)},
            "norm": {"scale": np.ones((32,), np.float32)}}
        save_checkpoint(tmp_path / "ckpt", 5, {"params": params})
        cfg = configs.get_smoke_config(ARCH)
        out = tmp_path / "art"
        write_artifact(out, arch=ARCH, model_cfg=cfg, ptqtp_cfg=PCFG,
                       params=iter_checkpoint_leaves(tmp_path / "ckpt"))
        tree, _ = load_artifact(out)
        qk_direct = quantize_kernel(jnp.asarray(params["layer"]["kernel"]),
                                    PCFG)
        qk = tree["layer"]["kernel"]
        np.testing.assert_array_equal(np.asarray(qk_direct.t1p),
                                      np.asarray(qk.t1p))
        np.testing.assert_array_equal(np.asarray(params["norm"]["scale"]),
                                      np.asarray(tree["norm"]["scale"]))


class TestQuantizeCLI:
    def test_cli_smoke(self, tmp_path, capsys):
        from repro.launch.quantize import main

        out = main(["--out", str(tmp_path / "cli"), "--t-max", "3",
                    "--group-size", "32", "--verify"])
        captured = capsys.readouterr().out
        assert "done in" in captured and "checksums OK" in captured
        m = read_manifest(out)
        assert m["complete"] and m["stats"]["n_quantized"] >= 5
