"""System-level tests: sharding rules, dry-run subprocess, end-to-end story.

The full 89-cell dry-run matrix is exercised by ``repro.launch.sweep`` (results
in benchmarks/results/dryrun/); here we gate-check one representative cell per
mesh in a subprocess (the 512-device XLA flag must not leak into this process).
"""

import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import configs
from repro.launch import specs as specs_mod

REPO = Path(__file__).resolve().parents[1]


class TestShardingRules:
    """PartitionSpec derivation on an abstract 16×16 mesh (no devices)."""

    def _mesh(self, multi=False):
        from jax.sharding import AbstractMesh

        # the installed jax's AbstractMesh wants ((name, size), ...) pairs;
        # other jax releases take (sizes_tuple, names_tuple) — re-check the
        # signature when bumping jax
        if multi:
            return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
        return AbstractMesh((("data", 16), ("model", 16)))

    @pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
    @pytest.mark.parametrize("multi", [False, True])
    def test_param_specs_cover_tree_and_divide(self, arch, multi):
        from jax.sharding import PartitionSpec as P

        from repro.sharding import partition as part

        cfg = configs.get_config(arch)
        mesh = self._mesh(multi)
        shapes = specs_mod.params_specs(cfg)
        pspecs = part.param_pspecs(shapes, mesh)

        leaves_s = jax.tree.leaves(shapes)
        leaves_p = jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_s) == len(leaves_p)
        for sds, spec in zip(leaves_s, leaves_p):
            assert isinstance(spec, P)
            assert len(spec) <= len(sds.shape)
            for dim, ax in zip(sds.shape, tuple(spec)):
                if ax is None:
                    continue
                size = (mesh.shape[ax] if isinstance(ax, str)
                        else int(np.prod([mesh.shape[a] for a in ax])))
                assert dim % size == 0, (arch, sds.shape, spec)

    def test_large_params_are_actually_sharded(self):
        """llama3-405b must not replicate any O(d²) matrix — FSDP/TP must
        split every big kernel or it cannot fit 256 chips."""
        from jax.sharding import PartitionSpec as P

        from repro.sharding import partition as part

        cfg = configs.get_config("llama3-405b")
        mesh = self._mesh()
        shapes = specs_mod.params_specs(cfg)
        pspecs = part.param_pspecs(shapes, mesh)
        flat_s = jax.tree.leaves(shapes)
        flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
        for sds, spec in zip(flat_s, flat_p):
            n = int(np.prod(sds.shape))
            if n >= 16 * 1024 * 1024:  # any 16M+ param tensor
                assert any(ax is not None for ax in tuple(spec)), (
                    sds.shape, spec)

    @pytest.mark.parametrize("arch", ["grok-1-314b", "deepseek-moe-16b"])
    def test_quantized_expert_specs(self, arch):
        """Quantized MoE experts: plane/scale specs must exist and divide."""
        from jax.sharding import PartitionSpec as P

        from repro.sharding import partition as part

        cfg = configs.get_config(arch)
        mesh = self._mesh()
        qshapes = specs_mod.quantized_params_specs(cfg)
        pspecs = part.param_pspecs(qshapes, mesh)
        flat_s = jax.tree.leaves(qshapes)
        flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_s) == len(flat_p)
        for sds, spec in zip(flat_s, flat_p):
            for dim, ax in zip(sds.shape, tuple(spec)):
                if ax is None:
                    continue
                size = (mesh.shape[ax] if isinstance(ax, str)
                        else int(np.prod([mesh.shape[a] for a in ax])))
                assert dim % size == 0, (arch, sds.shape, spec)


class TestInputSpecs:
    @pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
    def test_all_shapes_have_specs(self, arch):
        cfg = configs.get_config(arch)
        for shape in ("train_4k", "prefill_32k"):
            b = specs_mod.batch_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct) for v in b.values())
        st, tok = specs_mod.decode_state_specs(cfg, "decode_32k")
        assert tok.shape[0] == 128
        leaves = jax.tree.leaves(st)
        assert leaves and all(isinstance(v, jax.ShapeDtypeStruct)
                              for v in leaves)


@pytest.mark.slow
class TestDryRunSubprocess:
    @pytest.mark.parametrize("mesh", ["single", "multi"])
    def test_representative_cell_compiles(self, mesh, tmp_path):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "qwen2-1.5b", "--shape", "train_4k",
             "--mesh", mesh, "--out", str(tmp_path)],
            cwd=str(REPO), capture_output=True, text=True, timeout=900,
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
                 "HOME": "/root"},
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        out = json.loads(
            (tmp_path / f"qwen2-1.5b__train_4k__{mesh}.json").read_text())
        assert out["n_chips"] == (512 if mesh == "multi" else 256)
        assert out["cost_analysis"]["flops"] > 0
        assert out["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")


def test_dryrun_results_complete():
    """The committed dry-run cache must cover every runnable cell × mesh
    (33 × 2) plus the PTQTP-quantized inference variants (23)."""
    d = REPO / "benchmarks" / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run cache not generated yet")
    have = {p.stem for p in d.glob("*.json")}
    missing = []
    for arch, shape in configs.runnable_cells():
        for mesh in ("single", "multi"):
            if f"{arch}__{shape}__{mesh}" not in have:
                missing.append(f"{arch}__{shape}__{mesh}")
    assert not missing, missing
