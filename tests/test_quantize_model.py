"""Model-tree quantization walk + quantized inference equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import (QuantizedKernel, dequantize_kernel,
                                       quantize_kernel, quantize_tree)
from repro.models import forward, init_params
from repro.models.common import use_matmul_backend


def _smoke_params(arch="qwen2-1.5b", seed=0):
    cfg = configs.get_smoke_config(arch)
    return cfg, init_params(cfg, jax.random.PRNGKey(seed))


class TestTreeWalk:
    def test_excludes_non_linear_leaves(self):
        cfg, params = _smoke_params()
        qp, report = quantize_tree(params, PTQTPConfig(group_size=32, t_max=3))
        # embedding / norms must be untouched
        assert isinstance(qp["embed"]["embedding"], jax.Array)
        assert isinstance(qp["final_norm"]["scale"], jax.Array)
        # lm_head and block kernels must be quantized
        assert isinstance(qp["lm_head"]["kernel"], QuantizedKernel)
        paths = [p for p in report if p != "__total__"]
        assert any("lm_head" in p for p in paths)
        assert report["__total__"]["n_quantized"] >= 5

    def test_moe_experts_quantized_router_kept(self):
        cfg, params = _smoke_params("deepseek-moe-16b")
        qp, report = quantize_tree(params, PTQTPConfig(group_size=32, t_max=3))
        flat_types = {}

        def walk(node, path=""):
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{path}/{k}")
            else:
                flat_types[path] = type(node).__name__

        walk(qp)
        router_leaves = [p for p in flat_types if "router" in p]
        assert router_leaves
        assert all(flat_types[p] != "QuantizedKernel" for p in router_leaves)
        expert_kernels = [p for p, t in flat_types.items()
                          if "experts" in p and t == "QuantizedKernel"]
        assert expert_kernels  # stacked (L, in, out) kernels quantize too

    def test_report_bytes_match_packed_buffers(self):
        """Report after_bytes must equal the exact packed footprint
        (QuantizedKernel.nbytes()) for every entry — including 4-D MoE
        kernels (L, E, d_in, d_out), whose leading dims were once
        under-counted (only ndim == 3 multiplied the leading dim)."""
        cfg, params = _smoke_params("deepseek-moe-16b")
        qp, report = quantize_tree(params, PTQTPConfig(group_size=32, t_max=2))

        leaves = {}

        def walk(node, path=""):
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{path}/{k}")
            else:
                leaves[path] = node

        walk(qp)
        stacked = 0
        for path, info in report.items():
            if path == "__total__":
                continue
            qk = leaves[path]
            assert isinstance(qk, QuantizedKernel)
            assert info["after_bytes"] == qk.nbytes(), (path, info)
            stacked += len(info["shape"]) >= 4
        assert stacked >= 1  # the regression case: 4-D expert kernels
        tot = report["__total__"]
        assert tot["after_bytes"] == sum(
            leaf.nbytes() for leaf in leaves.values()
            if isinstance(leaf, QuantizedKernel))
        assert tot["compression"] == tot["before_bytes"] / tot["after_bytes"]

    def test_compression_ratio_near_paper(self):
        """Full-size kernel: compression vs fp16 ≈ 3.76× (App. A.3)."""
        w = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((512, 1024), dtype=np.float32))
        qk = quantize_kernel(w, PTQTPConfig(group_size=128, t_max=3))
        ratio = (w.size * 2) / qk.nbytes()
        assert 3.5 < ratio < 4.0, ratio

    def test_dequantize_roundtrip_shape(self):
        w = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((256, 128), dtype=np.float32))
        qk = quantize_kernel(w, PTQTPConfig(group_size=64, t_max=10))
        wd = dequantize_kernel(qk)
        assert wd.shape == w.shape
        rel = float(jnp.linalg.norm(w - wd) / jnp.linalg.norm(w))
        assert rel < 0.4


class TestQuantizedInference:
    def test_quantized_forward_close_to_dequantized_forward(self):
        """Running the QuantizedKernel fast path == running a dense model
        built from the dequantized weights (exact same math, different
        execution)."""
        cfg, params = _smoke_params(seed=2)
        qp, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=10))

        def dequant_walk(node):
            if isinstance(node, QuantizedKernel):
                return dequantize_kernel(node, jnp.float32)
            if isinstance(node, dict):
                return {k: dequant_walk(v) for k, v in node.items()}
            return node

        dp = dequant_walk(qp)
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(3).integers(0, 256, (2, 12)), jnp.int32)}
        y_q = forward(qp, cfg, batch)
        y_d = forward(dp, cfg, batch)
        np.testing.assert_allclose(np.asarray(y_q, np.float32),
                                   np.asarray(y_d, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_backends_agree_in_model(self):
        cfg, params = _smoke_params(seed=4)
        qp, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(5).integers(0, 256, (1, 8)), jnp.int32)}
        with use_matmul_backend("grouped"):
            y_g = forward(qp, cfg, batch)
        with use_matmul_backend("ref"):
            y_r = forward(qp, cfg, batch)
        np.testing.assert_allclose(np.asarray(y_g, np.float32),
                                   np.asarray(y_r, np.float32),
                                   rtol=2e-2, atol=2e-2)
