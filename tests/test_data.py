"""Data pipeline: determinism, host-sharding disjointness, resume addressing."""

import numpy as np

from repro.data.pipeline import DataConfig, ShardedLoader
from repro.data.synthetic import synthetic_corpus
from repro.data.tokenizer import ByteTokenizer


class TestTokenizer:
    def test_roundtrip(self):
        tok = ByteTokenizer()
        s = "ternary trit-planes, 1.58 bits!"
        assert tok.decode(tok.encode(s)) == s

    def test_batch_padding(self):
        tok = ByteTokenizer()
        b = tok.encode_batch(["ab", "cdef"], seq_len=8)
        assert b.shape == (2, 8)
        assert b[0, -1] == ByteTokenizer.PAD


class TestSynthetic:
    def test_deterministic(self):
        assert synthetic_corpus(4096, seed=1) == synthetic_corpus(4096, seed=1)
        assert synthetic_corpus(4096, seed=1) != synthetic_corpus(4096, seed=2)

    def test_has_structure(self):
        text = synthetic_corpus(1 << 16, seed=0).decode("utf-8")
        assert "equals" in text and "recall slot" in text


class TestLoader:
    def test_batch_shapes_and_labels_shift(self):
        cfg = DataConfig(seq_len=32, global_batch=4)
        loader = ShardedLoader(cfg)
        b = loader.batch_at(0)
        assert b["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_deterministic_addressing(self):
        cfg = DataConfig(seq_len=16, global_batch=4, seed=3)
        l1, l2 = ShardedLoader(cfg), ShardedLoader(cfg)
        for step in (0, 1, 17, 12345):
            np.testing.assert_array_equal(l1.batch_at(step)["tokens"],
                                          l2.batch_at(step)["tokens"])

    def test_host_shards_partition_global_batch(self):
        """Union of host slices == the single-host global batch, in order."""
        g = DataConfig(seq_len=16, global_batch=8, n_hosts=1)
        full = ShardedLoader(g).batch_at(5)["tokens"]
        parts = []
        for h in range(4):
            cfg = DataConfig(seq_len=16, global_batch=8, n_hosts=4, host_id=h)
            parts.append(ShardedLoader(cfg).batch_at(5)["tokens"])
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_prefetch_stream_matches_addressing(self):
        cfg = DataConfig(seq_len=16, global_batch=2)
        loader = ShardedLoader(cfg)
        it = loader.iterate(start_step=7)
        got = [next(it) for _ in range(3)]
        loader.close()
        for i, b in enumerate(got):
            np.testing.assert_array_equal(
                b["tokens"], loader.batch_at(7 + i)["tokens"])

    def test_producer_errors_propagate(self):
        """A failing producer must raise in the consumer, never deadlock."""
        import pytest

        cfg = DataConfig(seq_len=16, global_batch=2)
        loader = ShardedLoader(cfg)
        loader._ids = None  # corrupt the corpus → producer throws on slice
        it = loader.iterate(start_step=0)
        with pytest.raises(Exception):
            next(it)
