"""Trainer integration: convergence, resume-after-preemption, compression."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig


def _trainer(tmp=None, steps=20, grad_compress=False, arch="qwen2-1.5b",
             **tkw):
    cfg = configs.get_smoke_config(arch)
    dcfg = DataConfig(seq_len=32, global_batch=4)
    tcfg = TrainerConfig(total_steps=steps, log_interval=1000,
                         ckpt_dir=str(tmp) if tmp else None,
                         grad_compress=grad_compress, **tkw)
    return Trainer(cfg, AdamW(lr=3e-3), dcfg, tcfg)


class TestTraining:
    def test_loss_decreases(self):
        t = _trainer(steps=25)
        t.fit()
        first = np.mean([h["loss"] for h in t.history[:5]])
        last = np.mean([h["loss"] for h in t.history[-5:]])
        assert last < first, (first, last)

    def test_grad_compression_still_learns(self):
        """int8 + error feedback must not break optimization."""
        t = _trainer(steps=25, grad_compress=True)
        t.fit()
        first = np.mean([h["loss"] for h in t.history[:5]])
        last = np.mean([h["loss"] for h in t.history[-5:]])
        assert last < first, (first, last)

    def test_stub_frontend_arch_trains(self):
        t = _trainer(steps=6, arch="musicgen-large")
        t.fit()
        assert all(np.isfinite(h["loss"]) for h in t.history)


class TestFaultTolerance:
    def test_checkpoint_resume_continues_step_count(self, tmp_path):
        t1 = _trainer(tmp_path, steps=10, ckpt_interval=5)
        t1.fit()
        # second trainer resumes from step 10 checkpoint and runs to 15
        t2 = _trainer(tmp_path, steps=15, ckpt_interval=5)
        t2.fit()
        assert t2.history[0]["step"] == 11
        assert t2.history[-1]["step"] == 15

    def test_resume_deterministic_data(self, tmp_path):
        """Resumed run must see exactly the batches of an uninterrupted run."""
        t = _trainer(steps=1)
        b_direct = t.loader.batch_at(12)
        t2 = _trainer(steps=1)
        it = t2.loader.iterate(start_step=12)
        b_stream = next(it)
        t2.loader.close()
        np.testing.assert_array_equal(b_direct["tokens"], b_stream["tokens"])

    def test_preemption_checkpoints_and_exits(self, tmp_path):
        from repro.runtime.checkpoint import latest_step
        from repro.runtime.preempt import PreemptionGuard

        guard = PreemptionGuard(signals=())

        # deliver "SIGTERM" once step 5 is logged (log_interval=1)
        def log_hook(msg):
            if "step 5 " in msg:
                guard.request()

        cfg = configs.get_smoke_config("qwen2-1.5b")
        t = Trainer(cfg, AdamW(lr=3e-3),
                    DataConfig(seq_len=32, global_batch=4),
                    TrainerConfig(total_steps=500, log_interval=1,
                                  ckpt_dir=str(tmp_path),
                                  ckpt_interval=1000),
                    log_fn=log_hook)
        t.fit(guard=guard)
        assert len(t.history) <= 8  # exited promptly, not after 500 steps
        assert latest_step(tmp_path) is not None  # final ckpt written
