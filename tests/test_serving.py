"""Serving engine: continuous batching, quantized path, sampling, and the
v1 request API (SamplingParams / RequestHandle): per-request-seed
determinism across fleet compositions and schedulers, cancellation,
streaming, stop sets, row-wise top-k/top-p."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import forward, init_params
from repro.serving import SamplingParams, SerialAdmitEngine
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.sampling import (request_keys, sample_token,
                                    sample_tokens,
                                    sample_tokens_per_request,
                                    top_k_top_p_mask)


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([[0.1, 5.0, -2.0], [3.0, 0.0, 1.0]])
        toks = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
        np.testing.assert_array_equal(np.asarray(toks), [1, 0])

    def test_topk_restricts_support(self):
        logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]]).repeat(64, 0)
        toks = sample_token(logits, jax.random.PRNGKey(1),
                            temperature=1.0, top_k=2)
        assert set(np.asarray(toks).tolist()) <= {1, 2}

    def test_per_row_temperatures(self):
        """Row 0 (temp 0) must be the argmax even when other rows sample."""
        logits = jnp.asarray([[0.1, 5.0, -2.0],
                              [1.0, 1.1, 0.9],
                              [3.0, 0.0, 1.0]])
        temps = jnp.asarray([0.0, 2.0, 0.0])
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(2), temps))
        assert toks[0] == 1 and toks[2] == 0

    def test_vectorized_matches_scalar_greedy(self):
        logits = jnp.asarray(np.random.default_rng(3)
                             .standard_normal((8, 17), dtype=np.float32))
        a = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
        b = sample_tokens(logits, jax.random.PRNGKey(0), jnp.zeros((8,)))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestEngine:
    def test_completes_all_requests(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                      capacity=64))
        for i in range(5):  # more requests than slots → continuous batching
            eng.submit([1, 2, 3 + i], SamplingParams(max_new_tokens=4),
                       uid=i)
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.output) == 4 for r in done)
        assert all(r.done for r in done)

    def test_greedy_matches_forward_argmax(self, small_model):
        """Engine prefill+decode must reproduce teacher-forced argmax path."""
        cfg, params = small_model
        prompt = [5, 9, 17, 2]
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit(prompt, SamplingParams(max_new_tokens=3), uid=0)
        out = eng.run()[0].output

        seq = list(prompt)
        expect = []
        for _ in range(3):
            logits = forward(params, cfg,
                             {"tokens": jnp.asarray([seq], jnp.int32)})
            tok = int(jnp.argmax(logits[0, -1]))
            expect.append(tok)
            seq.append(tok)
        assert out == expect, (out, expect)

    def test_eos_stops_early(self, small_model):
        cfg, params = small_model
        logits = forward(params, cfg,
                         {"tokens": jnp.asarray([[5, 9, 17, 2]], jnp.int32)})
        eos = int(jnp.argmax(logits[0, -1]))  # first generated token == EOS
        eng = ServingEngine(params, cfg,
                            EngineConfig(max_slots=1, capacity=32,
                                         eos_id=eos))
        eng.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=64), uid=0)
        done = eng.run()
        assert len(done) == 1 and len(done[0].output) <= 2

    def test_quantized_params_serve(self, small_model):
        cfg, params = small_model
        qp, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))
        eng = ServingEngine(qp, cfg, EngineConfig(max_slots=2, capacity=32))
        for i in range(3):
            eng.submit([1, 2, 3], SamplingParams(max_new_tokens=3), uid=i)
        done = eng.run()
        assert len(done) == 3
        assert all(len(r.output) == 3 for r in done)

    def test_fused_chunk_matches_per_step(self, small_model):
        """K-token fused decode must be bit-identical to per-token decode
        at temperature 0 (same requests, chunk=8 vs chunk=1)."""
        cfg, params = small_model
        reqs = [([5, 9, 17, 2], 6), ([1, 2, 3], 5), ([7], 4)]
        outs = {}
        for chunk in (1, 8):
            eng = ServingEngine(params, cfg,
                                EngineConfig(max_slots=2, capacity=32,
                                             decode_chunk=chunk))
            for i, (prompt, mnt) in enumerate(reqs):
                eng.submit(prompt, SamplingParams(max_new_tokens=mnt), uid=i)
            outs[chunk] = {r.uid: r.output for r in eng.run()}
        assert outs[1] == outs[8]

    def test_fused_chunk_respects_eos(self, small_model):
        """EOS inside a chunk must truncate the output mid-chunk."""
        cfg, params = small_model
        # find the 2nd greedy continuation token, use it as EOS
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=8), uid=0)
        free_run = eng.run()[0].output
        eos = free_run[2]
        eng2 = ServingEngine(params, cfg,
                             EngineConfig(max_slots=1, capacity=32,
                                          eos_id=eos, decode_chunk=8))
        eng2.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=8), uid=0)
        out = eng2.run()[0].output
        # stops at (and includes) the *first* occurrence of the EOS token
        first = free_run.index(eos)
        assert out == free_run[:first + 1]

    def test_per_slot_temperature_isolation(self, small_model):
        """A greedy slot must stay greedy while a co-batched slot samples at
        high temperature (regression: engine used max over slot temps)."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        solo.submit([7, 8, 9], SamplingParams(max_new_tokens=5), uid=0)
        ref = solo.run()[0].output

        mixed = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                        capacity=32))
        mixed.submit([7, 8, 9], SamplingParams(max_new_tokens=5,
                                               temperature=0.0), uid=0)
        mixed.submit([1, 2], SamplingParams(max_new_tokens=5,
                                            temperature=8.0, seed=1),
                     uid=1)
        outs = {r.uid: r.output for r in mixed.run()}
        assert outs[0] == ref

    def test_slot_isolation(self, small_model):
        """A request's outputs must not depend on its co-batched neighbors."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        solo.submit([7, 8, 9], SamplingParams(max_new_tokens=4), uid=0)
        ref = solo.run()[0].output

        packed = ServingEngine(params, cfg, EngineConfig(max_slots=3,
                                                         capacity=32))
        packed.submit([7, 8, 9], SamplingParams(max_new_tokens=4), uid=0)
        packed.submit([1], SamplingParams(max_new_tokens=4), uid=1)
        packed.submit([2, 3], SamplingParams(max_new_tokens=4), uid=2)
        outs = {r.uid: r.output for r in packed.run()}
        assert outs[0] == ref


class TestRowwiseSampling:
    """sample_tokens_per_request / top_k_top_p_mask against references."""

    def test_top_k_top_p_mask_matches_numpy(self):
        """The row-wise support mask == a straightforward NumPy nucleus +
        top-k reference, row for row."""
        rng = np.random.default_rng(11)
        logits = rng.standard_normal((5, 37)).astype(np.float32)
        top_k = np.asarray([0, 5, 1, 36, 3], np.int32)
        top_p = np.asarray([1.0, 0.3, 0.9, 1e-3, 0.5], np.float32)
        got = np.asarray(top_k_top_p_mask(jnp.asarray(logits),
                                          jnp.asarray(top_k),
                                          jnp.asarray(top_p)))
        for r in range(logits.shape[0]):
            order = np.argsort(-logits[r], kind="stable")
            x = logits[r][order].astype(np.float64)
            probs = np.exp(x - x.max())
            probs /= probs.sum()
            cum = np.cumsum(probs)
            ref = np.zeros(logits.shape[1], bool)
            k = top_k[r] if top_k[r] > 0 else logits.shape[1]
            for j, v in enumerate(order):
                keep = j < k
                if top_p[r] < 1.0:
                    keep &= (cum[j] - probs[j]) < top_p[r]
                ref[v] = keep
            np.testing.assert_array_equal(got[r], ref, err_msg=f"row {r}")

    def test_top_k1_sampling_is_argmax(self):
        """temperature>0 with top_k=1 leaves exactly one eligible token."""
        rng = np.random.default_rng(3)
        logits = jnp.asarray(rng.standard_normal((6, 29), dtype=np.float32))
        keys = request_keys(jnp.arange(6, dtype=jnp.uint32),
                            jnp.zeros((6,), jnp.int32))
        toks = sample_tokens_per_request(
            logits, keys, jnp.full((6,), 1.3),
            top_k=jnp.ones((6,), jnp.int32),
            top_p=jnp.ones((6,), jnp.float32))
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_row_draw_independent_of_batch(self):
        """A row's draw depends only on (its key, its logits) — the whole
        point of per-request keys: move the row, change its neighbors, the
        token is the same."""
        rng = np.random.default_rng(5)
        row = rng.standard_normal((1, 41)).astype(np.float32)
        other = rng.standard_normal((3, 41)).astype(np.float32)
        key = request_keys(jnp.asarray([77], jnp.uint32),
                           jnp.asarray([4], jnp.int32))
        temps = jnp.asarray([0.8])
        alone = np.asarray(sample_tokens_per_request(
            jnp.asarray(row), key, temps))[0]
        batch = np.concatenate([other[:2], row, other[2:]], 0)
        keys4 = request_keys(jnp.asarray([1, 2, 77, 3], jnp.uint32),
                             jnp.asarray([0, 9, 4, 1], jnp.int32))
        packed = np.asarray(sample_tokens_per_request(
            jnp.asarray(batch), keys4, jnp.asarray([1.0, 2.0, 0.8, 0.5])))[2]
        assert alone == packed

    def test_greedy_rows_unaffected_by_mask(self):
        """temperature-0 rows stay bit-identical argmax even when the fleet
        compiles the top-k/top-p mask in (the v1 compat guarantee)."""
        rng = np.random.default_rng(8)
        logits = jnp.asarray(rng.standard_normal((4, 23), dtype=np.float32))
        keys = request_keys(jnp.zeros((4,), jnp.uint32),
                            jnp.zeros((4,), jnp.int32))
        toks = sample_tokens_per_request(
            logits, keys, jnp.asarray([0.0, 1.0, 0.0, 2.0]),
            top_k=jnp.asarray([0, 3, 0, 5], jnp.int32),
            top_p=jnp.asarray([1.0, 0.5, 1.0, 0.7], jnp.float32))
        greedy = np.asarray(jnp.argmax(logits, -1))
        np.testing.assert_array_equal(np.asarray(toks)[[0, 2]],
                                      greedy[[0, 2]])


class TestRequestAPI:
    """The v1 contract: determinism, streaming, cancellation, stop sets."""

    SP = dict(max_new_tokens=5, temperature=0.9, seed=41)

    def test_seeded_output_invariant_to_fleet_and_scheduler(self,
                                                           small_model):
        """A request with a fixed SamplingParams seed is bit-identical
        whether it runs alone, co-batched with arbitrary other traffic,
        under different chunk boundaries, or on the serial scheduler."""
        cfg, params = small_model
        prompt = [5, 9, 17, 2]
        sp = SamplingParams(**self.SP)
        solo = ServingEngine(params, cfg,
                             EngineConfig(max_slots=1, capacity=32))
        ref = solo.submit(prompt, sp).result().tokens
        assert len(ref) == sp.max_new_tokens

        # fleet 2: co-batched with hot + greedy traffic
        e2 = ServingEngine(params, cfg, EngineConfig(max_slots=3,
                                                     capacity=32))
        h2 = e2.submit(prompt, sp)
        e2.submit([1, 2], SamplingParams(max_new_tokens=7, temperature=3.0,
                                         seed=9))
        e2.submit([3, 4, 5], SamplingParams(max_new_tokens=3))
        assert h2.result().tokens == ref

        # fleet 3: different prefill/decode chunk boundaries
        e3 = ServingEngine(params, cfg,
                           EngineConfig(max_slots=2, capacity=32,
                                        decode_chunk=1, prefill_chunk=2))
        h3 = e3.submit(prompt, sp)
        e3.submit([7], SamplingParams(max_new_tokens=8, temperature=0.5,
                                      seed=3))
        assert h3.result().tokens == ref

        # fleet 4: the serial-admit scheduler, co-batched
        e4 = SerialAdmitEngine(params, cfg,
                               EngineConfig(max_slots=2, capacity=32))
        h4 = e4.submit(prompt, sp)
        e4.submit([1, 2, 3], SamplingParams(max_new_tokens=4,
                                            temperature=1.0, seed=5))
        assert h4.result().tokens == ref

    def test_retirements_preserve_neighbors_across_fleets(self, small_model):
        """The cancellation guarantee extended to every retirement path:
        a neighbor expiring on deadline, NaN-poisoned, or killed by a
        dispatch fault leaves a seeded request bit-identical across the
        same fleet compositions as the invariance test above."""
        from repro.serving import FaultInjector, FaultPlan, VirtualClock

        cfg, params = small_model
        prompt = [5, 9, 17, 2]
        sp = SamplingParams(**self.SP)
        solo = ServingEngine(params, cfg,
                             EngineConfig(max_slots=1, capacity=32))
        ref = solo.submit(prompt, sp).result().tokens

        # fleet 2: co-batched victim expires mid-flight (deadline sweep)
        clock = VirtualClock()
        e2 = ServingEngine(
            params, cfg, EngineConfig(max_slots=3, capacity=32),
            injector=FaultInjector(FaultPlan().stall_clock(2, 60.0),
                                   clock=clock))
        h2 = e2.submit(prompt, sp)
        victim2 = e2.submit([1, 2], SamplingParams(
            max_new_tokens=64, temperature=3.0, seed=9, deadline_s=30.0))
        e2.submit([3, 4, 5], SamplingParams(max_new_tokens=3))
        assert h2.result().tokens == ref
        e2.run()  # h2 may finish before step 2; drain so the stall fires
        assert victim2.finish_reason == "timeout"

        # fleet 3: different chunk boundaries, victim NaN-poisoned on device
        e3 = ServingEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32,
                                      decode_chunk=1, prefill_chunk=2),
            injector=FaultInjector(FaultPlan().nan_logits(uid=7,
                                                          gen_index=2)))
        h3 = e3.submit(prompt, sp)
        victim3 = e3.submit([7], SamplingParams(max_new_tokens=8,
                                                temperature=0.5, seed=3),
                            uid=7)
        assert h3.result().tokens == ref
        e3.run()
        assert victim3.finish_reason == "error"

        # fleet 4: the serial-admit scheduler, victim's dispatch raises
        e4 = SerialAdmitEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32),
            injector=FaultInjector(
                FaultPlan().dispatch_error("prefill", 1, uid=5)))
        h4 = e4.submit(prompt, sp)
        victim4 = e4.submit([1, 2, 3], SamplingParams(
            max_new_tokens=4, temperature=1.0, seed=5), uid=5)
        assert h4.result().tokens == ref
        e4.run()
        assert victim4.finish_reason == "error"

    def test_same_seed_same_output_repeated(self, small_model):
        cfg, params = small_model
        outs = []
        for _ in range(2):
            eng = ServingEngine(params, cfg,
                                EngineConfig(max_slots=1, capacity=32))
            outs.append(eng.submit([3, 1, 4], SamplingParams(
                max_new_tokens=4, temperature=1.1, seed=7)).result().tokens)
        assert outs[0] == outs[1]

    def test_stream_first_token_lands_with_prefill_completion(self,
                                                              small_model):
        """tokens() yields the first token in the same engine step that
        consumed the prompt's last prefill chunk — stream TTFT is engine
        TTFT, not engine-TTFT-plus-a-drain."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg,
                            EngineConfig(max_slots=1, capacity=32,
                                         prefill_chunk=2))
        h = eng.submit([5, 9, 17, 2, 11], SamplingParams(max_new_tokens=4))
        steps = []
        orig = eng.step
        eng.step = lambda: steps.append(0) or orig()
        it = h.tokens()
        first = next(it)
        # 5 prompt tokens / prefill_chunk 2 → 3rd step finishes prefill
        assert len(steps) == 3
        assert h.t_first > 0 and h.output[0] == first
        assert list(it) == h.output[1:] and h.done
        assert h.finish_reason == "length"

    def test_cancel_mid_decode_preserves_neighbor(self, small_model):
        """Cancelling a decoding request frees its slot without perturbing
        a co-resident request (output bit-identical with and without the
        cancellation), and the slot admits new work cleanly."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        ref = solo.submit([7, 8, 9], SamplingParams(
            max_new_tokens=8)).result().tokens

        eng = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                      capacity=32,
                                                      decode_chunk=2))
        keeper = eng.submit([7, 8, 9], SamplingParams(max_new_tokens=8))
        victim = eng.submit([1, 2], SamplingParams(max_new_tokens=64,
                                                   temperature=1.0, seed=1))
        eng.step()
        eng.step()
        assert victim.output and not victim.done  # genuinely mid-decode
        assert victim.cancel()
        assert victim.cancelled and victim.t_done > 0
        assert eng.slots.count(None) == 1  # freed immediately
        # slot reuse: a fresh request admits into the freed slot and is
        # itself bit-identical to its solo reference
        ref2 = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=32)).submit(
                [2, 3], SamplingParams(max_new_tokens=4)).result().tokens
        fresh = eng.submit([2, 3], SamplingParams(max_new_tokens=4))
        assert keeper.result().tokens == ref
        assert fresh.result().tokens == ref2
        assert not victim.cancel()  # idempotent: already finished

    def test_cancel_mid_prefill_preserves_neighbor(self, small_model):
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        ref = solo.submit([7, 8, 9], SamplingParams(
            max_new_tokens=6)).result().tokens

        eng = ServingEngine(params, cfg,
                            EngineConfig(max_slots=2, capacity=32,
                                         prefill_chunk=2))
        keeper = eng.submit([7, 8, 9], SamplingParams(max_new_tokens=6))
        victim = eng.submit(list(range(1, 13)), SamplingParams(
            max_new_tokens=8))
        eng.step()
        assert not victim.output and not victim.done  # mid-prefill
        assert victim.cancel()
        assert victim.output == [] and victim.cancelled
        assert keeper.result().tokens == ref
        # the freed slot admits and completes new work
        fresh = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=3))
        assert len(fresh.result().tokens) == 3

    def test_cancel_queued_never_admits(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit([1, 2], SamplingParams(max_new_tokens=2))
        queued = eng.submit([3, 4], SamplingParams(max_new_tokens=2))
        assert queued.cancel()
        eng.run()
        assert queued.output == [] and queued.cancelled
        assert eng.admits == 1

    def test_stop_set_truncates_mid_chunk(self, small_model):
        """Any SamplingParams.stop id ends the request at its first hit,
        wherever inside a fused decode chunk it lands."""
        cfg, params = small_model
        free = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        free_run = free.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=8)).result().tokens
        stop = free_run[3]
        first = free_run.index(stop)

        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32,
                                                      decode_chunk=8))
        res = eng.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=8, stop={stop})).result()
        assert res.tokens == free_run[:first + 1]
        assert res.finish_reason == "stop"

    def test_stop_hit_by_prefill_finisher(self, small_model):
        """The very first token (sampled as prefill completes) already
        honors the stop set — the request finishes without ever decoding."""
        cfg, params = small_model
        free = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        first = free.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=4)).result().tokens[0]

        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        res = eng.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=4, stop={first})).result()
        assert res.tokens == (first,) and res.finish_reason == "stop"
        assert eng.slots == [None] and eng.steps == 0

    def test_multi_stop_set_with_eos(self, small_model):
        """SamplingParams.stop composes with EngineConfig.eos_id: whichever
        id generates first terminates."""
        cfg, params = small_model
        free = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        free_run = free.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=8)).result().tokens
        eng = ServingEngine(params, cfg,
                            EngineConfig(max_slots=1, capacity=32,
                                         eos_id=free_run[4]))
        res = eng.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=8, stop={free_run[2], 100_000})).result()
        cut = min(free_run.index(free_run[2]), free_run.index(free_run[4]))
        assert res.tokens == free_run[:cut + 1]

    def test_truncated_prompt_flagged(self, small_model):
        """Prompts longer than capacity are clipped at admission — and now
        say so instead of silently dropping tokens."""
        cfg, params = small_model
        prompt = list(np.random.default_rng(0).integers(1, 500, size=20))
        ref = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=8)).submit(
                prompt[-8:], SamplingParams(max_new_tokens=3))
        assert not ref.truncated
        ref_toks = ref.result().tokens

        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=8))
        h = eng.submit(prompt, SamplingParams(max_new_tokens=3))
        assert h.truncated  # surfaced at submit, before admission
        res = h.result()
        assert res.truncated and res.tokens == ref_toks

    def test_pre_v1_shim_is_gone(self, small_model):
        """The deprecated Request/run() shim had its one PR of grace and is
        removed: the package no longer exports Request, and submit rejects
        anything that is not a token-id sequence."""
        import repro.serving as serving

        assert not hasattr(serving, "Request")
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        with pytest.raises(TypeError):
            eng.submit(object())
        with pytest.raises(TypeError):
            eng.submit("tokenize me first")
        # run() survives as the batch-driver style and returns v1 handles
        h = eng.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=3))
        done = eng.run()
        assert done == [h] and h.done and len(h.output) == 3

    def test_topk_topp_request_restricts_support(self, small_model):
        """A top-k request's every sampled token stays inside the greedy
        row's top-k support (probe via top_k=1 == greedy)."""
        cfg, params = small_model
        greedy = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                         capacity=32))
        ref = greedy.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=4)).result().tokens
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                      capacity=32))
        h = eng.submit([5, 9, 17, 2], SamplingParams(
            max_new_tokens=4, temperature=1.5, top_k=1, seed=123))
        eng.submit([1, 2], SamplingParams(max_new_tokens=4, temperature=1.0,
                                          top_p=0.9, seed=4))
        assert h.result().tokens == ref  # top_k=1 at any temp == greedy
