"""Serving engine: continuous batching, quantized path, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.ptqtp import PTQTPConfig
from repro.core.quantize_model import quantize_tree
from repro.models import forward, init_params
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.sampling import sample_token, sample_tokens


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([[0.1, 5.0, -2.0], [3.0, 0.0, 1.0]])
        toks = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
        np.testing.assert_array_equal(np.asarray(toks), [1, 0])

    def test_topk_restricts_support(self):
        logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]]).repeat(64, 0)
        toks = sample_token(logits, jax.random.PRNGKey(1),
                            temperature=1.0, top_k=2)
        assert set(np.asarray(toks).tolist()) <= {1, 2}

    def test_per_row_temperatures(self):
        """Row 0 (temp 0) must be the argmax even when other rows sample."""
        logits = jnp.asarray([[0.1, 5.0, -2.0],
                              [1.0, 1.1, 0.9],
                              [3.0, 0.0, 1.0]])
        temps = jnp.asarray([0.0, 2.0, 0.0])
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(2), temps))
        assert toks[0] == 1 and toks[2] == 0

    def test_vectorized_matches_scalar_greedy(self):
        logits = jnp.asarray(np.random.default_rng(3)
                             .standard_normal((8, 17), dtype=np.float32))
        a = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
        b = sample_tokens(logits, jax.random.PRNGKey(0), jnp.zeros((8,)))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestEngine:
    def test_completes_all_requests(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                      capacity=64))
        for i in range(5):  # more requests than slots → continuous batching
            eng.submit(Request(uid=i, prompt=[1, 2, 3 + i],
                               max_new_tokens=4))
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.output) == 4 for r in done)
        assert all(r.done for r in done)

    def test_greedy_matches_forward_argmax(self, small_model):
        """Engine prefill+decode must reproduce teacher-forced argmax path."""
        cfg, params = small_model
        prompt = [5, 9, 17, 2]
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
        out = eng.run()[0].output

        seq = list(prompt)
        expect = []
        for _ in range(3):
            logits = forward(params, cfg,
                             {"tokens": jnp.asarray([seq], jnp.int32)})
            tok = int(jnp.argmax(logits[0, -1]))
            expect.append(tok)
            seq.append(tok)
        assert out == expect, (out, expect)

    def test_eos_stops_early(self, small_model):
        cfg, params = small_model
        logits = forward(params, cfg,
                         {"tokens": jnp.asarray([[5, 9, 17, 2]], jnp.int32)})
        eos = int(jnp.argmax(logits[0, -1]))  # first generated token == EOS
        eng = ServingEngine(params, cfg,
                            EngineConfig(max_slots=1, capacity=32,
                                         eos_id=eos))
        eng.submit(Request(uid=0, prompt=[5, 9, 17, 2], max_new_tokens=64))
        done = eng.run()
        assert len(done) == 1 and len(done[0].output) <= 2

    def test_quantized_params_serve(self, small_model):
        cfg, params = small_model
        qp, _ = quantize_tree(params, PTQTPConfig(group_size=32, t_max=5))
        eng = ServingEngine(qp, cfg, EngineConfig(max_slots=2, capacity=32))
        for i in range(3):
            eng.submit(Request(uid=i, prompt=[1, 2, 3], max_new_tokens=3))
        done = eng.run()
        assert len(done) == 3
        assert all(len(r.output) == 3 for r in done)

    def test_fused_chunk_matches_per_step(self, small_model):
        """K-token fused decode must be bit-identical to per-token decode
        at temperature 0 (same requests, chunk=8 vs chunk=1)."""
        cfg, params = small_model
        reqs = [([5, 9, 17, 2], 6), ([1, 2, 3], 5), ([7], 4)]
        outs = {}
        for chunk in (1, 8):
            eng = ServingEngine(params, cfg,
                                EngineConfig(max_slots=2, capacity=32,
                                             decode_chunk=chunk))
            for i, (prompt, mnt) in enumerate(reqs):
                eng.submit(Request(uid=i, prompt=prompt, max_new_tokens=mnt))
            outs[chunk] = {r.uid: r.output for r in eng.run()}
        assert outs[1] == outs[8]

    def test_fused_chunk_respects_eos(self, small_model):
        """EOS inside a chunk must truncate the output mid-chunk."""
        cfg, params = small_model
        # find the 2nd greedy continuation token, use it as EOS
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit(Request(uid=0, prompt=[5, 9, 17, 2], max_new_tokens=8))
        free_run = eng.run()[0].output
        eos = free_run[2]
        eng2 = ServingEngine(params, cfg,
                             EngineConfig(max_slots=1, capacity=32,
                                          eos_id=eos, decode_chunk=8))
        eng2.submit(Request(uid=0, prompt=[5, 9, 17, 2], max_new_tokens=8))
        out = eng2.run()[0].output
        # stops at (and includes) the *first* occurrence of the EOS token
        first = free_run.index(eos)
        assert out == free_run[:first + 1]

    def test_per_slot_temperature_isolation(self, small_model):
        """A greedy slot must stay greedy while a co-batched slot samples at
        high temperature (regression: engine used max over slot temps)."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        solo.submit(Request(uid=0, prompt=[7, 8, 9], max_new_tokens=5))
        ref = solo.run()[0].output

        mixed = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                        capacity=32))
        mixed.submit(Request(uid=0, prompt=[7, 8, 9], max_new_tokens=5,
                             temperature=0.0))
        mixed.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=5,
                             temperature=8.0))
        outs = {r.uid: r.output for r in mixed.run()}
        assert outs[0] == ref

    def test_slot_isolation(self, small_model):
        """A request's outputs must not depend on its co-batched neighbors."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=32))
        solo.submit(Request(uid=0, prompt=[7, 8, 9], max_new_tokens=4))
        ref = solo.run()[0].output

        packed = ServingEngine(params, cfg, EngineConfig(max_slots=3,
                                                         capacity=32))
        packed.submit(Request(uid=0, prompt=[7, 8, 9], max_new_tokens=4))
        packed.submit(Request(uid=1, prompt=[1], max_new_tokens=4))
        packed.submit(Request(uid=2, prompt=[2, 3], max_new_tokens=4))
        outs = {r.uid: r.output for r in packed.run()}
        assert outs[0] == ref
