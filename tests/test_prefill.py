"""Bucketed/chunked prefill: model-level exactness + scheduler edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, init_decode_state, init_params,
                          prefill, prefill_chunk)
from repro.serving import SamplingParams
from repro.serving.engine import (EngineConfig, SerialAdmitEngine,
                                  ServingEngine)

ARCHS = ("qwen2-1.5b", "rwkv6-3b", "recurrentgemma-2b")


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = configs.get_smoke_config(arch)
        out[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _greedy(params, cfg, state, tok, n):
    toks = []
    for _ in range(n):
        logits, state = decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(tok[0]))
    return toks


class TestPrefillChunkModel:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_chunked_matches_full(self, models, arch):
        """Prompt fed in chunks (with padding in the tail chunk) must yield
        the same last-token logits and a decode-equivalent state as one
        whole-prompt prefill — incl. sliding-window archs whose ring wraps."""
        cfg, params = models[arch]
        rng = np.random.default_rng(0)
        prompt = rng.integers(1, 400, size=11).tolist()
        cap = 16  # < prompt for window layers of recurrentgemma (window 8)
        lg_full, st_full = prefill(
            params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)},
            capacity=cap)
        st = init_decode_state(cfg, 1, cap)
        for start in range(0, len(prompt), 4):
            chunk = prompt[start:start + 4]
            t = np.zeros((1, 4), np.int32)
            t[0, :len(chunk)] = chunk
            lg, st = prefill_chunk(params, cfg, st, {"tokens": jnp.asarray(t)},
                                   jnp.asarray([len(chunk)], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(lg_full, np.float32),
                                   rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(lg_full, -1).astype(jnp.int32)
        assert _greedy(params, cfg, st_full, tok, 4) == \
            _greedy(params, cfg, st, tok, 4)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_padded_batch_matches_per_row(self, models, arch):
        """Rows of different lengths in one padded bucket must each match
        their own solo prefill (padding never leaks across rows)."""
        cfg, params = models[arch]
        prompts = [[5, 9], [1, 2, 3, 4, 7], [11, 3, 6]]
        cap, L = 16, 8
        st = init_decode_state(cfg, len(prompts), cap)
        toks = np.zeros((len(prompts), L), np.int32)
        lens = np.asarray([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lg, st = prefill_chunk(params, cfg, st, {"tokens": jnp.asarray(toks)},
                               jnp.asarray(lens))
        assert np.asarray(st["pos"]).tolist() == lens.tolist()
        for i, p in enumerate(prompts):
            lg1, _ = prefill(params, cfg,
                             {"tokens": jnp.asarray([p], jnp.int32)},
                             capacity=cap)
            np.testing.assert_allclose(np.asarray(lg[i], np.float32),
                                       np.asarray(lg1[0], np.float32),
                                       rtol=2e-4, atol=2e-4)

    def test_zero_length_rows_are_noops(self, models):
        """lengths == 0 must leave every state leaf bit-identical — that is
        what lets decoding/free slots ride through the prefill dispatch."""
        cfg, params = models["qwen2-1.5b"]
        st = init_decode_state(cfg, 2, 16)
        toks = jnp.asarray([[3, 4, 5, 0], [7, 8, 0, 0]], jnp.int32)
        _, st = prefill_chunk(params, cfg, st, {"tokens": toks},
                              jnp.asarray([3, 2], jnp.int32))
        _, st2 = prefill_chunk(params, cfg, st, {"tokens": toks},
                               jnp.zeros((2,), jnp.int32))
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
            assert jnp.array_equal(a, b)


class TestBucketedScheduler:
    @pytest.fixture(scope="class")
    def small_model(self, models):
        return models["qwen2-1.5b"]

    def _mixed_outputs(self, cls, params, cfg, prompts, **cfg_kw):
        eng = cls(params, cfg, EngineConfig(**cfg_kw))
        for i, (p, mnt) in enumerate(prompts):
            eng.submit(p, SamplingParams(max_new_tokens=mnt), uid=i)
        done = eng.run()
        return eng, {r.uid: tuple(r.output) for r in done}

    def test_bit_identity_and_compile_bound(self, small_model):
        """Bucketed admits must reproduce the serial path token for token at
        temperature 0, while its prefill compile cache stays within the
        O(log prefill_chunk) bucket bound (the serial cache grows per
        distinct length)."""
        cfg, params = small_model
        rng = np.random.default_rng(1)
        # queue (9) deeper than slots (3); lengths exercise: 1 token, short,
        # longer than prefill_chunk (8), longer than capacity (32)
        lens = (1, 3, 9, 4, 20, 2, 40, 6, 12)
        prompts = [(rng.integers(1, 500, size=n).tolist(), 5) for n in lens]
        eng_s, out_s = self._mixed_outputs(
            SerialAdmitEngine, params, cfg, prompts,
            max_slots=3, capacity=32, prefill_chunk=8, decode_chunk=4)
        eng_b, out_b = self._mixed_outputs(
            ServingEngine, params, cfg, prompts,
            max_slots=3, capacity=32, prefill_chunk=8, decode_chunk=4)
        assert out_s == out_b
        stats = eng_b.compile_stats()
        bound = stats["prefill_bucket_bound"]
        assert bound == 4  # log2(8) + 1
        assert stats["n_prefill_compiles"] <= bound
        assert all(L & (L - 1) == 0 and L <= 8
                   for L in stats["prefill_bucket_lengths"])
        # the serial baseline's cache is per-length (here: every clipped
        # distinct length), which is exactly what bucketing bounds away
        assert eng_s.compile_stats()["n_prefill_compiles"] == len(
            {min(n, 32) for n in lens})

    def test_warmup_precompiles_everything(self, small_model):
        """After warmup() no serving workload may add a prefill or decode
        compile — the dispatch set really is closed and bounded."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=2, capacity=32, prefill_chunk=8, decode_chunk=4))
        eng.warmup()
        before = eng.compile_stats()
        assert before["prefill_bucket_lengths"] == [1, 2, 4, 8]
        rng = np.random.default_rng(2)
        for i, n in enumerate((1, 5, 13, 40, 7)):
            eng.submit(rng.integers(1, 500, size=n).tolist(),
                       SamplingParams(max_new_tokens=3), uid=i)
        assert len(eng.run()) == 5
        after = eng.compile_stats()
        assert after["prefill_bucket_lengths"] == before["prefill_bucket_lengths"]
        assert after["decode_chunk_lengths"] == before["decode_chunk_lengths"]

    def test_prompt_longer_than_capacity(self, small_model):
        """Prompts are clipped to the last `capacity` tokens; the clipped
        tail must drive generation identically across schedulers."""
        cfg, params = small_model
        rng = np.random.default_rng(3)
        prompt = rng.integers(1, 500, size=50).tolist()
        outs = {}
        for cls in (SerialAdmitEngine, ServingEngine):
            eng = cls(params, cfg, EngineConfig(max_slots=1, capacity=16,
                                                prefill_chunk=8))
            eng.submit(prompt, SamplingParams(max_new_tokens=4), uid=0)
            outs[cls] = eng.run()[0].output
            assert len(outs[cls]) == 4
        assert outs[SerialAdmitEngine] == outs[ServingEngine]

    def test_eos_on_prefill_sampled_token(self, small_model):
        """If the very first generated token is EOS the request finishes at
        admission and the slot is immediately reusable."""
        cfg, params = small_model
        probe = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                        capacity=32))
        probe.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=1), uid=0)
        eos = probe.run()[0].output[0]
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=32, prefill_chunk=8, eos_id=eos))
        eng.submit([5, 9, 17, 2], SamplingParams(max_new_tokens=64), uid=0)
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=2), uid=1)
        done = {r.uid: r for r in eng.run()}
        assert done[0].done and done[0].output == [eos]
        assert done[1].done and len(done[1].output) == 2

    def test_max_new_tokens_1(self, small_model):
        """max_new_tokens=1 finishes at prefill: no decode dispatch needed."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=2,
                                                      capacity=32,
                                                      prefill_chunk=8))
        for i in range(3):
            eng.submit([1 + i, 2, 3], SamplingParams(max_new_tokens=1),
                       uid=i)
        done = eng.run()
        assert len(done) == 3
        assert all(len(r.output) == 1 and r.done for r in done)
        assert eng.steps == 0  # never decoded

    def test_long_prompt_interleaves_with_decode(self, small_model):
        """A long prompt admitted mid-flight must not stall a decoding slot:
        the decoder's output is unchanged and the engine interleaves decode
        chunks between the long prompt's prefill chunks."""
        cfg, params = small_model
        solo = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                       capacity=64,
                                                       prefill_chunk=8))
        solo.submit([7, 8, 9], SamplingParams(max_new_tokens=10), uid=0)
        ref = solo.run()[0].output

        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=2, capacity=64, prefill_chunk=8, decode_chunk=4))
        eng.submit([7, 8, 9], SamplingParams(max_new_tokens=10), uid=0)
        eng.step()  # uid 0 is decoding now
        rng = np.random.default_rng(4)
        eng.submit(rng.integers(1, 500, size=40).tolist(),
                   SamplingParams(max_new_tokens=3), uid=1)
        decode_steps_before = eng.steps
        done = {r.uid: r for r in eng.run()}
        assert done[0].output == ref  # decoder unaffected by the long admit
        assert len(done[1].output) == 3
        # decode advanced while the 40-token prompt was still chunking
        # (40 tokens / chunk 8 = 5 prefill steps, decode ran throughout)
        assert eng.prefill_steps >= 5
        assert eng.steps > decode_steps_before

    def test_empty_prompt_rejected(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=16))
        with pytest.raises(ValueError):
            eng.submit([], SamplingParams(max_new_tokens=2), uid=0)
