"""2-bit trit packing: round-trip + storage-size properties."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; the rest of the module runs without
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.core.packing import pack_trits, ptqtp_weight_bytes, unpack_trits

if hypothesis is not None:
    trit_arrays = hnp.arrays(
        np.int8,
        st.tuples(st.integers(1, 7), st.sampled_from([4, 8, 128, 256])),
        elements=st.sampled_from([-1, 0, 1]),
    )

    @hypothesis.given(t=trit_arrays)
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_pack_unpack_roundtrip(t):
        packed = pack_trits(jnp.asarray(t))
        assert packed.dtype == jnp.uint8
        assert packed.shape == (*t.shape[:-1], t.shape[-1] // 4)
        out = np.asarray(unpack_trits(packed))
        np.testing.assert_array_equal(out, t)


def test_pack_unpack_roundtrip_seeded():
    """Deterministic roundtrip (always runs, hypothesis or not)."""
    for shape in [(1, 4), (7, 128), (3, 256)]:
        t = np.random.default_rng(hash(shape) % 2**32).integers(
            -1, 2, shape).astype(np.int8)
        packed = pack_trits(jnp.asarray(t))
        assert packed.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack_trits(packed)), t)


def test_stacked_roundtrip():
    t = np.random.default_rng(0).integers(-1, 2, (3, 5, 64)).astype(np.int8)
    out = np.asarray(unpack_trits(pack_trits(jnp.asarray(t))))
    np.testing.assert_array_equal(out, t)


def test_compression_ratio_matches_paper():
    """Paper App. A.3: 2 planes @ 2 bit + fp16 α per 128-group ≈ 0.531 B/w,
    3.76× smaller than fp16."""
    n, d = 1024, 4096
    bytes_q = ptqtp_weight_bytes((n, d), 128)
    bytes_fp16 = 2 * n * d
    ratio = bytes_fp16 / bytes_q
    assert 3.7 < ratio < 3.8, ratio


def test_reject_bad_width():
    import pytest

    with pytest.raises(ValueError):
        pack_trits(jnp.zeros((2, 5), jnp.int8))
