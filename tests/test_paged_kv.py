"""Paged int8 KV cache: page-gather attention bit-identity, the refcounted
page allocator, and engine-level COW prefix reuse.

Three layers of guarantee:

  * **kernel** — ``chunk_attention_paged`` on every backend is
    *bit-identical* to its contiguous-ring counterpart under random page
    permutations (with matching tile the logical tile walk is the same
    float program; ``materialized`` is gather-then-oracle by
    construction), across ring wrap, sliding windows, GQA, decode L = 1,
    length-0 rows, and the all-null-page table;
  * **allocator** — refcounts partition the pool, COW forks preserve the
    original, LRU eviction only ever takes cache-only pages, failed
    allocation rolls back;
  * **engine** — a 90%-shared-prefix fleet produces outputs identical to
    cold-start and to the ring layout; every retirement path (finish,
    cancel, timeout, error containment — the fault-harness paths) returns
    its pages to the pool; admission waits for pages FIFO and sheds
    never-fits requests at submit.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.chunk_attention import (chunk_attention,
                                           chunk_attention_paged,
                                           gather_pages, paged_tile)
from repro.kernels.chunk_attention.ref import chunk_attention_ref
from repro.models import init_params
from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                           PageAllocator, SamplingParams, SerialAdmitEngine,
                           ServingEngine, VirtualClock)
from tests.test_chunk_attention_kernel import make_case

BACKENDS = ("materialized", "stream", "pallas")


def paginate(rng, ring_args, page_size):
    """Scatter a contiguous-ring case into randomly permuted physical
    pages: per-row page p of the ring lands at a random distinct physical
    id >= 1; physical page 0 is the reserved null page (pos = -1)."""
    (q, kn, vn, kc, ks, vc, vs, pb, positions, lengths) = ring_args
    b, cap = pb.shape
    ps = page_size
    assert cap % ps == 0
    n = cap // ps
    P = b * n + 1
    perm = rng.permutation(np.arange(1, P))
    table = perm.reshape(b, n)

    def pool_of(ring, fill=0):
        if ring is None:
            return None
        pool = np.full((P, ps) + ring.shape[2:], fill, np.asarray(ring).dtype)
        src = np.asarray(ring).reshape((b, n, ps) + ring.shape[2:])
        pool[table.reshape(-1)] = src.reshape((b * n, ps) + ring.shape[2:])
        return jnp.asarray(pool)

    pos_pool = np.full((P, ps), -1, np.int32)
    pos_pool[table.reshape(-1)] = np.asarray(pb).reshape(b * n, ps)
    return (q, kn, vn, pool_of(kc), pool_of(ks), pool_of(vc), pool_of(vs),
            jnp.asarray(pos_pool), jnp.asarray(table, jnp.int32),
            positions, lengths)


PAGED_CASES = [
    # (b, L, kv, g, hd, cap, ps, window, int8, wrap)
    pytest.param(2, 8, 2, 2, 16, 32, 8, None, True, False, id="gqa-full"),
    pytest.param(2, 8, 1, 4, 16, 32, 8, None, True, True, id="gqa-wrap"),
    pytest.param(2, 8, 4, 1, 16, 32, 16, 8, True, True, id="window-wrap"),
    pytest.param(2, 6, 1, 3, 8, 24, 8, 5, True, True, id="ps8-cap24"),
    pytest.param(3, 1, 2, 2, 8, 16, 4, None, True, True, id="decode-L1"),
    pytest.param(3, 1, 2, 2, 8, 16, 8, 8, True, True, id="decode-window"),
    pytest.param(2, 4, 2, 2, 8, 16, 4, None, False, False, id="float-cache"),
]


class TestPageGatherBitIdentity:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("b,L,kv,g,hd,cap,ps,window,int8,wrap",
                             PAGED_CASES)
    def test_paged_equals_ring_bitwise(self, backend, b, L, kv, g, hd, cap,
                                       ps, window, int8, wrap):
        """Random page permutation, matching tile → the paged op walks the
        identical logical tile sequence as the contiguous op: outputs must
        be equal to the last bit, per backend."""
        rng = np.random.default_rng(hash((b, L, cap, ps, int8)) % 2**31)
        ring = make_case(rng, b, L, kv, g, hd, cap, int8=int8, wrap=wrap)
        paged = paginate(rng, ring, ps)
        want = np.asarray(chunk_attention(*ring, window=window,
                                          backend=backend, tile=ps))
        got = np.asarray(chunk_attention_paged(*paged, window=window,
                                               backend=backend, tile=ps))
        np.testing.assert_array_equal(got, want, err_msg=backend)

    def test_materialized_is_gather_then_oracle(self):
        """The paged materialized path is literally gather_pages + the
        contiguous oracle — pin that construction."""
        rng = np.random.default_rng(11)
        ring = make_case(rng, 2, 8, 2, 2, 8, 32, int8=True, wrap=True)
        paged = paginate(rng, ring, 8)
        (q, kn, vn, kp, ksp, vp, vsp, posp, table, positions, lengths) = paged
        want = chunk_attention_ref(
            q, kn, vn, gather_pages(kp, table), gather_pages(ksp, table),
            gather_pages(vp, table), gather_pages(vsp, table),
            gather_pages(posp, table), positions, lengths, window=None)
        got = chunk_attention_paged(*paged, backend="materialized")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and the gathered ring reconstructs the original exactly
        np.testing.assert_array_equal(np.asarray(gather_pages(posp, table)),
                                      np.asarray(ring[7]))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_null_page_table_is_safe(self, backend):
        """An all-zero table (nothing mapped — warmup, freed rows) gathers
        only the null page: everything masked, output finite."""
        rng = np.random.default_rng(3)
        ring = make_case(rng, 2, 4, 2, 2, 8, 16,
                         lengths=np.zeros((2,), np.int64))
        paged = paginate(rng, ring, 4)
        paged = paged[:8] + (jnp.zeros_like(paged[8]),) + paged[9:]
        out = np.asarray(chunk_attention_paged(*paged, backend=backend))
        assert np.isfinite(out).all(), backend

    def test_paged_tile_divides_page(self):
        for ps in (4, 8, 16, 128, 4096):
            for L in (1, 8, 64, 512):
                t = paged_tile(ps, L)
                assert ps % t == 0 and t >= 1
        assert paged_tile(16, 8) == 16     # whole page per tile
        assert paged_tile(4096, 64) < 4096  # budget-bound splits the page


class TestPageAllocator:
    def test_alloc_release_partitions_pool(self):
        a = PageAllocator(8, 16)
        got = a.alloc(5)
        assert len(set(got)) == 5 and 0 not in got
        assert a.used_pages() == 5 and a.free_pages == 3
        a.check()
        for pid in got:
            a.release(pid)
        assert a.used_pages() == 0 and a.free_pages == 8
        a.check()

    def test_alloc_rolls_back_on_failure(self):
        a = PageAllocator(4, 16)
        held = a.alloc(3)
        with pytest.raises(MemoryError):
            a.alloc(2)
        assert a.free_pages == 1  # the partial grab was returned
        a.check()
        assert held == a.alloc(0) + held  # held pages untouched

    def test_refcounts_and_fork(self):
        a = PageAllocator(4, 16)
        (pid,) = a.alloc(1)
        a.retain(pid)
        assert a.shared_pages() == 1
        new = a.fork(pid)
        assert new != pid and a.forks == 1
        assert a.ref[pid] == 1 and a.ref[new] == 1  # fork dropped one ref
        with pytest.raises(RuntimeError):
            a.fork(pid)  # unshared pages must not fork
        a.release(pid)
        with pytest.raises(RuntimeError):
            a.release(pid)  # double free

    def test_cache_eviction_is_lru_and_ref_safe(self):
        a = PageAllocator(3, 16)
        p = a.alloc(3)
        for i, pid in enumerate(p):
            a.cache_insert((i,), pid)
            a.release(pid)  # cache holds the only ref now
        assert a.available() == 3 and a.free_pages == 0
        a.cache_lookup([(0,)])  # touch LRU; also retains for "a request"
        got = a.alloc(1)  # must evict (1,), the LRU *unreferenced* entry
        assert a.evictions == 1 and got[0] == p[1]
        assert a.cache_lookup([(1,)]) == []  # gone
        assert a.cache_lookup([(2,)]) == [p[2]]  # survivors intact
        a.check()

    def test_disabled_cache_never_serves(self):
        a = PageAllocator(2, 16, prefix_cache=False)
        (pid,) = a.alloc(1)
        a.cache_insert((1, 2), pid)
        assert a.cache_lookup([(1, 2)]) == [] and a.cached_pages() == 0


@pytest.fixture(scope="module")
def paged_model():
    cfg = dataclasses.replace(configs.get_smoke_config("qwen2-1.5b"),
                              kv_cache_dtype="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def paged_cfg(**kw):
    base = dict(max_slots=4, capacity=128, prefill_chunk=32, decode_chunk=8,
                kv_layout="paged", page_size=16)
    base.update(kw)
    return EngineConfig(**base)


def shared_fleet(n=6, seed=7, vocab=500):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, size=96).tolist()  # 90% of the prompt
    return [prefix + rng.integers(1, vocab, size=8).tolist()
            for _ in range(n)]


class TestEnginePrefixReuse:
    def test_shared_prefix_fleet_matches_cold_and_ring(self, paged_model):
        """The tentpole guarantee: outputs are identical whether a prefix
        was shared (paged + cache), recomputed (paged, cache off), or
        served from the contiguous ring — and the cache actually hit."""
        cfg, params = paged_model
        prompts = shared_fleet()

        def run(**kw):
            eng = ServingEngine(params, cfg, paged_cfg(**kw))
            hs = [eng.submit(p, SamplingParams(max_new_tokens=10,
                                               temperature=0.8, seed=i))
                  for i, p in enumerate(prompts)]
            eng.run()
            return eng, [h.output for h in hs]

        ring_eng, ring_out = run(kv_layout="ring")
        warm_eng, warm_out = run()
        cold_eng, cold_out = run(prefix_cache=False)
        assert warm_out == cold_out == ring_out
        assert warm_eng.alloc.hits > 0
        assert cold_eng.alloc.hits == 0
        # cache reuse showed up as skipped prefill work
        assert warm_eng.prefill_steps < cold_eng.prefill_steps

    def test_pages_return_to_baseline_after_drain(self, paged_model):
        cfg, params = paged_model
        eng = ServingEngine(params, cfg, paged_cfg())
        for i, p in enumerate(shared_fleet(4)):
            eng.submit(p, SamplingParams(max_new_tokens=6, seed=i))
        eng.run()
        eng.alloc.check()
        # only prefix-cache holds survive; nothing leaks
        assert eng.alloc.used_pages() == eng.alloc.cached_pages()
        assert eng.alloc.shared_pages() == 0
        snap = eng.health()
        assert snap.pages_used == eng.alloc.used_pages()
        assert snap.pages_free == eng.alloc.free_pages
        assert snap.prefix_hits == eng.alloc.hits

    def test_cow_fork_on_wrap_keeps_cache_pristine(self, paged_model):
        """Generation that wraps the ring overwrites the request's oldest
        pages — shared prefix pages must fork (COW), and a later request
        must still see the untouched prefix."""
        cfg, params = paged_model
        ecfg = paged_cfg(max_slots=2)
        eng = ServingEngine(params, cfg, ecfg)
        prompt = shared_fleet(1)[0]
        eng.submit(prompt, SamplingParams(max_new_tokens=4, seed=0))
        eng.run()  # registers the prefix
        assert eng.alloc.forks == 0
        eng.submit(prompt, SamplingParams(max_new_tokens=40, seed=1))
        eng.run()  # 104 + 40 > 128: wraps, must fork shared pages
        assert eng.alloc.forks > 0
        eng.alloc.check()
        # third request reuses the (pristine) cached prefix; a cache-off
        # engine recomputes it — identical outputs prove no corruption
        warm = eng.submit(prompt, SamplingParams(max_new_tokens=8, seed=5))
        eng.run()
        cold_eng = ServingEngine(params, cfg,
                                 dataclasses.replace(ecfg,
                                                     prefix_cache=False))
        cold = cold_eng.submit(prompt, SamplingParams(max_new_tokens=8,
                                                      seed=5))
        cold_eng.run()
        assert warm.output == cold.output

    def test_prefix_reuse_disabled_for_recurrent_models(self, paged_model):
        """A recurrent mixer can't skip tokens: prefix_cache auto-disables
        (the engine still pages) instead of serving wrong state."""
        cfg, params = paged_model
        rec = dataclasses.replace(cfg, prefix_pattern=("rwkv",))
        try:
            eng = ServingEngine(params, rec, paged_cfg())
        except Exception:
            pytest.skip("recurrent smoke state not buildable here")
        assert not eng._prefix_reuse
        assert not eng.alloc.prefix_cache_enabled


class TestEnginePageLifecycle:
    def _baseline(self, eng):
        return eng.alloc.used_pages() - eng.alloc.cached_pages()

    def test_cancel_releases_pages(self, paged_model):
        cfg, params = paged_model
        eng = ServingEngine(params, cfg, paged_cfg(max_slots=2))
        h = eng.submit(shared_fleet(1)[0],
                       SamplingParams(max_new_tokens=30, seed=0))
        eng.step()
        assert self._baseline(eng) > 0  # resident and holding pages
        assert h.cancel()
        assert self._baseline(eng) == 0
        eng.alloc.check()

    def test_timeout_releases_pages(self, paged_model):
        cfg, params = paged_model
        clock = VirtualClock()
        eng = ServingEngine(params, cfg, paged_cfg(max_slots=2),
                            injector=FaultInjector(FaultPlan(), clock=clock))
        h = eng.submit(shared_fleet(1)[0],
                       SamplingParams(max_new_tokens=64, deadline_s=5.0))
        eng.step()
        assert self._baseline(eng) > 0
        clock.advance(6.0)
        eng.step()
        assert h.finish_reason == "timeout"
        assert self._baseline(eng) == 0
        eng.alloc.check()

    def test_error_containment_releases_pages(self, paged_model):
        """A dispatch fault retires the request through _contain — its
        pages must come back even though the slot is quarantined."""
        cfg, params = paged_model
        plan = FaultPlan().dispatch_error("decode", 0)
        eng = ServingEngine(params, cfg, paged_cfg(max_slots=2),
                            injector=FaultInjector(plan,
                                                   clock=VirtualClock()))
        h = eng.submit([1, 2, 3, 4], SamplingParams(max_new_tokens=8))
        eng.run()
        assert h.finish_reason == "error"
        assert self._baseline(eng) == 0
        assert eng.quarantined or eng.errors == 1
        eng.alloc.check()

    def test_admission_waits_for_pages_fifo(self, paged_model):
        """With a pool sized for one resident request, the second queues
        until the first retires — and admits as soon as pages free."""
        cfg, params = paged_model
        # 64-token capacity, 4-page pool: each request's worst case is
        # ceil((32+32)/16) = 4 pages → exactly one resident at a time
        eng = ServingEngine(params, cfg, paged_cfg(
            max_slots=2, capacity=64, page_size=16, max_pages=4,
            prefix_cache=False))
        a = eng.submit(list(range(1, 33)), SamplingParams(max_new_tokens=32))
        b = eng.submit(list(range(2, 34)), SamplingParams(max_new_tokens=32))
        eng.step()
        assert eng.slots.count(None) == 1  # b is page-blocked, not admitted
        assert eng.queue and eng.queue[0] is b
        eng.run()
        assert a.finish_reason == "length" and b.finish_reason == "length"
        assert len(a.output) == 32 and len(b.output) == 32
        eng.alloc.check()

    def test_never_fits_sheds_at_submit(self, paged_model):
        cfg, params = paged_model
        eng = ServingEngine(params, cfg, paged_cfg(
            max_slots=2, capacity=64, page_size=16, max_pages=2))
        h = eng.submit(list(range(1, 40)), SamplingParams(max_new_tokens=32))
        assert h.finish_reason == "rejected" and "page budget" in h.error
        assert eng.sheds == 1
        ok = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=2))
        eng.run()
        assert ok.finish_reason == "length"  # small requests still serve

    def test_memory_stats_reports_paged_kv(self, paged_model):
        cfg, params = paged_model
        eng = ServingEngine(params, cfg, paged_cfg())
        ms = eng.memory_stats()
        assert ms["kv_layout"] == "paged"
        empty = ms["kv_resident_bytes"]
        h = eng.submit(shared_fleet(1)[0],
                       SamplingParams(max_new_tokens=20, seed=0))
        eng.step()
        grown = eng.memory_stats()["kv_resident_bytes"]
        assert grown > empty  # used pages cost bytes
        assert grown <= ms["kv_pool_bytes"]
        h.cancel()
        ring = ServingEngine(params, cfg, paged_cfg(kv_layout="ring"))
        rms = ring.memory_stats()
        assert rms["kv_layout"] == "ring"
        assert rms["kv_resident_bytes"] == rms["kv_pool_bytes"]

    def test_serial_engine_rejects_paged(self, paged_model):
        cfg, params = paged_model
        with pytest.raises(ValueError, match="ring"):
            SerialAdmitEngine(params, cfg, paged_cfg())
