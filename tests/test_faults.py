"""Fault-contained serving: deadlines, load shedding, quarantine, and the
deterministic fault-injection harness (repro.serving.faults).

The keystone assertion, repeated across scenarios: whatever the plan does
to other requests — NaN logits, dispatch exceptions, deadline expiry,
shedding — requests the plan does *not* touch finish bit-identical to a
fault-free run."""

import jax
import pytest

from repro import configs
from repro.artifacts import ArtifactError, load_artifact, verify_artifact
from repro.models import init_params
from repro.serving import (EngineConfig, FaultInjector, FaultPlan,
                           SamplingParams, SerialAdmitEngine, ServingEngine,
                           VirtualClock)
from repro.serving.faults import (corrupt_artifact_shard,
                                  truncate_artifact_shard)


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("qwen2-1.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def solo_ref(small_model, prompt, sp):
    cfg, params = small_model
    eng = ServingEngine(params, cfg, EngineConfig(max_slots=1, capacity=32))
    return eng.submit(prompt, sp).result().tokens


def timed_engine(small_model, ecfg=None, plan=None):
    """Engine on a VirtualClock (tests never sleep)."""
    cfg, params = small_model
    clock = VirtualClock()
    inj = FaultInjector(plan or FaultPlan(), clock=clock)
    eng = ServingEngine(params, cfg,
                        ecfg or EngineConfig(max_slots=2, capacity=32),
                        injector=inj)
    return eng, clock


class TestDeadlines:
    def test_deadline_expires_mid_decode(self, small_model):
        """A resident request past deadline_s retires with "timeout" at the
        next step, keeping the tokens it already produced; its co-batched
        neighbor is bit-unperturbed."""
        sp = SamplingParams(max_new_tokens=8, temperature=0.9, seed=41)
        ref = solo_ref(small_model, [5, 9, 17, 2], sp)

        eng, clock = timed_engine(small_model, EngineConfig(
            max_slots=2, capacity=32, decode_chunk=2))
        keeper = eng.submit([5, 9, 17, 2], sp)
        victim = eng.submit([1, 2], SamplingParams(max_new_tokens=64,
                                                   deadline_s=10.0))
        eng.step()
        eng.step()
        assert victim.output and not victim.done  # genuinely mid-decode
        got = len(victim.output)
        clock.advance(11.0)
        eng.step()  # sweep fires before this step's work
        assert victim.finish_reason == "timeout"
        assert len(victim.output) == got  # kept what it had
        assert victim.t_done == clock()
        assert keeper.result().tokens == ref
        assert eng.timeouts == 1

    def test_ttft_deadline_expires_queued_request(self, small_model):
        """A queued request that misses its first-token budget never
        admits; one that produced token 0 in time is no longer bound by
        ttft_deadline_s."""
        eng, clock = timed_engine(small_model)
        fast = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=6,
                                                    ttft_deadline_s=5.0))
        eng.submit([4, 5], SamplingParams(max_new_tokens=6))
        late = eng.submit([6, 7], SamplingParams(max_new_tokens=2,
                                                 ttft_deadline_s=5.0))
        eng.step()  # both slots busy; `late` waits
        assert fast.output  # first token landed inside the budget
        clock.advance(6.0)
        done = eng.run()
        assert late.finish_reason == "timeout" and late.output == []
        assert late in done
        assert fast.finish_reason == "length"  # ttft satisfied, no deadline
        assert len(fast.output) == 6

    def test_deadline_frees_slot_for_next_admission(self, small_model):
        eng, clock = timed_engine(small_model, EngineConfig(max_slots=1,
                                                            capacity=32))
        stuck = eng.submit([1, 2], SamplingParams(max_new_tokens=64,
                                                  deadline_s=1.0))
        nxt = eng.submit([3, 4], SamplingParams(max_new_tokens=3))
        eng.step()
        clock.advance(2.0)
        eng.step()  # sweep retires `stuck`; same step admits `nxt`
        assert stuck.finish_reason == "timeout"
        assert eng.admits == 2  # `nxt` reused the freed slot that same step
        assert len(nxt.result().tokens) == 3

    def test_stall_clock_fault_is_deterministic(self, small_model):
        """FaultPlan.stall_clock expires a deadline at an exact engine
        step, twice over."""
        cfg, params = small_model
        reasons = []
        for _ in range(2):
            inj = FaultInjector(FaultPlan().stall_clock(at_step=2,
                                                        advance_s=60.0),
                                clock=VirtualClock())
            eng = ServingEngine(params, cfg,
                                EngineConfig(max_slots=2, capacity=32),
                                injector=inj)
            h = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=32,
                                                     deadline_s=30.0))
            eng.run()
            reasons.append((h.finish_reason, len(h.output)))
            assert inj.log and inj.log[0][0] == "stall"
        assert reasons[0] == reasons[1]
        assert reasons[0][0] == "timeout"


class TestAdmissionControl:
    def test_reject_policy_sheds_past_queue_cap(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=32, max_queue=1,
            admission_policy="reject"))
        a = eng.submit([1, 2], SamplingParams(max_new_tokens=2))
        eng.step()  # `a` admits into the slot; the queue is free again
        b = eng.submit([3, 4], SamplingParams(max_new_tokens=2))
        shed = eng.submit([5, 6], SamplingParams(max_new_tokens=2))
        assert shed.finish_reason == "rejected" and shed.done
        assert "queue full" in shed.error
        assert shed.result().error == shed.error  # surfaced in the record
        eng.run()
        assert a.finish_reason == b.finish_reason == "length"
        assert eng.sheds == 1

    def test_resident_token_cap_sheds(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=2, capacity=32, max_resident_tokens=20))
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=8))  # 11 tokens
        shed = eng.submit([4, 5], SamplingParams(max_new_tokens=16))  # +18
        assert shed.finish_reason == "rejected"
        assert "resident-token" in shed.error
        ok = eng.submit([4, 5], SamplingParams(max_new_tokens=4))  # +6 fits
        eng.run()
        assert ok.finish_reason == "length"

    def test_block_policy_waits_for_drain(self, small_model):
        """Under "block", an over-cap submit drives step() until the fleet
        drains — the handle returns admissible, nothing is shed."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=32, max_resident_tokens=6,
            admission_policy="block"))
        a = eng.submit([1, 2], SamplingParams(max_new_tokens=2))  # 4 committed
        b = eng.submit([3, 4], SamplingParams(max_new_tokens=2))  # 4 more > 6
        # submit(b) could only return once `a` fully left residency
        assert a.done and not b.done
        eng.run()
        assert b.finish_reason == "length" and eng.sheds == 0

    def test_never_fits_rejected_even_under_block(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(
            max_slots=1, capacity=32, max_resident_tokens=8,
            admission_policy="block"))
        h = eng.submit([1, 2, 3, 4], SamplingParams(max_new_tokens=16))
        assert h.finish_reason == "rejected"
        assert "resident-token cap" in h.error

    def test_resident_tokens_accounting(self, small_model):
        """The gauge counts clipped prompt + generation budget over
        queued + resident work and drains as requests finish."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=5))   # 8
        eng.submit([4, 5], SamplingParams(max_new_tokens=4))       # 6 queued
        assert eng.resident_tokens() == 14
        eng.run()
        assert eng.resident_tokens() == 0


class TestFaultContainment:
    def test_nan_logits_mid_decode_contained(self, small_model):
        """NaN poison at generated-token k (through the real on-device
        detection path): the victim retires with "error" after k tokens,
        the slot quarantines, the neighbor is bit-identical."""
        sp = SamplingParams(max_new_tokens=8, temperature=0.9, seed=41)
        ref = solo_ref(small_model, [5, 9, 17, 2], sp)
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32,
                                      quarantine_steps=None),
            injector=FaultInjector(FaultPlan().nan_logits(uid=1,
                                                          gen_index=3)))
        keeper = eng.submit([5, 9, 17, 2], sp)        # uid 0
        victim = eng.submit([1, 2], SamplingParams(max_new_tokens=8))
        eng.run()
        assert victim.finish_reason == "error"
        assert len(victim.output) == 3  # tokens before the poisoned one
        assert "non-finite logits" in victim.error
        assert keeper.output == list(ref)
        assert list(eng.quarantined) != []

    def test_nan_at_prefill_finisher_contained(self, small_model):
        """gen_index 0 poisons the token sampled as prefill completes."""
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32),
            injector=FaultInjector(FaultPlan().nan_logits(uid=0,
                                                          gen_index=0)))
        victim = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        other = eng.submit([4, 5], SamplingParams(max_new_tokens=4))
        eng.run()
        assert victim.finish_reason == "error" and victim.output == []
        assert "prefill" in victim.error
        assert other.finish_reason == "length" and len(other.output) == 4

    def test_attributed_dispatch_fault_retires_one_row(self, small_model):
        """An EngineFault carrying a slot retires exactly that request;
        survivors repeat the vetoed step and stay bit-identical."""
        sp = SamplingParams(max_new_tokens=6, temperature=0.9, seed=41)
        ref = solo_ref(small_model, [5, 9, 17, 2], sp)
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg,
            EngineConfig(max_slots=2, capacity=32, decode_chunk=2),
            injector=FaultInjector(
                FaultPlan().dispatch_error("decode", 1, uid=1)))
        keeper = eng.submit([5, 9, 17, 2], sp)
        victim = eng.submit([1, 2], SamplingParams(max_new_tokens=6))
        eng.run()
        assert victim.finish_reason == "error"
        assert "dispatch failed" in victim.error
        assert keeper.finish_reason == "length"
        assert keeper.output == list(ref)
        assert eng.errors == 1

    def test_unattributed_dispatch_fault_contains_whole_dispatch(
            self, small_model):
        """No slot attribution → every participating request retires (the
        honest containment unit); the engine keeps stepping and fresh work
        completes after rehabilitation."""
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg,
            EngineConfig(max_slots=2, capacity=32, quarantine_steps=None),
            injector=FaultInjector(FaultPlan().dispatch_error("decode", 0)))
        a = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        b = eng.submit([4, 5], SamplingParams(max_new_tokens=4))
        eng.run()
        assert a.finish_reason == b.finish_reason == "error"
        assert sorted(eng.quarantined) == [0, 1]
        # operator override: row-reset + return to pool, then serve again
        assert sorted(eng.rehabilitate()) == [0, 1]
        assert eng.quarantined == {}
        c = eng.submit([6, 7], SamplingParams(max_new_tokens=3))
        eng.run()
        assert c.finish_reason == "length"

    def test_prefill_dispatch_fault_contained(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32),
            injector=FaultInjector(
                FaultPlan().dispatch_error("prefill", 0)))
        a = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=3))
        b = eng.submit([4, 5], SamplingParams(max_new_tokens=3))
        eng.run()
        # both rows were in the vetoed first prefill dispatch
        assert a.finish_reason == b.finish_reason == "error"
        assert eng.errors == 2

    def test_quarantine_cooldown_auto_rehabilitates(self, small_model):
        """quarantine_steps engine steps after containment, the slot
        row-resets and rejoins the pool on its own — a fully-quarantined
        engine self-heals instead of stranding queued work."""
        cfg, params = small_model
        eng = ServingEngine(
            params, cfg,
            EngineConfig(max_slots=1, capacity=32, quarantine_steps=2),
            injector=FaultInjector(FaultPlan().dispatch_error("decode", 0)))
        bad = eng.submit([1, 2], SamplingParams(max_new_tokens=4))
        queued = eng.submit([3, 4], SamplingParams(max_new_tokens=3))
        done = eng.run()
        assert bad.finish_reason == "error"
        assert queued.finish_reason == "length" and queued in done
        assert eng.quarantined == {}

    def test_serial_engine_contains_faults_too(self, small_model):
        """The PR-1 baseline implements the same containment contract."""
        cfg, params = small_model
        eng = SerialAdmitEngine(
            params, cfg, EngineConfig(max_slots=2, capacity=32),
            injector=FaultInjector(FaultPlan()
                                   .dispatch_error("prefill", 0)
                                   .nan_logits(uid=1, gen_index=0)))
        a = eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        b = eng.submit([4, 5], SamplingParams(max_new_tokens=4))
        c = eng.submit([6, 7, 8], SamplingParams(max_new_tokens=4))
        eng.run()
        assert a.finish_reason == "error" and b.finish_reason == "error"
        assert c.finish_reason == "length"  # self-healed via cool-down

    def test_production_engine_has_no_injection_residue(self, small_model):
        """injector=None (the default) compiles the poison path out: the
        decode jit cache never contains a use_poison=True entry."""
        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit([1, 2, 3], SamplingParams(max_new_tokens=4))
        eng.run()
        assert all(k[3] is False for k in eng._loop_cache)


class TestHealthSnapshot:
    def test_gauges_and_counters(self, small_model):
        plan = FaultPlan().nan_logits(uid=0, gen_index=1)
        eng, clock = timed_engine(small_model, EngineConfig(
            max_slots=2, capacity=32, max_queue=3,
            quarantine_steps=None), plan)
        victim = eng.submit([1, 2], SamplingParams(max_new_tokens=8))
        eng.submit([3, 4], SamplingParams(max_new_tokens=2))
        eng.submit([5, 6], SamplingParams(max_new_tokens=2))
        shed = eng.submit([7, 8], SamplingParams(max_new_tokens=2))
        h = eng.health()
        assert h.queue_depth == 3 and h.resident == 0
        assert h.sheds == 1 and shed.finish_reason == "rejected"
        eng.run()
        h = eng.health()
        assert victim.finish_reason == "error"
        assert h.errors == 1 and h.completed == 2
        assert h.quarantined_slots != ()
        assert h.free_slots == 2 - len(h.quarantined_slots)
        assert h.resident_tokens == 0 and h.queue_depth == 0
        assert h.t == clock()
        s = h.summary()
        assert "error=1" in s and "shed=1" in s

    def test_snapshot_beats_into_fleet_monitor(self, small_model, tmp_path):
        """A serving host publishes through the training heartbeat
        protocol and shows up in the same StragglerDetector assessment."""
        from repro.runtime.monitor import HeartbeatMonitor, StragglerDetector

        cfg, params = small_model
        eng = ServingEngine(params, cfg, EngineConfig(max_slots=1,
                                                      capacity=32))
        eng.submit([1, 2], SamplingParams(max_new_tokens=2))
        eng.run()
        eng.health().beat(HeartbeatMonitor(str(tmp_path), host_id=0))
        rep = StragglerDetector(str(tmp_path)).assess()
        assert rep["healthy"] == [0]
        beat = StragglerDetector(str(tmp_path)).read()[0]
        assert beat["completed"] == 1 and beat["queue_depth"] == 0


class TestArtifactFaults:
    @pytest.fixture()
    def artifact(self, tmp_path, small_model):
        from repro.core.ptqtp import PTQTPConfig
        from repro.artifacts import write_artifact

        cfg, params = small_model
        out = tmp_path / "artifact"
        write_artifact(out, arch="qwen2-1.5b", model_cfg=cfg,
                       ptqtp_cfg=PTQTPConfig(group_size=32, t_max=5),
                       params=params)
        return out

    def test_corrupt_shard_report_names_damage(self, artifact):
        """verify="full" rejects a bit-flipped artifact and the error
        pinpoints the tensor, buffer, shard, byte range, and both crc32s."""
        dmg = corrupt_artifact_shard(artifact, seed=3)
        with pytest.raises(ArtifactError) as ei:
            load_artifact(artifact, verify="full")
        msg = str(ei.value)
        assert dmg["tensor"] in msg and dmg["buffer"] in msg
        assert dmg["shard"] in msg
        assert f"{dmg['crc32']:#010x}" in msg  # expected crc named
        assert "got" in msg                    # ...and the actual one

    def test_truncated_shard_caught_by_sizes_mode(self, artifact):
        """verify="sizes" rejects a torn shard from stat() alone."""
        dmg = truncate_artifact_shard(artifact, seed=0, drop_bytes=7)
        with pytest.raises(ArtifactError, match="truncated"):
            load_artifact(artifact, verify="sizes")
        with pytest.raises(ArtifactError, match=dmg["shard"]):
            verify_artifact(artifact, mode="sizes")

    def test_sizes_mode_passes_intact_artifact(self, artifact):
        tree, _ = load_artifact(artifact, verify="sizes")
        assert tree  # loaded; no checksum work was needed
        assert verify_artifact(artifact, mode="sizes") != {} or True

    def test_corruption_invisible_to_sizes_mode(self, artifact):
        """A bit-flip keeps sizes intact — only "full" catches it (the
        documented trade: O(#shards) stat vs full read)."""
        corrupt_artifact_shard(artifact, seed=1)
        load_artifact(artifact, verify="sizes")  # passes
        with pytest.raises(ArtifactError):
            load_artifact(artifact, verify="full")


class TestChaosScenario:
    def test_survivors_bit_identical_under_combined_faults(self,
                                                           small_model):
        """The acceptance scenario in miniature: NaN injection + dispatch
        exception + deadline expiry + 2x over-capacity admission, and every
        untouched request matches its fault-free twin bit for bit."""
        cfg, params = small_model
        prompts = [[5, 9, 17, 2], [1, 2], [3, 4, 5], [7, 8], [9, 10, 11],
                   [12, 13], [14, 15, 16], [6, 7]]
        sps = [SamplingParams(max_new_tokens=4 + (i % 3),
                              temperature=0.0 if i % 2 else 0.9,
                              seed=100 + i)
               for i in range(len(prompts))]

        def run(plan, ecfg):
            clock = VirtualClock()
            inj = FaultInjector(plan, clock=clock)
            eng = ServingEngine(params, cfg, ecfg, injector=inj)
            handles = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
            eng.run()
            return handles, eng

        base_cfg = dict(max_slots=2, capacity=32, decode_chunk=2)
        clean, _ = run(FaultPlan(), EngineConfig(**base_cfg))
        assert all(h.finish_reason == "length" for h in clean)

        plan = (FaultPlan()
                .nan_logits(uid=1, gen_index=1)
                .dispatch_error("decode", 3, uid=3)
                .stall_clock(at_step=4, advance_s=60.0))
        sps_f = list(sps)
        sps_f[5] = SamplingParams(max_new_tokens=4 + (5 % 3),
                                  temperature=0.9, seed=105,
                                  deadline_s=30.0)  # expires at the stall
        ecfg = EngineConfig(**base_cfg, max_queue=6,
                            admission_policy="reject")
        clock = VirtualClock()
        inj = FaultInjector(plan, clock=clock)
        eng = ServingEngine(params, cfg, ecfg, injector=inj)
        faulty = [eng.submit(p, sp) for p, sp in zip(prompts, sps_f)]
        eng.run()

        # touched = anything a fault, deadline, or the admission cap hit
        # (a dispatch fault that lands unattributed contains every request
        # in that dispatch — the containment unit, not a fixed uid set)
        touched = {h.uid for h in faulty
                   if h.finish_reason in ("error", "timeout", "rejected")}
        survivors = [h for h in faulty if h.uid not in touched]
        assert survivors  # the scenario must actually exercise survivors
        by_uid = {h.uid: h for h in clean}
        for h in survivors:
            assert h.finish_reason == "length"
            assert h.output == by_uid[h.uid].output, f"uid {h.uid}"
        assert faulty[1].finish_reason == "error"    # the planned NaN victim
        assert faulty[5].finish_reason == "timeout"  # expired at the stall
        assert any("dispatch failed" in (h.error or "") for h in faulty)
        assert sum(h.finish_reason == "rejected" for h in faulty) == 2
        kinds = {k for k, _ in inj.log}
        assert {"nan", "dispatch", "stall"} <= kinds
