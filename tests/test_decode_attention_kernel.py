"""Fused int8-KV flash-decode attention kernel vs jnp oracle (§Perf it. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _case(b, s, kv, g, hd, filled, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, kv, g, hd), dtype=np.float32))
    k8 = jnp.asarray(rng.integers(-127, 128, (b, s, kv, hd)), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 128, (b, s, kv, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, s, kv)).astype(np.float32))
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, s, kv)).astype(np.float32))
    pb = np.full((b, s), -1, np.int32)
    pb[:, :filled] = np.arange(filled)
    pos = jnp.asarray(rng.integers(filled - 8, filled, (b,)), jnp.int32)
    return q, k8, ks, v8, vs, jnp.asarray(pb), pos


@pytest.mark.parametrize("b,s,kv,g,hd,filled", [
    (1, 256, 1, 1, 64, 200),     # MHA corner, partially filled ring
    (2, 1024, 2, 4, 64, 700),    # GQA
    (3, 512, 4, 2, 128, 512),    # full ring, hd=128
    (2, 768, 1, 8, 64, 100),     # non-pow2 S, mostly empty
])
@pytest.mark.parametrize("window", [None, 128])
def test_matches_oracle(b, s, kv, g, hd, filled, window):
    args = _case(b, s, kv, g, hd, filled, seed=s)
    y_k = decode_attention(*args, window=window)
    y_r = decode_attention_ref(*args, window=window)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def test_matches_model_int8_decode_path():
    """Kernel semantics == the in-model int8 decode attention math."""
    from repro import configs
    from repro.models import attention as attn
    from repro.models import init_params

    cfg = configs.get_smoke_config("qwen2-1.5b").scaled(
        kv_cache_dtype="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["b0"])["attn"]
    b, cap = 2, 32
    cache = attn.cache_init(cfg, b, cap, None, jnp.float32)
    rng = np.random.default_rng(1)
    pos0 = jnp.zeros((b,), jnp.int32)
    cache2 = cache
    xs = [jnp.asarray(rng.standard_normal((b, cfg.d_model), np.float32) * .1)
          for _ in range(5)]
    for t, x_t in enumerate(xs):
        y_model, cache2 = attn.attention_decode(
            p, cfg, cache2, x_t, jnp.full((b,), t, jnp.int32))
    # replay the last step through the kernel
    from repro.models.common import apply_rope, dense

    x_t = xs[-1]
    pos = jnp.full((b,), 4, jnp.int32)
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    g = cfg.n_heads // kv
    q = dense(p["wq"], x_t).reshape(b, cfg.n_heads, hd)
    q = apply_rope(q, pos, cfg.rope_theta).reshape(b, kv, g, hd)
    y_kern = decode_attention(
        q.astype(jnp.float32), cache2["k"], cache2["k_scale"],
        cache2["v"], cache2["v_scale"], cache2["pos"], pos)
    y_kern = dense(p["wo"], y_kern.reshape(b, cfg.n_heads * hd))
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               rtol=5e-3, atol=5e-3)
